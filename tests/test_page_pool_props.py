"""Property-based :class:`PagePool` invariants (DESIGN.md §9/§10).

Random alloc/free traces — driven by hypothesis when installed, the
seeded fixed-corpus fallback in ``tests/_hyp.py`` otherwise — must
uphold the allocator's contract at EVERY step of the trace, not just at
quiescence:

* the null page (id 0) is never handed out and never freeable,
* a live (allocated, not yet freed) page is never handed out again,
* ``free_pages + live == num_pages - 1`` — pages are conserved,
* ``can_alloc`` tells the truth: an alloc it approves succeeds, one it
  rejects raises without changing the pool.
"""
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.serving import NULL_PAGE, PagePool, PrefixIndex


@settings(max_examples=50, deadline=None)
@given(
    st.integers(0, 2**31 - 1),
    st.integers(2, 17),
    st.integers(1, 9),
)
def test_pool_random_trace_invariants(seed, num_pages, page_size):
    rng = np.random.default_rng(seed)
    pool = PagePool(num_pages=num_pages, page_size=page_size)
    usable = num_pages - 1
    live = {}                       # alloc seq no -> page list
    n_allocs = 0
    for _ in range(40):
        do_alloc = bool(rng.integers(2)) or not live
        if do_alloc:
            tokens = int(rng.integers(1, 3 * page_size + 1))
            need = pool.pages_for(tokens)
            assert need == max(1, -(-tokens // page_size))
            if pool.can_alloc(tokens):
                got = pool.alloc(tokens)
                assert len(got) == need
                assert NULL_PAGE not in got
                assert all(0 < p < num_pages for p in got)
                # no page may be live twice
                flat = [p for ps in live.values() for p in ps]
                assert set(got).isdisjoint(flat)
                assert len(set(got)) == len(got)
                live[n_allocs] = got
                n_allocs += 1
            else:
                before = pool.free_pages
                with pytest.raises(RuntimeError):
                    pool.alloc(tokens)
                assert pool.free_pages == before   # failed alloc is a no-op
        else:
            key = list(live)[int(rng.integers(len(live)))]
            pool.free(live.pop(key))
        n_live = sum(len(ps) for ps in live.values())
        assert pool.free_pages + n_live == usable   # conservation
        assert pool.used_pages == n_live

    for pages in live.values():                     # drain: all pages return
        pool.free(pages)
    assert pool.free_pages == usable and pool.used_pages == 0


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 9))
def test_pool_rejects_double_and_null_frees(seed, num_pages):
    rng = np.random.default_rng(seed)
    pool = PagePool(num_pages=num_pages, page_size=4)
    got = pool.alloc(int(rng.integers(1, 4 * (num_pages - 1) + 1))) \
        if pool.can_alloc(1) else []
    with pytest.raises(ValueError):
        pool.free([NULL_PAGE])
    if got:
        pool.free(got)
        with pytest.raises(ValueError):
            pool.free([got[0]])                     # double free
        with pytest.raises(ValueError):
            pool.free([num_pages + 7])              # out of range


@settings(max_examples=50, deadline=None)
@given(
    st.integers(0, 2**31 - 1),
    st.integers(2, 17),
)
def test_pool_refcount_trace_invariants(seed, num_pages):
    """Random alloc/share/free/cow traces against a host-side refcount
    mirror (DESIGN.md §12).  At EVERY step:

    * conservation under sharing: ``free_pages + #{refcount > 0} ==
      num_pages - 1`` — a page is on the free list XOR referenced,
    * ``refcount`` / ``live_refs`` match the mirror exactly,
    * no page is freed while referenced: share/free/cow of a refcount-0
      page raise without changing the pool,
    * COW never aliases a writer: ``cow`` of a shared page returns a
      FRESH page (caller's ref transferred), and only an exclusively
      owned page comes back as itself with no copy counted.
    """
    rng = np.random.default_rng(seed)
    pool = PagePool(num_pages=num_pages, page_size=4)
    usable = num_pages - 1
    refs = {}                       # mirror: pid -> refcount
    high = 0

    def check():
        live = {p for p, r in refs.items() if r > 0}
        assert pool.free_pages + len(live) == usable
        assert pool.live_refs() == sum(refs.values())
        for p, r in refs.items():
            assert pool.refcount(p) == r
        assert pool.ref_high_water == high

    for _ in range(60):
        live = [p for p, r in refs.items() if r > 0]
        op = rng.choice(["alloc", "share", "free", "cow", "bad"])
        if op == "alloc" or not live:
            if pool.free_pages:
                pid = pool.alloc_pages(1)[0]
                assert refs.get(pid, 0) == 0        # never hand out a live page
                refs[pid] = 1
                high = max(high, 1)
            else:
                with pytest.raises(RuntimeError):
                    pool.alloc_pages(1)
        elif op == "share":
            pid = int(rng.choice(live))
            pool.share([pid])
            refs[pid] += 1
            high = max(high, refs[pid])
        elif op == "free":
            pid = int(rng.choice(live))
            pool.free([pid])
            refs[pid] -= 1
        elif op == "cow":
            pid = int(rng.choice(live))
            copies = pool.cow_copies
            if refs[pid] == 1:
                assert pool.cow(pid) == pid         # exclusive: no copy
                assert pool.cow_copies == copies
            elif pool.free_pages == 0:
                before = dict(refs)
                with pytest.raises(RuntimeError):
                    pool.cow(pid)                   # dry pool: clean failure
                for p, r in before.items():
                    assert pool.refcount(p) == r
            else:
                new = pool.cow(pid)
                assert new != pid                   # never aliases the writer
                assert refs.get(new, 0) == 0
                refs[pid] -= 1
                refs[new] = 1
                assert pool.refcount(new) == 1
                assert pool.cow_copies == copies + 1
        else:
            dead = [p for p in range(1, num_pages) if refs.get(p, 0) == 0]
            if dead:
                pid = int(rng.choice(dead))
                for bad in (pool.share, pool.free):
                    with pytest.raises(ValueError):
                        bad([pid])                  # refcount-0 page
                with pytest.raises(ValueError):
                    pool.cow(pid)
            with pytest.raises(ValueError):
                pool.free([NULL_PAGE])
        check()

    for pid, r in refs.items():                     # drain all references
        pool.free([pid] * r)
    assert pool.free_pages == usable and pool.live_refs() == 0


def test_prefix_index_roundtrip_retire_and_eviction():
    """PrefixIndex lifecycle against one pool (DESIGN.md §12): chain-hash
    insert/match roundtrip, the proper-prefix cap, branch sharing,
    survival past request retirement, and leaf-first LRU eviction that
    never reclaims a page another holder still maps."""
    pool = PagePool(num_pages=20, page_size=4)
    idx = PrefixIndex(pool)
    rng = np.random.default_rng(3)

    a = rng.integers(0, 100, size=13).astype(np.int32)   # 3 full blocks + 1
    assert idx.match(a) == []                            # cold index
    a_pages = pool.alloc_pages(4)
    assert idx.insert(a, a_pages) == 3                   # only FULL blocks
    assert len(idx) == 3

    # roundtrip + proper-prefix cap: a 12-token prompt with identical
    # content may only match 2 blocks — its own last block must prefill
    assert idx.match(a) == a_pages[:3]
    assert idx.match(a[:12]) == a_pages[:2]
    assert idx.match(a[:4]) == []                        # 1 block -> cap 0

    # same content at a different position must not alias (chain hash)
    shifted = np.concatenate([a[4:8], a[4:8], a[4:8]]).astype(np.int32)
    assert idx.match(shifted) == []

    # divergent sibling: shares 2 blocks, adds 1 of its own (a branch)
    b = np.concatenate([a[:8], rng.integers(100, 200, size=5)]).astype(np.int32)
    hits = idx.match(b)
    assert hits == a_pages[:2]
    pool.share(hits)                                     # b maps the hit pages
    b_pages = hits + pool.alloc_pages(2)
    assert idx.insert(b, b_pages) == 1                   # 2 blocks were hits
    assert len(idx) == 4

    # retirement frees the requests' refs; the index refs keep every
    # indexed page alive for readmission
    pool.free(a_pages)
    pool.free(b_pages)
    assert idx.match(a) == a_pages[:3]
    assert idx.match(b) == a_pages[:2] + [b_pages[2]]
    assert pool.free_pages == 19 - 4                     # only non-indexed back

    # all 4 entries are refcount-1 now; exclude pins
    assert idx.evictable_pages() == 4
    assert idx.evictable_pages(exclude=a_pages[:2]) == 2

    # leaf-first: the shared trunk (children > 0) cannot be a victim
    # while its continuations are cached.  Evict one page: a leaf goes.
    assert idx.evict(1) == 1
    assert len(idx) == 3
    assert idx.match(a[:8]) == a_pages[:1]               # trunk still matches
    # a pinned leaf never goes: exclude everything -> nothing evictable
    assert idx.evict(10, exclude=[e for e in a_pages + b_pages]) == 0
    # drain the rest leaf-first; every page returns exactly once
    assert idx.evict(10) == 3
    assert len(idx) == 0 and idx.evictions == 4
    assert pool.free_pages == 19 and pool.live_refs() == 0

    # a page still mapped by a live request is never evictable
    c_pages = pool.alloc_pages(1)
    c = rng.integers(0, 100, size=4).astype(np.int32)
    idx.insert(c, c_pages)                               # refcount 2: req + index
    assert idx.evictable_pages() == 0 and idx.evict(1) == 0
    pool.free(c_pages)                                   # request retires
    assert idx.evictable_pages() == 1
    assert idx.clear() == 1
    assert pool.free_pages == 19


# ---------------------------------------------------------------------------
# Fault tolerance (DESIGN.md §13): the owned-refs ledger, verify(),
# drop_pages() quarantine, and clear()-under-corruption
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_prefix_index_ledger_trace_invariants(seed):
    """Random insert/evict/drop_pages/clear traces: at every step the
    owned-refs ledger must equal the entries' page multiset, ``verify``
    must report healthy, and the pool must balance exactly against
    request refs + ledger refs (conservation under quarantine)."""
    rng = np.random.default_rng(seed)
    pool = PagePool(num_pages=24, page_size=4)
    idx = PrefixIndex(pool)
    request_pages = []              # pages live requests still map

    def check():
        assert idx.verify() == []
        entry_pages = {}
        for e in idx._entries.values():
            entry_pages[e.page] = entry_pages.get(e.page, 0) + 1
        assert entry_pages == idx._owned
        live = sum(len(ps) for ps in request_pages) + sum(idx._owned.values())
        assert pool.live_refs() == live
        held = {p for ps in request_pages for p in ps} | set(idx._owned)
        assert pool.free_pages == 23 - len(held)

    for _ in range(40):
        op = rng.choice(["insert", "retire", "evict", "drop", "clear"])
        if op == "insert" and pool.free_pages >= 3:
            prompt = rng.integers(0, 50, size=int(rng.integers(4, 13)))
            n = pool.pages_for(len(prompt))
            hits = idx.match(prompt.astype(np.int32))
            if pool.free_pages >= n - len(hits):
                pool.share(hits)
                pages = hits + pool.alloc_pages(n - len(hits))
                idx.insert(prompt.astype(np.int32), pages)
                request_pages.append(pages)
        elif op == "retire" and request_pages:
            pool.free(request_pages.pop(int(rng.integers(len(request_pages)))))
        elif op == "evict":
            idx.evict(int(rng.integers(1, 4)))
        elif op == "drop" and idx._owned:
            victims = rng.choice(sorted(idx._owned),
                                 size=min(2, len(idx._owned)), replace=False)
            idx.drop_pages(int(v) for v in victims)
        elif op == "clear":
            idx.clear()
            assert not idx._owned and not len(idx)
        check()

    for ps in request_pages:
        pool.free(ps)
    idx.clear()
    assert pool.free_pages == 23 and pool.live_refs() == 0


def test_prefix_index_verify_catches_corruption_and_clear_is_safe():
    """Scrambled entries must be DETECTED by verify() and releasable by
    clear() without a leak or double-free — the ledger, not the corrupt
    entry fields, decides what returns to the pool."""
    rng = np.random.default_rng(11)
    pool = PagePool(num_pages=20, page_size=4)
    idx = PrefixIndex(pool)
    a = rng.integers(0, 100, size=12).astype(np.int32)
    pages = pool.alloc_pages(3)
    idx.insert(a, pages)
    assert idx.verify() == []

    # corruption 1: page field scrambled to a DIFFERENT owned page
    victim = next(iter(idx._entries.values()))
    orig = victim.page
    victim.page = pages[(pages.index(orig) + 1) % 3]
    assert any("ledger" in s for s in idx.verify())
    victim.page = orig
    assert idx.verify() == []

    # corruption 2: page field scrambled to the null page
    victim.page = 0
    assert any("invalid page" in s for s in idx.verify())
    victim.page = orig

    # corruption 3: children count drifts
    victim.children += 1
    assert any("children" in s for s in idx.verify())
    victim.children -= 1

    # corruption 4: dangling parent link
    leaf = list(idx._entries.values())[-1]
    keep_parent = leaf.parent
    leaf.parent = 123456789
    reports = idx.verify()
    assert any("dangling parent" in s for s in reports)
    leaf.parent = keep_parent

    # clear() under ANY of the above frees exactly the taken refs:
    victim.page = 0                       # corrupt again, then drop all
    assert idx.clear() == 3
    pool.free(pages)                      # the request's own refs
    assert pool.free_pages == 19 and pool.live_refs() == 0
    with pytest.raises(ValueError):       # and not one ref more
        pool.free([pages[0]])


def test_prefix_index_drop_pages_quarantines_descendants():
    """drop_pages must remove the targeted blocks AND every descendant
    entry (chains stay root-contiguous), while unrelated branches keep
    matching."""
    rng = np.random.default_rng(12)
    pool = PagePool(num_pages=20, page_size=4)
    idx = PrefixIndex(pool)
    a = rng.integers(0, 100, size=16).astype(np.int32)   # 4 blocks
    a_pages = pool.alloc_pages(4)
    idx.insert(a, a_pages)
    b = np.concatenate([a[:4], rng.integers(100, 200, size=8)]).astype(np.int32)
    hits = idx.match(b)
    assert hits == a_pages[:1]
    pool.share(hits)
    b_pages = hits + pool.alloc_pages(2)
    idx.insert(b, b_pages)
    assert len(idx) == 6

    # quarantine a's block 1: blocks 2/3 are its descendants and go too;
    # the shared root (block 0) and b's branch survive
    assert idx.drop_pages([a_pages[1]]) == 3
    assert idx.match(a) == a_pages[:1]
    assert idx.match(b) == b_pages[:2]      # proper-prefix cap: 2 blocks
    assert idx.verify() == []
    # dropping the shared root kills everything
    assert idx.drop_pages([a_pages[0]]) == 3
    assert len(idx) == 0 and idx.verify() == []
    pool.free(a_pages)
    pool.free(b_pages)
    assert pool.free_pages == 19 and pool.live_refs() == 0
