"""Property-based :class:`PagePool` invariants (DESIGN.md §9/§10).

Random alloc/free traces — driven by hypothesis when installed, the
seeded fixed-corpus fallback in ``tests/_hyp.py`` otherwise — must
uphold the allocator's contract at EVERY step of the trace, not just at
quiescence:

* the null page (id 0) is never handed out and never freeable,
* a live (allocated, not yet freed) page is never handed out again,
* ``free_pages + live == num_pages - 1`` — pages are conserved,
* ``can_alloc`` tells the truth: an alloc it approves succeeds, one it
  rejects raises without changing the pool.
"""
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.serving import NULL_PAGE, PagePool


@settings(max_examples=50, deadline=None)
@given(
    st.integers(0, 2**31 - 1),
    st.integers(2, 17),
    st.integers(1, 9),
)
def test_pool_random_trace_invariants(seed, num_pages, page_size):
    rng = np.random.default_rng(seed)
    pool = PagePool(num_pages=num_pages, page_size=page_size)
    usable = num_pages - 1
    live = {}                       # alloc seq no -> page list
    n_allocs = 0
    for _ in range(40):
        do_alloc = bool(rng.integers(2)) or not live
        if do_alloc:
            tokens = int(rng.integers(1, 3 * page_size + 1))
            need = pool.pages_for(tokens)
            assert need == max(1, -(-tokens // page_size))
            if pool.can_alloc(tokens):
                got = pool.alloc(tokens)
                assert len(got) == need
                assert NULL_PAGE not in got
                assert all(0 < p < num_pages for p in got)
                # no page may be live twice
                flat = [p for ps in live.values() for p in ps]
                assert set(got).isdisjoint(flat)
                assert len(set(got)) == len(got)
                live[n_allocs] = got
                n_allocs += 1
            else:
                before = pool.free_pages
                with pytest.raises(RuntimeError):
                    pool.alloc(tokens)
                assert pool.free_pages == before   # failed alloc is a no-op
        else:
            key = list(live)[int(rng.integers(len(live)))]
            pool.free(live.pop(key))
        n_live = sum(len(ps) for ps in live.values())
        assert pool.free_pages + n_live == usable   # conservation
        assert pool.used_pages == n_live

    for pages in live.values():                     # drain: all pages return
        pool.free(pages)
    assert pool.free_pages == usable and pool.used_pages == 0


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 9))
def test_pool_rejects_double_and_null_frees(seed, num_pages):
    rng = np.random.default_rng(seed)
    pool = PagePool(num_pages=num_pages, page_size=4)
    got = pool.alloc(int(rng.integers(1, 4 * (num_pages - 1) + 1))) \
        if pool.can_alloc(1) else []
    with pytest.raises(ValueError):
        pool.free([NULL_PAGE])
    if got:
        pool.free(got)
        with pytest.raises(ValueError):
            pool.free([got[0]])                     # double free
        with pytest.raises(ValueError):
            pool.free([num_pages + 7])              # out of range
