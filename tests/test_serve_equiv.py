"""Decode-path equivalence: incremental decoding must reproduce the
teacher-forced forward logits (validates rope positions, cache mechanics,
GQA grouping, SWA windows, SSM recurrences, xLSTM state updates)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, make_smoke
from repro.models import (
    init_caches,
    init_params,
    lm_decode,
    lm_forward,
    lm_prefill,
)
from repro.models.transformer import encode_kv_caches, encoder_forward
from repro.models.attention import chunked_causal_attention, full_attention
from repro.models.mamba import mamba_apply, mamba_decode, mamba_init, init_mamba_cache
from repro.models.xlstm import (
    init_mlstm_cache, init_slstm_cache,
    mlstm_apply, mlstm_decode, slstm_apply, slstm_decode, mlstm_init, slstm_init,
)

ARCHS_EQ = ["qwen1.5-0.5b", "mixtral-8x7b", "jamba-v0.1-52b", "xlstm-350m",
            "granite-moe-1b-a400m"]


@pytest.mark.parametrize("arch", ARCHS_EQ)
def test_prefill_vs_incremental(arch):
    cfg = make_smoke(get_config(arch))
    if cfg.moe_experts:
        # token-choice capacity routing differs batched-vs-single-token by
        # design (capacity drops); compare with generous capacity instead
        cfg = cfg.replace(capacity_factor=8.0)
    params = init_params(jax.random.PRNGKey(1), cfg)
    b, s = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab)

    full_logits, _ = lm_forward(params, {"tokens": tokens}, cfg)

    caches = init_caches(cfg, b, s + 1, jnp.float32)
    inc = []
    for t in range(s):
        logits, caches = lm_decode(
            params, caches, {"tokens": tokens[:, t:t + 1]},
            jnp.asarray(t, jnp.int32), cfg)
        inc.append(logits[:, 0])
    inc = jnp.stack(inc, axis=1)

    np.testing.assert_allclose(
        np.asarray(inc, np.float32), np.asarray(full_logits, np.float32),
        atol=2e-2, rtol=2e-2,
    )


def test_whisper_decode_and_prefill_match_forward():
    """Encoder-decoder: the serve paths (per-token decode AND batched
    lm_prefill) reproduce lm_forward — pins use_rope=False handling and
    the cross-attention raw-residual dataflow."""
    cfg = make_smoke(get_config("whisper-tiny"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 9
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    frames = jax.random.normal(jax.random.PRNGKey(2),
                               (b, cfg.enc_frames, cfg.d_model))
    full_logits, _ = lm_forward(params, {"tokens": tokens, "frames": frames}, cfg)
    enc = encoder_forward(params, frames, cfg)

    caches = init_caches(cfg, b, s, jnp.float32)
    caches = encode_kv_caches(params, enc, cfg, caches)
    inc = []
    for t in range(s):
        logits, caches = lm_decode(params, caches, {"tokens": tokens[:, t:t + 1]},
                                   jnp.asarray(t, jnp.int32), cfg)
        inc.append(logits[:, 0])
    np.testing.assert_allclose(
        np.asarray(jnp.stack(inc, axis=1), np.float32),
        np.asarray(full_logits, np.float32), atol=2e-2, rtol=2e-2)

    caches_p = init_caches(cfg, b, s, jnp.float32)
    caches_p = encode_kv_caches(params, enc, cfg, caches_p)
    pf, _ = lm_prefill(params, caches_p, {"tokens": tokens}, cfg)
    np.testing.assert_allclose(
        np.asarray(pf, np.float32), np.asarray(full_logits, np.float32),
        atol=2e-2, rtol=2e-2)


def test_chunked_attention_matches_full():
    rng = jax.random.PRNGKey(0)
    b, s, h, kv, dh = 2, 64, 8, 4, 16
    q = jax.random.normal(rng, (b, s, h, dh))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (b, s, kv, dh))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (b, s, kv, dh))
    for window in [None, 16]:
        got = chunked_causal_attention(q, k, v, chunk=16, window=window)
        want = full_attention(q, k, v, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_mamba_chunked_equals_sequential_decode():
    d = 32
    p = mamba_init(jax.random.PRNGKey(0), d, d_state=8)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 20, d))
    y_full = mamba_apply(p, x, chunk=5)
    y_full2 = mamba_apply(p, x, chunk=20)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_full2), atol=1e-4)

    cache = init_mamba_cache(2, 2 * d, 8, 4, jnp.float32)
    ys = []
    for t in range(20):
        y, cache = mamba_decode(p, x[:, t:t + 1], cache)
        ys.append(y[:, 0])
    y_inc = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_inc), np.asarray(y_full), atol=1e-3)


def test_mlstm_chunked_equals_decode():
    d, h = 32, 4
    p = mlstm_init(jax.random.PRNGKey(0), d, h)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d))
    y_full = mlstm_apply(p, x, num_heads=h, chunk=4)
    y_full2 = mlstm_apply(p, x, num_heads=h, chunk=16)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_full2),
                               atol=1e-4, rtol=1e-3)
    d_in = 2 * d
    cache = init_mlstm_cache(2, h, d_in // h)
    ys = []
    for t in range(16):
        y, cache = mlstm_decode(p, x[:, t:t + 1], cache, num_heads=h)
        ys.append(y[:, 0])
    y_inc = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_inc), np.asarray(y_full),
                               atol=1e-3, rtol=1e-2)


def test_slstm_scan_equals_decode():
    d, h = 32, 4
    p = slstm_init(jax.random.PRNGKey(0), d, h)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, d))
    y_full = slstm_apply(p, x, num_heads=h)
    cache = init_slstm_cache(2, d)
    ys = []
    for t in range(10):
        y, cache = slstm_decode(p, x[:, t:t + 1], cache, num_heads=h)
        ys.append(y[:, 0])
    y_inc = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_inc), np.asarray(y_full), atol=1e-4)


def test_ragged_cache_len_vector_matches_straight_through():
    """Per-row (B,) cache_len: ragged prompts batched together decode the
    same tokens each row would decode straight through on its own — the
    scalar-start_len bug made short rows attend over garbage KV slots."""
    from repro.models import lm_generate

    cfg = make_smoke(get_config("qwen1.5-0.5b"), n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    lens, gen = [3, 7, 5], 6
    max_len = max(lens) + gen
    prompts = [
        jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(1), i),
                           (1, l), 0, cfg.vocab)
        for i, l in enumerate(lens)
    ]

    # per-row straight-through reference: each sequence alone (b=1)
    want, firsts, row_caches = [], [], []
    for p, l in zip(prompts, lens):
        caches = init_caches(cfg, 1, max_len, jnp.float32)
        logits, caches = lm_prefill(params, caches, {"tokens": p}, cfg)
        first = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        toks, _ = lm_generate(params, caches, first,
                              jnp.asarray(l, jnp.int32), gen, cfg)
        want.append(np.asarray(toks)[0])
        firsts.append(first)
        row_caches.append(caches)

    # one ragged batch: per-row prefilled caches stacked, (B,) lengths
    batched = jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, axis=0), *row_caches)
    got, _ = lm_generate(params, batched, jnp.concatenate(firsts, axis=0),
                         jnp.asarray(lens, jnp.int32), gen, cfg)
    np.testing.assert_array_equal(np.asarray(got), np.stack(want))


def test_swa_ring_buffer_decode():
    """SWA cache smaller than the sequence: ring writes stay correct."""
    cfg = make_smoke(get_config("mixtral-8x7b"), window=8, capacity_factor=8.0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s = 1, 20
    tokens = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab)
    full_logits, _ = lm_forward(params, {"tokens": tokens}, cfg)
    caches = init_caches(cfg, b, cfg.window, jnp.float32)  # ring = window
    logits = None
    for t in range(s):
        logits, caches = lm_decode(
            params, caches, {"tokens": tokens[:, t:t + 1]},
            jnp.asarray(t, jnp.int32), cfg)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0], np.float32),
        np.asarray(full_logits[:, -1], np.float32),
        atol=3e-2, rtol=3e-2,
    )
