"""Optimizer, checkpoint, data-pipeline, trainer fault-tolerance tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.data import LMPipeline, TokenTask
from repro.optim import (
    AdamWConfig,
    adamw_update,
    clip_by_global_norm,
    constant_lr,
    init_opt_state,
    warmup_cosine,
)
from repro.train import Trainer, TrainerConfig


def test_adamw_masked_updates_keep_zeros():
    params = {"w": jnp.ones((4, 4))}
    masks = {"w": jnp.asarray(np.tril(np.ones((4, 4), np.float32)))}
    cfg = AdamWConfig(use_master=True, weight_decay=0.1)
    opt = init_opt_state(params, cfg)
    params = {"w": params["w"] * masks["w"]}
    for _ in range(5):
        grads = {"w": jnp.ones((4, 4))}
        params, opt = adamw_update(params, grads, opt, cfg, jnp.asarray(0.1), masks=masks)
    w = np.asarray(params["w"])
    assert np.all(w[np.triu_indices(4, 1)] == 0), "pruned weights drifted"
    assert np.all(w[np.tril_indices(4)] != 1.0), "unpruned weights must move"


def test_grad_clip():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    flat = np.asarray(clipped["a"])
    assert np.linalg.norm(flat) <= 1.0 + 1e-5


def test_warmup_cosine_shape():
    sched = warmup_cosine(1.0, 10, 100)
    assert float(sched(jnp.asarray(0))) == 0.0
    assert float(sched(jnp.asarray(10))) == pytest.approx(1.0, abs=0.1)
    assert float(sched(jnp.asarray(100))) == pytest.approx(0.1, abs=0.05)


def test_checkpoint_atomicity_and_gc():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        state = {"w": jnp.arange(6.0), "n": {"m": jnp.zeros((2, 2))}}
        for s in (1, 5, 9):
            ck.save(s, state)
        assert ck.committed_steps() == [5, 9]
        # a stale tmp dir must not be treated as a checkpoint
        os.makedirs(os.path.join(d, "step_0000000011.tmp"))
        assert ck.latest_step() == 9
        out = ck.restore(target=state)
        np.testing.assert_allclose(out["w"], state["w"])


def test_checkpoint_elastic_restore_shapes():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        state = {"w": jnp.arange(8.0).reshape(2, 4)}
        ck.save(3, state)
        out = ck.restore(target=state, shardings={"w": None})
        assert out["w"].shape == (2, 4)


def test_pipeline_determinism_and_prefetch():
    task = TokenTask(vocab=97)
    pipe = LMPipeline(task, batch=4, seq=32, prefetch=2)
    a = pipe.batch_at(7)
    b = pipe.batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    seen = list(pipe.run(0, 3))
    assert len(seen) == 3
    np.testing.assert_array_equal(np.asarray(seen[1]["tokens"]),
                                  np.asarray(pipe.batch_at(1)["tokens"]))


def _tiny_step():
    def step(state, batch):
        loss = jnp.mean((state["w"] - batch["x"]) ** 2)
        state = {"w": state["w"] - 0.1 * (state["w"] - jnp.mean(batch["x"])),
                 "step": state["step"] + 1}
        return state, {"total_loss": loss}
    return step


def test_trainer_resume_after_interrupt():
    with tempfile.TemporaryDirectory() as d:
        import dataclasses as _dc

        cfg5 = TrainerConfig(total_steps=5, ckpt_every=5, ckpt_dir=d, log_every=1)
        batch_fn = lambda s: {"x": jnp.full((4,), float(s))}
        state = {"w": jnp.zeros(()), "step": jnp.asarray(0)}

        t1 = Trainer(_tiny_step(), state, batch_fn, cfg5)  # dies at step 5
        r1 = t1.run()
        assert r1["final_step"] == 5

        cfg10 = _dc.replace(cfg5, total_steps=10)
        t2 = Trainer(_tiny_step(), state, batch_fn, cfg10)
        start = t2.resume_if_available()
        assert start == 5, "must resume from the committed checkpoint"
        r2 = t2.run()
        assert r2["final_step"] == 10


def test_trainer_straggler_detection():
    import time

    with tempfile.TemporaryDirectory() as d:
        cfg = TrainerConfig(total_steps=8, ckpt_every=0, ckpt_dir=d,
                            log_every=0, straggler_factor=3.0, ewma_alpha=0.5)
        slow = {5}

        def batch_fn(s):
            if s in slow:
                time.sleep(0.25)
            return {"x": jnp.ones((2,))}

        state = {"w": jnp.zeros(()), "step": jnp.asarray(0)}
        t = Trainer(_tiny_step(), state, batch_fn, cfg)
        r = t.run()
        assert any(e["step"] == 5 for e in r["stragglers"]), r["stragglers"]


def test_compression_error_feedback_converges():
    """Accumulated int8 psum with error feedback is unbiased over steps."""
    import os
    from repro.distributed.sharding import make_mesh, shard_map, use_mesh
    from repro.optim.compression import compressed_psum

    # single-device: emulate via shard_map on a 1-axis mesh of size 1
    # (make_mesh/use_mesh/shard_map gate the post-0.4.x jax APIs)
    mesh = make_mesh((1,), ("pod",))
    from jax.sharding import PartitionSpec as P

    g = jnp.asarray(np.random.default_rng(0).normal(size=(64,)).astype(np.float32))
    err = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    with use_mesh(mesh):
        fn = shard_map(
            lambda a, b: compressed_psum(a, b, "pod"),
            mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()), check=False)
        for _ in range(50):
            out, err = fn(g, err)
            total = total + out
    # mean of 50 compressed reductions ~= g (error feedback cancels bias)
    np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g), atol=1e-3)
