"""Fused paged-attention kernels (kernels/paged_attention.py, DESIGN.md §11).

Three-way differential coverage: the Pallas kernel body (interpret mode,
decode M=1 and prefill bm-tiled grids) against the non-gathering ref,
the ref against a dense gather oracle, and the ``attention_decode`` /
``attention_prefill`` fused dispatch against the legacy gather path —
across ragged ``(B,)`` cache_len (including empty rows parked on the
null page), GQA ratios, and page sizes 4/8/16.

The ref mirrors the kernel's op sequence exactly (same seed, same
per-page update order), so kernel-vs-ref agreement is at float32
rounding (1–2 ulp from einsum batching), not accumulated drift.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import paged_attention_decode, paged_attention_prefill
from repro.kernels.paged_attention import (
    paged_attention_decode_pallas,
    paged_attention_decode_ref,
    paged_attention_prefill_pallas,
    paged_attention_prefill_ref,
)
from repro.models.attention import attention_decode, attention_init, full_attention

ATOL = 2e-6


def _mk_decode(rng, b, h, kvh, dh, ps, max_pages, clens, poison=False):
    """Random decode case: shuffled non-null page ids per live row; rows
    with cache_len 0 park their whole table on the null page.  With
    ``poison`` every slot not owned by a live row is NaN."""
    n_pages = b * max_pages + 1
    q = jnp.asarray(rng.normal(size=(b, h, dh)), jnp.float32)
    kn = jnp.asarray(rng.normal(size=(b, kvh, dh)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(b, kvh, dh)), jnp.float32)
    clens = np.asarray(clens)
    ids = rng.permutation(np.arange(1, n_pages))[: b * max_pages]
    tbl = np.where(clens[:, None] == 0, 0, ids.reshape(b, max_pages))
    if poison:
        kp = np.full((n_pages, ps, kvh, dh), np.nan, np.float32)
        vp = np.full((n_pages, ps, kvh, dh), np.nan, np.float32)
        for r in range(b):                    # only live positions are real
            for t in range(int(clens[r])):
                kp[tbl[r, t // ps], t % ps] = rng.normal(size=(kvh, dh))
                vp[tbl[r, t // ps], t % ps] = rng.normal(size=(kvh, dh))
        kp, vp = jnp.asarray(kp), jnp.asarray(vp)
    else:
        kp = jnp.asarray(rng.normal(size=(n_pages, ps, kvh, dh)), jnp.float32)
        vp = jnp.asarray(rng.normal(size=(n_pages, ps, kvh, dh)), jnp.float32)
    return (q, kn, vn, kp, vp, jnp.asarray(tbl, jnp.int32),
            jnp.asarray(clens, jnp.int32))


def _decode_oracle(q, kn, vn, kp, vp, tbl, clen):
    """Dense gather + monolithic softmax, the new token appended at its
    row's cache_len — the legacy view the kernel must reproduce."""
    b, h, dh = q.shape
    kvh = kn.shape[1]
    g = h // kvh
    ps = kp.shape[1]
    s_max = tbl.shape[1] * ps
    ck = np.array(kp[tbl].reshape(b, s_max, kvh, dh))
    cv = np.array(vp[tbl].reshape(b, s_max, kvh, dh))
    for r in range(b):
        c = int(clen[r])
        ck[r, c] = np.asarray(kn[r])
        cv[r, c] = np.asarray(vn[r])
    qg = np.asarray(q).reshape(b, kvh, g, dh)
    s = np.einsum("bkgd,bskd->bkgs", qg, ck) / np.sqrt(dh)
    valid = np.arange(s_max)[None] <= np.asarray(clen)[:, None]
    s = np.where(valid[:, None, None], s, -1e30)
    w = np.exp(s - s.max(-1, keepdims=True))
    w /= w.sum(-1, keepdims=True)
    cv = np.where(valid[:, :, None, None], cv, 0)
    return np.einsum("bkgs,bskd->bkgd", w, cv).reshape(b, h, dh)


@pytest.mark.parametrize("ps", [4, 8, 16])
@pytest.mark.parametrize("h,kvh", [(4, 4), (8, 2), (4, 1)])
def test_paged_decode_kernel_interpret_matches_ref(ps, h, kvh):
    """Decode-grid (M=1) kernel body under the interpreter vs the
    page-per-step ref: same op order, float-rounding agreement, across
    ragged cache_len including an empty row on the null page."""
    rng = np.random.default_rng(ps * 10 + h)
    b, dh, mp = 4, 32, 5
    clens = [0, 1, ps * mp - 1, int(rng.integers(1, ps * mp - 1))]
    args = _mk_decode(rng, b, h, kvh, dh, ps, mp, clens)
    ref = paged_attention_decode_ref(*args, pages_per_step=1)
    ker = paged_attention_decode_pallas(*args, interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref), atol=ATOL)
    orc = _decode_oracle(*args)
    np.testing.assert_allclose(np.asarray(ref), orc, atol=1e-5)


def test_paged_decode_ref_segment_width_invariant():
    """The ref's pages_per_step is a CPU throughput knob, not semantics:
    any width agrees with the per-page walk to float rounding."""
    rng = np.random.default_rng(3)
    args = _mk_decode(rng, 3, 8, 4, 64, 8, 6, [0, 17, 47])
    base = paged_attention_decode_ref(*args, pages_per_step=1)
    for pps in (2, 4, 8):
        got = paged_attention_decode_ref(*args, pages_per_step=pps)
        np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                                   atol=ATOL)


def test_paged_decode_never_reads_unallocated_pages():
    """NaN-poison every slot outside the live prefix of each row's own
    pages (including the whole null page): outputs must be finite and
    bit-identical to the clean-pool run on ref AND interpret kernel."""
    rng = np.random.default_rng(5)
    b, h, kvh, dh, ps, mp = 3, 8, 4, 32, 4, 4
    clens = [0, 5, 13]
    dirty = _mk_decode(np.random.default_rng(5), b, h, kvh, dh, ps, mp,
                       clens, poison=True)
    # clean pool: identical live data, zeros elsewhere
    clean = tuple(jnp.nan_to_num(a, nan=0.0) if a.ndim == 4 else a
                  for a in dirty)
    for fn in (lambda *a: paged_attention_decode_ref(*a, pages_per_step=2),
               lambda *a: paged_attention_decode_pallas(*a, interpret=True)):
        got = np.asarray(fn(*dirty))
        assert np.isfinite(got).all()
        np.testing.assert_array_equal(got, np.asarray(fn(*clean)))


def test_paged_decode_ops_mode_dispatch():
    rng = np.random.default_rng(7)
    args = _mk_decode(rng, 2, 4, 2, 16, 8, 3, [0, 11])
    ref = paged_attention_decode(*args, mode="ref")
    itp = paged_attention_decode(*args, mode="interpret")
    auto = paged_attention_decode(*args, mode="auto")   # CPU host -> ref
    np.testing.assert_allclose(np.asarray(itp), np.asarray(ref), atol=ATOL)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(ref))
    with pytest.raises(ValueError, match="unknown kernel mode"):
        paged_attention_decode(*args, mode="bogus")


def test_attention_decode_fused_matches_gather_impl():
    """The dispatch-level contract: attention_decode with the fused page
    walk == the legacy gather view, per row, over ragged cache_len —
    including the cache writes (shared between impls)."""
    rng = np.random.default_rng(11)
    b, ps, mp, kvh, h, dh, d = 3, 4, 4, 2, 4, 16, 64
    key = jax.random.PRNGKey(0)
    p = attention_init(key, d, h, kvh, dh)
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, 1, d))
    n_pages = b * mp + 1
    pool = {
        "k": jnp.asarray(rng.normal(size=(n_pages, ps, kvh, dh)), jnp.float32),
        "v": jnp.asarray(rng.normal(size=(n_pages, ps, kvh, dh)), jnp.float32),
    }
    tables = jnp.asarray(
        rng.permutation(np.arange(1, n_pages))[: b * mp].reshape(b, mp),
        jnp.int32)
    clen = jnp.asarray([0, 7, 14], jnp.int32)
    out_f, cf = attention_decode(p, x, dict(pool), clen, num_heads=h,
                                 kv_heads=kvh, head_dim=dh,
                                 page_table=tables, paged_impl="fused")
    out_g, cg = attention_decode(p, x, dict(pool), clen, num_heads=h,
                                 kv_heads=kvh, head_dim=dh,
                                 page_table=tables, paged_impl="gather")
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_g),
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(cf["k"]), np.asarray(cg["k"]))
    np.testing.assert_array_equal(np.asarray(cf["v"]), np.asarray(cg["v"]))


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def _mk_prefill(rng, b, s, h, kvh, dh, ps):
    """Prompt K/V scattered into shuffled pages; everything the scatter
    didn't touch stays NaN, so any stray read is loud."""
    mp = -(-s // ps)
    n_pages = b * mp + 1
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kvh, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kvh, dh)), jnp.float32)
    tbl = jnp.asarray(
        rng.permutation(np.arange(1, n_pages)).reshape(b, mp), jnp.int32)
    kp = jnp.full((n_pages, ps, kvh, dh), jnp.nan, jnp.float32)
    vp = jnp.full((n_pages, ps, kvh, dh), jnp.nan, jnp.float32)
    t = jnp.arange(s)
    pid = tbl[:, t // ps]
    off = jnp.broadcast_to(t % ps, (b, s))
    kp = kp.at[pid, off].set(k)
    vp = vp.at[pid, off].set(v)
    return q, k, v, kp, vp, tbl


@pytest.mark.parametrize("ps", [4, 8, 16])
@pytest.mark.parametrize("h,kvh,bm", [(4, 4, 32), (8, 2, 64), (4, 1, 16)])
def test_paged_prefill_kernel_interpret_matches_ref_m64(ps, h, kvh, bm):
    """Prefill-grid kernel (bm-tiled query blocks, M=64) vs ref vs the
    unchunked causal oracle; the NaN pool padding proves the page walk
    stays inside the prompt's own pages."""
    rng = np.random.default_rng(ps + h + bm)
    b, s, dh = 2, 64, 32
    q, k, v, kp, vp, tbl = _mk_prefill(rng, b, s, h, kvh, dh, ps)
    lengths = jnp.full((b,), s, jnp.int32)
    ref = paged_attention_prefill_ref(q, kp, vp, tbl, lengths,
                                      pages_per_step=1)
    ker = paged_attention_prefill_pallas(q, kp, vp, tbl, lengths, bm=bm,
                                         interpret=True)
    assert np.isfinite(np.asarray(ker)).all()
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref), atol=ATOL)
    orc = full_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(orc), atol=1e-5)


def test_paged_prefill_ragged_lengths_and_odd_sizes():
    """Per-row lengths: rows at/past their length produce zeros, live
    rows match the oracle restricted to their prefix; S not divisible by
    bm or page_size exercises the padded tail tiles."""
    rng = np.random.default_rng(17)
    b, s, h, kvh, dh, ps = 3, 50, 4, 2, 16, 8
    q, k, v, kp, vp, tbl = _mk_prefill(rng, b, s, h, kvh, dh, ps)
    lengths = jnp.asarray([0, 23, 50], jnp.int32)
    ref = paged_attention_prefill_ref(q, kp, vp, tbl, lengths,
                                      pages_per_step=2)
    ker = paged_attention_prefill_pallas(q, kp, vp, tbl, lengths, bm=16,
                                         interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref), atol=ATOL)
    orc = np.asarray(full_attention(q, k, v, causal=True))
    got = np.asarray(ref)
    for r, ln in enumerate([0, 23, 50]):
        np.testing.assert_allclose(got[r, :ln], orc[r, :ln], atol=1e-5)
        np.testing.assert_array_equal(got[r, ln:],
                                      np.zeros_like(got[r, ln:]))


def test_paged_prefill_ops_mode_dispatch():
    rng = np.random.default_rng(19)
    q, k, v, kp, vp, tbl = _mk_prefill(rng, 2, 32, 4, 2, 8, 8)
    lengths = jnp.full((2,), 32, jnp.int32)
    ref = paged_attention_prefill(q, kp, vp, tbl, lengths, mode="ref")
    itp = paged_attention_prefill(q, kp, vp, tbl, lengths, mode="interpret",
                                  bm=16)
    np.testing.assert_allclose(np.asarray(itp), np.asarray(ref), atol=ATOL)


@pytest.mark.parametrize("ps,start", [(4, 4), (8, 24), (16, 16)])
def test_paged_prefill_q_offset_tail_matches_full(ps, start):
    """Tail-only prefill (DESIGN.md §12 prefix caching): queries for
    positions [start, s) against pages holding the FULL prompt's K/V
    must reproduce rows [start:] of the full-prompt prefill — the walk
    covers the cached-prefix pages the tail queries attend over, the
    causal mask uses absolute positions, and the NaN padding past the
    prompt stays unread."""
    rng = np.random.default_rng(23 + ps)
    b, s, h, kvh, dh = 2, 48, 4, 2, 16
    q, k, v, kp, vp, tbl = _mk_prefill(rng, b, s, h, kvh, dh, ps)
    lengths = jnp.full((b,), s, jnp.int32)   # total lengths incl. prefix
    full = paged_attention_prefill_ref(q, kp, vp, tbl, lengths,
                                       pages_per_step=2)
    tail = paged_attention_prefill_ref(q[:, start:], kp, vp, tbl, lengths,
                                       pages_per_step=2, q_offset=start)
    np.testing.assert_allclose(np.asarray(tail),
                               np.asarray(full)[:, start:], atol=ATOL)
    ker = paged_attention_prefill_pallas(q[:, start:], kp, vp, tbl, lengths,
                                         bm=16, interpret=True,
                                         q_offset=start)
    assert np.isfinite(np.asarray(ker)).all()
    np.testing.assert_allclose(np.asarray(ker), np.asarray(tail), atol=ATOL)
    orc = full_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(tail),
                               np.asarray(orc)[:, start:], atol=1e-5)


def test_paged_prefill_q_offset_ragged_and_ops_dispatch():
    """q_offset composed with per-row lengths: a row whose total length
    ends mid-tail zeroes its out-of-range rows, and the ops-layer
    dispatch threads q_offset to both impls."""
    rng = np.random.default_rng(29)
    b, s, h, kvh, dh, ps, start = 2, 40, 4, 2, 16, 8, 16
    q, k, v, kp, vp, tbl = _mk_prefill(rng, b, s, h, kvh, dh, ps)
    lengths = jnp.asarray([40, 25], jnp.int32)
    full = paged_attention_prefill_ref(q, kp, vp, tbl, lengths,
                                       pages_per_step=1)
    ref = paged_attention_prefill(q[:, start:], kp, vp, tbl, lengths,
                                  mode="ref", q_offset=start)
    itp = paged_attention_prefill(q[:, start:], kp, vp, tbl, lengths,
                                  mode="interpret", bm=16, q_offset=start)
    got = np.asarray(ref)
    np.testing.assert_allclose(got, np.asarray(full)[:, start:], atol=ATOL)
    np.testing.assert_allclose(np.asarray(itp), got, atol=ATOL)
    assert np.isfinite(got).all()
    np.testing.assert_array_equal(got[1, 25 - start:],
                                  np.zeros_like(got[1, 25 - start:]))
