"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BlockingSpec, pack_bsr
from repro.core.packing import BSRPlanes
from repro.kernels import (
    Epilogue,
    apply_epilogue,
    bsr_matmul,
    bsr_planes_matmul,
    structure_norms,
)
from repro.kernels import ref
from repro.kernels.block_sparse_matmul import (
    bsr_matmul_pallas,
    bsr_planes_matmul_pallas,
)
from repro.kernels.structure_norms import structure_norms_pallas

SHAPES = [
    # (m, k, n, bk, bn, bm, density)
    (64, 256, 128, 128, 128, 64, 0.5),
    (128, 512, 256, 128, 128, 128, 0.25),
    (32, 128, 384, 64, 128, 32, 1.0),
    (8, 130, 50, 32, 32, 8, 0.6),       # ragged tails
    (16, 64, 64, 64, 64, 16, 0.0),      # fully pruned
    (256, 384, 512, 128, 256, 128, 0.4),
    (1, 512, 256, 128, 128, 1, 0.25),   # decode-shaped single row
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _make_bsr(rng, k, n, bk, bn, density, dtype):
    w = rng.normal(size=(k, n)).astype(np.float32)
    ebk, ebn = min(bk, k), min(bn, n)
    gk, gn = -(-k // ebk), -(-n // ebn)
    alive = rng.uniform(size=(gk, gn)) < density
    mask = np.repeat(np.repeat(alive, ebk, 0), ebn, 1)[:k, :n].astype(np.float32)
    return pack_bsr(w.astype(dtype), BlockingSpec(bk=bk, bn=bn), mask=mask), w, mask


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_bsr_matmul_matches_oracle(shape, dtype):
    m, k, n, bk, bn, bm, density = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    bsr, w, mask = _make_bsr(rng, k, n, bk, bn, density, dtype)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)).astype(dtype)
    got = bsr_matmul_pallas(x, bsr, bm=bm, interpret=True)
    want = ref.bsr_matmul_ref(x, bsr)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


@pytest.mark.parametrize("shape", SHAPES)
def test_bsr_matmul_skips_pruned_blocks(shape):
    """Semantics: pruned tiles contribute exactly zero."""
    m, k, n, bk, bn, bm, density = shape
    rng = np.random.default_rng(0)
    bsr, w, mask = _make_bsr(rng, k, n, bk, bn, density, jnp.float32)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    got = bsr_matmul_pallas(x, bsr, bm=bm, interpret=True)
    dense = jnp.asarray(w * mask)
    want = x @ dense
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


def test_flat_store_scales_with_true_nnz():
    """The flat store holds exactly the live tiles — no per-column padding
    copy of the weights (the prefill-shaped work contract, DESIGN.md §8)."""
    rng = np.random.default_rng(2)
    bsr, w, mask = _make_bsr(rng, 512, 2048, 128, 128, 0.25, jnp.float32)
    assert bsr.blocks.shape[0] == bsr.nnz_blocks
    assert bsr.blocks.shape[0] < bsr.grid_n * bsr.max_nnz
    # the per-column map and the flat store agree tile-for-tile
    idx = np.asarray(bsr.indices)
    slots = np.asarray(bsr.slots)
    for j in range(bsr.grid_n):
        for s in range(bsr.max_nnz):
            if idx[j, s] < 0:
                continue
            z = slots[j, s]
            assert np.asarray(bsr.flat_rows)[z] == idx[j, s]
            assert np.asarray(bsr.flat_cols)[z] == j


@pytest.mark.parametrize("kshape", [(64, 64), (128, 384), (100, 36), (8, 1024)])
@pytest.mark.parametrize("blocks", [(32, 32), (64, 128), (8, 128)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_structure_norms_sweep(kshape, blocks, dtype):
    k, n = kshape
    bk, bn = blocks
    rng = np.random.default_rng(k * n)
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32)).astype(dtype)
    got = structure_norms_pallas(w, bk=bk, bn=bn, interpret=True)
    want = ref.structure_norms_ref(w, bk, bn)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=1e-2, rtol=1e-2
    )


def _make_planes(rng, e, k, n, bk, bn, densities, dtype=jnp.float32):
    """Fused BSRPlanes + the masked dense (E, K, N) stack it represents."""
    planes, dense = [], []
    for d in densities:
        bsr, w, mask = _make_bsr(rng, k, n, bk, bn, d, dtype)
        planes.append(bsr)
        dense.append(w * mask)
    fused = BSRPlanes.from_planes(tuple(planes), shape=(e, k, n))
    return fused, np.stack(dense)


@pytest.mark.parametrize("dtype", DTYPES)
def test_bsr_planes_matmul_matches_oracle(dtype):
    """Fused plane kernel (interpret) vs the flat-store ref vs dense —
    mixed per-plane densities including a fully-pruned plane."""
    rng = np.random.default_rng(3)
    e, m, k, n, bk, bn = 3, 16, 128, 96, 32, 32
    fused, dense = _make_planes(rng, e, k, n, bk, bn, [0.6, 0.0, 1.0], dtype)
    x = jnp.asarray(rng.normal(size=(e, m, k)).astype(np.float32)).astype(dtype)
    got_pl = bsr_planes_matmul_pallas(x, fused, bm=16, interpret=True)
    got_ref = ref.bsr_planes_matmul_ref(x, fused)
    want = jnp.einsum("emk,ekn->emn", x.astype(jnp.float32),
                      jnp.asarray(dense))
    tol = 1e-3 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got_ref, np.float32),
                               np.asarray(want), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(got_pl, np.float32),
                               np.asarray(want), atol=tol, rtol=tol)


def test_planes_flat_cols_stay_sorted_through_padding():
    """BSRPlanes padding must keep every plane's flat_cols monotonic —
    the ref's segment-sum declares indices_are_sorted=True (unequal
    per-plane live counts force padding on the sparser planes)."""
    rng = np.random.default_rng(17)
    fused, _ = _make_planes(rng, 3, 128, 96, 32, 32, [0.3, 1.0, 0.0])
    fc = np.asarray(fused.flat_cols)
    assert (np.diff(fc, axis=1) >= 0).all()


def test_bsr_refs_never_densify():
    """The zero-skipping contract of the CPU serving path: the ref
    kernels must not reconstruct the dense weight."""
    import inspect

    src = inspect.getsource(ref)
    assert "bsr_to_dense" not in src


def test_ops_mode_interpret_exercises_pallas():
    """mode='interpret' must run the Pallas kernel body (not the ref
    shortcut) on any backend — this is CI's coverage of the kernels."""
    rng = np.random.default_rng(4)
    bsr, w, mask = _make_bsr(rng, 128, 64, 32, 32, 0.5, jnp.float32)
    x = jnp.asarray(rng.normal(size=(4, 128)).astype(np.float32))
    want = x @ jnp.asarray(w * mask)
    for mode in ("auto", "ref", "interpret"):
        got = bsr_matmul(x, bsr, mode=mode)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-3, err_msg=mode)
    with pytest.raises(ValueError):
        bsr_matmul(x, bsr, mode="bogus")

    nn = structure_norms(jnp.asarray(w), bk=32, bn=32, mode="interpret")
    np.testing.assert_allclose(
        np.asarray(nn), np.asarray(ref.structure_norms_ref(jnp.asarray(w), 32, 32)),
        atol=1e-3)


def test_ops_bsr_planes_wrapper_modes():
    rng = np.random.default_rng(5)
    e, k, n = 2, 64, 64
    fused, dense = _make_planes(rng, e, k, n, 32, 32, [0.5, 0.25])
    x = jnp.asarray(rng.normal(size=(e, 3, 5, k)).astype(np.float32))
    want = jnp.einsum("egck,ekn->egcn", x, jnp.asarray(dense))
    for mode in ("auto", "interpret"):
        got = bsr_planes_matmul(x, fused, mode=mode)
        assert got.shape == (e, 3, 5, n)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-3, err_msg=mode)


def test_ops_wrappers_batched():
    rng = np.random.default_rng(1)
    bsr, w, mask = _make_bsr(rng, 128, 64, 64, 64, 0.5, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 8, 128)).astype(np.float32))
    y = bsr_matmul(x, bsr)                 # auto -> ref on CPU
    assert y.shape == (2, 8, 64)
    want = jnp.einsum("bmk,kn->bmn", x, jnp.asarray(w * mask))
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-3)

    nn = structure_norms(jnp.asarray(w), bk=64, bn=64)
    assert nn.shape == (2, 1)


# ---------------------------------------------------------------------------
# Fused epilogue (DESIGN.md §8): bias / activation / gate / residual
# ---------------------------------------------------------------------------

EPI_SPECS = ["bias", "gelu", "bias+silu+mult", "bias+gelu+mult+res"]


def _build_epilogue(rng, m, n, spec):
    """(Epilogue, unfused-composition closure) for a named spec."""
    bias = mult = res = act = None
    if "bias" in spec:
        bias = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    for a in ("gelu", "silu"):
        if a in spec:
            act = a
    if "mult" in spec:
        mult = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    if "res" in spec:
        res = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    epi = Epilogue(bias=bias, multiplier=mult, residual=res, activation=act)

    def unfused(y):
        if bias is not None:
            y = y + bias
        if act is not None:
            y = getattr(jax.nn, act)(y)
        if mult is not None:
            y = y * mult
        if res is not None:
            y = y + res
        return y

    return epi, unfused


@pytest.mark.parametrize("m", [1, 64])   # decode- and prefill-shaped grids
@pytest.mark.parametrize("spec", EPI_SPECS)
def test_interpret_grid_epilogue_fused(m, spec):
    """The fused in-kernel epilogue (interpret mode, bm-tiled grid: M=1
    decode-shaped and M=64 prefill-shaped with 2 row tiles) matches the
    unfused composition applied to the plain kernel output."""
    rng = np.random.default_rng(len(spec) + m)
    k, n = 256, 128
    bsr, w, mask = _make_bsr(rng, k, n, 64, 64, 0.5, jnp.float32)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    epi, unfused = _build_epilogue(rng, m, n, spec)
    bm = max(m // 2, 1)                   # force >1 row tile when m > 1
    got = bsr_matmul_pallas(x, bsr, bm=bm, epilogue=epi, interpret=True)
    plain = bsr_matmul_pallas(x, bsr, bm=bm, interpret=True)
    want = unfused(plain.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("m", [1, 64])
@pytest.mark.parametrize("spec", EPI_SPECS)
def test_ref_epilogue_bitmatches_unfused(m, spec):
    """The ref path's fused epilogue is bit-identical to the unfused fp32
    composition — the serving guarantee that fusing changes no numerics."""
    rng = np.random.default_rng(7 + m)
    k, n = 192, 96
    bsr, w, mask = _make_bsr(rng, k, n, 32, 32, 0.4, jnp.float32)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    epi, unfused = _build_epilogue(rng, m, n, spec)
    got = ref.bsr_matmul_ref(x, bsr, epilogue=epi)
    want = unfused(ref.bsr_matmul_ref(x, bsr).astype(jnp.float32))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("mode", ["ref", "interpret"])
def test_planes_epilogue_fused(mode):
    """Fused epilogue through the plane-stack kernel and its ref — the MoE
    expert path's act(gate) * up composition."""
    rng = np.random.default_rng(11)
    e, m, k, n = 3, 8, 128, 64
    fused, dense = _make_planes(rng, e, k, n, 32, 32, [0.5, 0.0, 1.0])
    x = jnp.asarray(rng.normal(size=(e, m, k)).astype(np.float32))
    mult = jnp.asarray(rng.normal(size=(e, m, n)).astype(np.float32))
    epi = Epilogue(multiplier=mult, activation="silu")
    got = bsr_planes_matmul(x, fused, mode=mode, epilogue=epi)
    plain = bsr_planes_matmul(x, fused, mode=mode).astype(jnp.float32)
    want = jax.nn.silu(plain) * mult
    tol = 0 if mode == "ref" else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=tol, rtol=tol)


def test_apply_epilogue_matches_kernel_order():
    """apply_epilogue (the dense-fallback path) and the fused kernels use
    the same op order: act(y + bias) * mult + res."""
    rng = np.random.default_rng(13)
    y = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))
    epi, unfused = _build_epilogue(rng, 4, 8, "bias+gelu+mult+res")
    np.testing.assert_array_equal(
        np.asarray(apply_epilogue(y, epi)), np.asarray(unfused(y)))
    assert apply_epilogue(y, None) is y
