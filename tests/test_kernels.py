"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BlockingSpec, pack_bsr
from repro.kernels import bsr_matmul, bsr_planes_matmul, structure_norms
from repro.kernels import ref
from repro.kernels.block_sparse_matmul import (
    bsr_matmul_pallas,
    bsr_planes_matmul_pallas,
)
from repro.kernels.structure_norms import structure_norms_pallas
from repro.sparse.transform import BSRPlanes

SHAPES = [
    # (m, k, n, bk, bn, bm, density)
    (64, 256, 128, 128, 128, 64, 0.5),
    (128, 512, 256, 128, 128, 128, 0.25),
    (32, 128, 384, 64, 128, 32, 1.0),
    (8, 130, 50, 32, 32, 8, 0.6),       # ragged tails
    (16, 64, 64, 64, 64, 16, 0.0),      # fully pruned
    (256, 384, 512, 128, 256, 128, 0.4),
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _make_bsr(rng, k, n, bk, bn, density, dtype):
    w = rng.normal(size=(k, n)).astype(np.float32)
    ebk, ebn = min(bk, k), min(bn, n)
    gk, gn = -(-k // ebk), -(-n // ebn)
    alive = rng.uniform(size=(gk, gn)) < density
    mask = np.repeat(np.repeat(alive, ebk, 0), ebn, 1)[:k, :n].astype(np.float32)
    return pack_bsr(w.astype(dtype), BlockingSpec(bk=bk, bn=bn), mask=mask), w, mask


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_bsr_matmul_matches_oracle(shape, dtype):
    m, k, n, bk, bn, bm, density = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    bsr, w, mask = _make_bsr(rng, k, n, bk, bn, density, dtype)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)).astype(dtype)
    got = bsr_matmul_pallas(x, bsr.indices, bsr.blocks, n=n, bm=bm, interpret=True)
    want = ref.bsr_matmul_ref(x, bsr)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


@pytest.mark.parametrize("shape", SHAPES)
def test_bsr_matmul_skips_pruned_blocks(shape):
    """Semantics: pruned tiles contribute exactly zero."""
    m, k, n, bk, bn, bm, density = shape
    rng = np.random.default_rng(0)
    bsr, w, mask = _make_bsr(rng, k, n, bk, bn, density, jnp.float32)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    got = bsr_matmul_pallas(x, bsr.indices, bsr.blocks, n=n, bm=bm, interpret=True)
    dense = jnp.asarray(w * mask)
    want = x @ dense
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


@pytest.mark.parametrize("kshape", [(64, 64), (128, 384), (100, 36), (8, 1024)])
@pytest.mark.parametrize("blocks", [(32, 32), (64, 128), (8, 128)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_structure_norms_sweep(kshape, blocks, dtype):
    k, n = kshape
    bk, bn = blocks
    rng = np.random.default_rng(k * n)
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32)).astype(dtype)
    got = structure_norms_pallas(w, bk=bk, bn=bn, interpret=True)
    want = ref.structure_norms_ref(w, bk, bn)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=1e-2, rtol=1e-2
    )


def _make_planes(rng, e, k, n, bk, bn, densities, dtype=jnp.float32):
    """Fused BSRPlanes + the masked dense (E, K, N) stack it represents."""
    planes, dense = [], []
    for d in densities:
        bsr, w, mask = _make_bsr(rng, k, n, bk, bn, d, dtype)
        planes.append(bsr)
        dense.append(w * mask)
    fused = BSRPlanes.from_planes(tuple(planes), shape=(e, k, n))
    return fused, np.stack(dense)


@pytest.mark.parametrize("dtype", DTYPES)
def test_bsr_planes_matmul_matches_oracle(dtype):
    """Fused plane kernel (interpret) vs the segment-wise ref vs dense —
    mixed per-plane densities including a fully-pruned plane."""
    rng = np.random.default_rng(3)
    e, m, k, n, bk, bn = 3, 16, 128, 96, 32, 32
    fused, dense = _make_planes(rng, e, k, n, bk, bn, [0.6, 0.0, 1.0], dtype)
    x = jnp.asarray(rng.normal(size=(e, m, k)).astype(np.float32)).astype(dtype)
    got_pl = bsr_planes_matmul_pallas(
        x, fused.indices, fused.blocks, n=n, bm=16, interpret=True)
    got_ref = ref.bsr_planes_matmul_ref(x, fused.indices, fused.blocks, n=n)
    want = jnp.einsum("emk,ekn->emn", x.astype(jnp.float32),
                      jnp.asarray(dense))
    tol = 1e-3 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got_ref, np.float32),
                               np.asarray(want), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(got_pl, np.float32),
                               np.asarray(want), atol=tol, rtol=tol)


def test_bsr_refs_never_densify():
    """The zero-skipping contract of the CPU serving path: the ref
    kernels must not reconstruct the dense weight."""
    import inspect

    src = inspect.getsource(ref)
    assert "bsr_to_dense" not in src


def test_ops_mode_interpret_exercises_pallas():
    """mode='interpret' must run the Pallas kernel body (not the ref
    shortcut) on any backend — this is CI's coverage of the kernels."""
    rng = np.random.default_rng(4)
    bsr, w, mask = _make_bsr(rng, 128, 64, 32, 32, 0.5, jnp.float32)
    x = jnp.asarray(rng.normal(size=(4, 128)).astype(np.float32))
    want = x @ jnp.asarray(w * mask)
    for mode in ("auto", "ref", "interpret"):
        got = bsr_matmul(x, bsr, mode=mode)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-3, err_msg=mode)
    with pytest.raises(ValueError):
        bsr_matmul(x, bsr, mode="bogus")

    nn = structure_norms(jnp.asarray(w), bk=32, bn=32, mode="interpret")
    np.testing.assert_allclose(
        np.asarray(nn), np.asarray(ref.structure_norms_ref(jnp.asarray(w), 32, 32)),
        atol=1e-3)


def test_ops_bsr_planes_wrapper_modes():
    rng = np.random.default_rng(5)
    e, k, n = 2, 64, 64
    fused, dense = _make_planes(rng, e, k, n, 32, 32, [0.5, 0.25])
    x = jnp.asarray(rng.normal(size=(e, 3, 5, k)).astype(np.float32))
    want = jnp.einsum("egck,ekn->egcn", x, jnp.asarray(dense))
    for mode in ("auto", "interpret"):
        got = bsr_planes_matmul(x, fused.indices, fused.blocks, n=n, mode=mode)
        assert got.shape == (e, 3, 5, n)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-3, err_msg=mode)


def test_ops_wrappers_batched():
    rng = np.random.default_rng(1)
    bsr, w, mask = _make_bsr(rng, 128, 64, 64, 64, 0.5, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 8, 128)).astype(np.float32))
    y = bsr_matmul(x, bsr)                 # auto -> ref on CPU
    assert y.shape == (2, 8, 64)
    want = jnp.einsum("bmk,kn->bmn", x, jnp.asarray(w * mask))
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-3)

    nn = structure_norms(jnp.asarray(w), bk=64, bn=64)
    assert nn.shape == (2, 1)
