"""Sparse execution layer: pack/unpack round-trips and packed-vs-dense
logits equivalence through the full model stack (DESIGN.md §6).

The fp32 ref BSR path reconstructs exactly the masked dense weight, so
forward and decode logits on packed params must match the masked-dense
execution to numerical noise — the end-to-end guarantee that lets the
serving path swap in BSR kernels without touching the model code.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, make_smoke
from repro.core import BlockingSpec, apply_masks, build_structures, masks_from_knapsack
from repro.core.masks import _get_path
from repro.core.packing import BSRWeight
from repro.models import (
    init_caches,
    init_params,
    lm_decode,
    lm_forward,
    lm_generate,
    lm_prefill,
)
from repro.sparse import (
    BSRPlanes,
    knapsack_prune,
    pack_params,
    sparsity_summary,
    unpack_params,
)


def _assert_trees_close(a, b, atol=1e-6):
    flat_a = jax.tree_util.tree_leaves(a)
    flat_b = jax.tree_util.tree_leaves(b)
    assert len(flat_a) == len(flat_b)
    for la, lb in zip(flat_a, flat_b):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=atol)


def test_pack_unpack_roundtrip_property():
    """pack_params ∘ unpack_params == apply_masks over random selections
    (deterministic corpus: mixed shapes, blockings, keep fractions)."""
    rng = np.random.default_rng(0)
    cases = [
        ((96, 64), (16, 16), 0.6),
        ((128, 128), (32, 32), 0.3),
        ((100, 56), (32, 16), 0.5),   # ragged: blocks overhang both dims
        ((64, 72), (64, 8), 0.8),
        ((48, 48), (48, 48), 0.5),    # single-tile weight
    ]
    for (k, n), (bk, bn), keep in cases:
        params = {
            "layer": {"kernel": jnp.asarray(
                rng.normal(size=(k, n)).astype(np.float32))},
            "norm": {"scale": jnp.ones((n,), jnp.float32)},
        }
        structures = build_structures(
            params, BlockingSpec(bk=bk, bn=bn), min_size=16)
        sel = (rng.uniform(size=structures.total_structures) < keep
               ).astype(np.float32)
        masks = masks_from_knapsack(params, structures, sel)
        packed = pack_params(params, masks, structures)
        assert isinstance(packed["layer"]["kernel"], BSRWeight)
        # untouched leaves pass through identically
        assert packed["norm"]["scale"] is params["norm"]["scale"]
        recon = unpack_params(packed)
        masked = apply_masks(params, masks)
        _assert_trees_close(recon, masked)


def test_pack_unpack_roundtrip_planes():
    """3-D expert weights pack to BSRPlanes and round-trip exactly."""
    rng = np.random.default_rng(1)
    params = {"moe": {"experts_up": jnp.asarray(
        rng.normal(size=(4, 64, 48)).astype(np.float32))}}
    structures = build_structures(params, BlockingSpec(bk=16, bn=16), min_size=16)
    info = structures.infos[0]
    assert info.planes == 4
    sel = (rng.uniform(size=structures.total_structures) < 0.5).astype(np.float32)
    masks = masks_from_knapsack(params, structures, sel)
    packed = pack_params(params, masks, structures)
    leaf = packed["moe"]["experts_up"]
    assert isinstance(leaf, BSRPlanes) and len(leaf.planes) == 4
    recon = unpack_params(packed)
    masked = apply_masks(params, masks)
    _assert_trees_close(recon, masked)


def _pruned_pair(arch, *, sparsity=0.4, bk=32, bn=32, seed=0, **prune_kw):
    """(cfg, masked-dense params, packed params) for a pruned smoke model."""
    cfg = make_smoke(get_config(arch))
    if cfg.moe_experts:
        cfg = cfg.replace(capacity_factor=8.0)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    sel = knapsack_prune(
        params, sparsity=sparsity, blocking=BlockingSpec(bk=bk, bn=bn),
        min_size=1024, **prune_kw)
    masked = apply_masks(params, sel.masks)
    packed = pack_params(params, sel.masks, sel.structures)
    assert 0 < sparsity_summary(packed)["density"] < 1
    return cfg, masked, packed


def test_lm_forward_packed_equals_masked_dense():
    cfg, masked, packed = _pruned_pair("qwen1.5-0.5b")
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, cfg.vocab)
    ld, _ = lm_forward(masked, {"tokens": tokens}, cfg)
    lp, _ = lm_forward(packed, {"tokens": tokens}, cfg)
    np.testing.assert_allclose(
        np.asarray(lp), np.asarray(ld), atol=1e-3, rtol=1e-4)


def test_lm_decode_packed_equals_masked_dense():
    cfg, masked, packed = _pruned_pair("qwen1.5-0.5b")
    b, steps = 2, 6
    tokens = jax.random.randint(jax.random.PRNGKey(3), (b, steps), 0, cfg.vocab)
    caches_d = init_caches(cfg, b, steps + 1, jnp.float32)
    caches_p = init_caches(cfg, b, steps + 1, jnp.float32)
    for t in range(steps):
        tok = tokens[:, t:t + 1]
        ld, caches_d = lm_decode(masked, caches_d, {"tokens": tok},
                                 jnp.asarray(t, jnp.int32), cfg)
        lp, caches_p = lm_decode(packed, caches_p, {"tokens": tok},
                                 jnp.asarray(t, jnp.int32), cfg)
        np.testing.assert_allclose(
            np.asarray(lp), np.asarray(ld), atol=1e-3, rtol=1e-4,
            err_msg=f"decode step {t}")


def test_lm_decode_packed_jits():
    """The packed tree is a valid jit input (BSR leaves are pytrees)."""
    cfg, _, packed = _pruned_pair("qwen1.5-0.5b")
    b = 2
    caches = init_caches(cfg, b, 4, jnp.float32)
    decode = jax.jit(lambda p, c, t, l: lm_decode(p, c, {"tokens": t}, l, cfg))
    tok = jnp.zeros((b, 1), jnp.int32)
    logits, caches = decode(packed, caches, tok, jnp.asarray(0, jnp.int32))
    assert logits.shape == (b, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_moe_packed_equals_masked_dense():
    """Expert (plane) BSR path through the full MoE forward."""
    cfg, masked, packed = _pruned_pair(
        "granite-moe-1b-a400m", include=("moe", "mlp", "attn"))
    assert any(
        isinstance(leaf, BSRPlanes)
        for leaf in jax.tree_util.tree_leaves(
            packed, is_leaf=lambda x: isinstance(x, BSRPlanes))
        if isinstance(leaf, BSRPlanes)
    ), "expected at least one packed expert stack"
    tokens = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0, cfg.vocab)
    ld, _ = lm_forward(masked, {"tokens": tokens}, cfg)
    lp, _ = lm_forward(packed, {"tokens": tokens}, cfg)
    np.testing.assert_allclose(
        np.asarray(lp), np.asarray(ld), atol=1e-3, rtol=1e-4)


def test_unpack_is_masked_dense_oracle():
    """unpack_params(pack_params(p, m)) == apply_masks(p, m) on the model."""
    cfg = make_smoke(get_config("qwen1.5-0.5b"))
    params = init_params(jax.random.PRNGKey(5), cfg)
    sel = knapsack_prune(params, sparsity=0.5,
                         blocking=BlockingSpec(bk=32, bn=32), min_size=1024)
    packed = pack_params(params, sel.masks, sel.structures)
    recon = unpack_params(packed)
    masked = apply_masks(params, sel.masks)
    for info in sel.structures.infos:
        np.testing.assert_allclose(
            np.asarray(_get_path(recon, info.path)),
            np.asarray(_get_path(masked, info.path)),
            atol=1e-6, err_msg=info.path)


# ---------------------------------------------------------------------------
# Serving hot path: batched prefill + single-scan decode (DESIGN.md §7)
# ---------------------------------------------------------------------------

def _greedy_loop(cfg, params, tokens, gen):
    """The per-token reference loop the hot path replaced: prefill by
    feeding prompt tokens through lm_decode, then greedy decode with a
    host round-trip per token."""
    b, plen = tokens.shape
    caches = init_caches(cfg, b, plen + gen, jnp.float32)
    logits = None
    for t in range(plen):
        logits, caches = lm_decode(params, caches, {"tokens": tokens[:, t:t + 1]},
                                   jnp.asarray(t, jnp.int32), cfg)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out = []
    for i in range(gen):
        out.append(np.asarray(tok)[:, 0])
        logits, caches = lm_decode(params, caches, {"tokens": tok},
                                   jnp.asarray(plen + i, jnp.int32), cfg)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    return np.stack(out, axis=1)


def _hot_path(cfg, params, tokens, gen):
    """Batched lm_prefill + one lm_generate scan (two jitted calls)."""
    b, plen = tokens.shape
    caches = init_caches(cfg, b, plen + gen, jnp.float32)
    prefill = jax.jit(lambda p, c, t: lm_prefill(p, c, {"tokens": t}, cfg))
    generate = jax.jit(lambda p, c, t, l: lm_generate(p, c, t, l, gen, cfg))
    logits, caches = prefill(params, caches, tokens)
    first = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    toks, _ = generate(params, caches, first, jnp.asarray(plen, jnp.int32))
    return np.asarray(toks), logits


@pytest.mark.parametrize("arch,prune_kw", [
    ("qwen1.5-0.5b", {}),
    ("granite-moe-1b-a400m", {"include": ("moe", "mlp", "attn")}),
    ("jamba-v0.1-52b", {}),          # mamba_prefill SSM/conv state
    # mlstm/slstm prefill carries (xlstm has no mlp/attn paths to prune)
    ("xlstm-350m", {"include": ("mlstm", "slstm")}),
])
def test_hot_path_token_identical(arch, prune_kw):
    """Prefill+scan-decode reproduces the per-token loop token-for-token,
    on masked-dense AND packed params (transformer, MoE, SSM, xLSTM)."""
    cfg, masked, packed = _pruned_pair(arch, **prune_kw)
    tokens = jax.random.randint(jax.random.PRNGKey(7), (2, 10), 0, cfg.vocab)
    gen = 6
    for name, params in (("dense", masked), ("packed", packed)):
        want = _greedy_loop(cfg, params, tokens, gen)
        got, _ = _hot_path(cfg, params, tokens, gen)
        np.testing.assert_array_equal(got, want, err_msg=f"{arch}/{name}")


def test_hot_path_swa_ring_token_identical():
    """SWA ring cache (prompt longer than the window-sized cache):
    attention_prefill's last-alloc-tokens-at-t%alloc writes must match
    the per-token decode's ring placement."""
    cfg = make_smoke(get_config("mixtral-8x7b"), window=8, capacity_factor=8.0)
    params = init_params(jax.random.PRNGKey(10), cfg)
    b, plen, gen = 2, 14, 5
    tokens = jax.random.randint(jax.random.PRNGKey(11), (b, plen), 0, cfg.vocab)

    caches = init_caches(cfg, b, cfg.window, jnp.float32)  # alloc = window
    logits = None
    for t in range(plen):
        logits, caches = lm_decode(params, caches, {"tokens": tokens[:, t:t + 1]},
                                   jnp.asarray(t, jnp.int32), cfg)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    want = []
    for i in range(gen):
        want.append(np.asarray(tok)[:, 0])
        logits, caches = lm_decode(params, caches, {"tokens": tok},
                                   jnp.asarray(plen + i, jnp.int32), cfg)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)

    caches_p = init_caches(cfg, b, cfg.window, jnp.float32)
    pl, caches_p = lm_prefill(params, caches_p, {"tokens": tokens}, cfg)
    first = jnp.argmax(pl[:, -1], -1)[:, None].astype(jnp.int32)
    got, _ = lm_generate(params, caches_p, first,
                         jnp.asarray(plen, jnp.int32), gen, cfg)
    np.testing.assert_array_equal(np.asarray(got), np.stack(want, axis=1))


def test_prefill_logits_match_forward():
    """lm_prefill is lm_forward + cache fill: identical logits, dense and
    packed."""
    cfg, masked, packed = _pruned_pair("qwen1.5-0.5b")
    tokens = jax.random.randint(jax.random.PRNGKey(8), (2, 9), 0, cfg.vocab)
    for params in (masked, packed):
        want, _ = lm_forward(params, {"tokens": tokens}, cfg)
        caches = init_caches(cfg, 2, 12, jnp.float32)
        got, _ = lm_prefill(params, caches, {"tokens": tokens}, cfg)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-3, rtol=1e-4)


def test_hot_path_packed_equals_dense_tokens():
    """Packed and masked-dense params greedy-decode the same tokens
    through the new path (the end-to-end zero-skipping guarantee)."""
    cfg, masked, packed = _pruned_pair("qwen1.5-0.5b")
    tokens = jax.random.randint(jax.random.PRNGKey(9), (2, 8), 0, cfg.vocab)
    got_d, logits_d = _hot_path(cfg, masked, tokens, 5)
    got_p, logits_p = _hot_path(cfg, packed, tokens, 5)
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_d),
                               atol=1e-3, rtol=1e-4)
    np.testing.assert_array_equal(got_p, got_d)


# ---------------------------------------------------------------------------
# Fused epilogue at block level (DESIGN.md §8): mlp_apply / expert_matmul
# must match the unfused composition, packed and dense
# ---------------------------------------------------------------------------

def _packed_mlp(rng, d_model=64, d_ff=128, gated=True, keep=0.5):
    from repro.models.ffn import mlp_init

    params = mlp_init(jax.random.PRNGKey(0), d_model, d_ff, gated=gated,
                      use_bias=True)
    structures = build_structures(params, BlockingSpec(bk=32, bn=32),
                                  min_size=64)
    sel = (rng.uniform(size=structures.total_structures) < keep
           ).astype(np.float32)
    masks = masks_from_knapsack(params, structures, sel)
    masked = apply_masks(params, masks)
    packed = pack_params(params, masks, structures)
    return masked, packed


@pytest.mark.parametrize("gated", [True, False])
def test_mlp_fused_epilogue_matches_unfused(gated):
    """mlp_apply's fused bias+activation+gate+residual tail bit-matches
    the unfused layer composition it replaced — on the masked-dense path
    AND the packed (ref-kernel) path."""
    from repro.models.ffn import mlp_apply
    from repro.models.layers import dense

    rng = np.random.default_rng(20 + gated)
    masked, packed = _packed_mlp(rng, gated=gated)
    x = jnp.asarray(rng.normal(size=(2, 6, 64)).astype(np.float32))
    res = jnp.asarray(rng.normal(size=(2, 6, 64)).astype(np.float32))

    def unfused(p):
        up = dense(p["w_up"], x)
        if gated:
            gate = dense(p["w_gate"], x)
            h = jax.nn.silu(gate) * up
        else:
            h = jax.nn.silu(up)
        return res + dense(p["w_down"], h.astype(x.dtype))

    for name, p in (("dense", masked), ("packed", packed)):
        got = mlp_apply(p, x, activation="silu", residual=res)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(unfused(p)), err_msg=name)


def test_expert_matmul_fused_epilogue_matches_unfused():
    """expert_matmul's fused act(gate)*up epilogue (the MoE expert FFN
    tail) matches the unfused composition for dense stacks and for
    BSRPlanes on the ref kernel.  (Tight allclose, not bitwise: the fused
    and unfused graphs compile separately and XLA may reassociate the
    fp32 segment-sum — kernel-level bitwise identity is covered in
    test_kernels.test_ref_epilogue_bitmatches_unfused.)"""
    from repro.kernels import Epilogue
    from repro.models.layers import expert_matmul

    rng = np.random.default_rng(22)
    e, g, c, d, f = 3, 2, 4, 64, 96
    dense_w = jnp.asarray(rng.normal(size=(e, d, f)).astype(np.float32))
    params = {"experts_gate": dense_w}
    structures = build_structures(params, BlockingSpec(bk=32, bn=32),
                                  min_size=64)
    sel = (rng.uniform(size=structures.total_structures) < 0.5
           ).astype(np.float32)
    masks = masks_from_knapsack(params, structures, sel)
    masked = apply_masks(params, masks)["experts_gate"]
    packed = pack_params(params, masks, structures)["experts_gate"]
    assert isinstance(packed, BSRPlanes)

    h = jnp.asarray(rng.normal(size=(g, e, c, d)).astype(np.float32))
    up = jnp.asarray(rng.normal(size=(g, e, c, f)).astype(np.float32))
    epi = Epilogue(activation="gelu", multiplier=up)
    for name, w in (("dense", masked), ("packed", packed)):
        got = expert_matmul(h, w, epilogue=epi)
        want = jax.nn.gelu(expert_matmul(h, w).astype(jnp.float32)) * up
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), atol=1e-5, err_msg=name)


# ---------------------------------------------------------------------------
# Sampling + EOS early-exit inside the lm_generate scan
# ---------------------------------------------------------------------------

def test_generate_topk1_and_tiny_topp_equal_greedy():
    """temperature>0 with top_k=1 (or a vanishing top_p nucleus) collapses
    to argmax — the sampled scan must emit exactly the greedy tokens."""
    cfg, _, packed = _pruned_pair("qwen1.5-0.5b")
    tokens = jax.random.randint(jax.random.PRNGKey(12), (2, 6), 0, cfg.vocab)
    caches = init_caches(cfg, 2, 12, jnp.float32)
    logits, caches = lm_prefill(packed, caches, {"tokens": tokens}, cfg)
    first = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    plen = jnp.asarray(tokens.shape[1], jnp.int32)
    want, _ = lm_generate(packed, caches, first, plen, 5, cfg)
    for kw in ({"top_k": 1}, {"top_p": 1e-9}):
        got, _ = lm_generate(packed, caches, first, plen, 5, cfg,
                             temperature=1.0, key=jax.random.PRNGKey(3), **kw)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=str(kw))


def test_nucleus_filter_breaks_ties_by_sorted_position():
    """Regression: the old value-threshold nucleus kept EVERY logit tied
    at the cutoff, so tied logits could keep far more than top_p mass.
    Ties must break by sorted position (stable: lowest vocab id first)."""
    from repro.models.transformer import _nucleus_filter

    # 8-way tie, top_p=0.5: exactly 4 survive (old code kept all 8)
    out = np.asarray(_nucleus_filter(jnp.zeros((1, 8)), 0.5))[0]
    kept = np.isfinite(out)
    assert kept.sum() == 4
    assert kept[:4].all() and not kept[4:].any()

    # the top-1 token always survives, even a vanishing nucleus
    out = np.asarray(_nucleus_filter(jnp.zeros((1, 4)), 1e-9))[0]
    assert np.isfinite(out).sum() == 1

    # distinct logits: minimal prefix whose mass reaches top_p, and the
    # kept entries pass through unchanged
    logits = jnp.log(jnp.asarray([[0.4, 0.3, 0.2, 0.1]]))
    out = np.asarray(_nucleus_filter(logits, 0.6))[0]
    np.testing.assert_allclose(out[:2], np.asarray(logits)[0, :2])
    assert not np.isfinite(out[2:]).any()

    # tied tail straddling the cutoff: mass before each of the four tied
    # 0.15-tokens is 0.4, 0.55, 0.70, ... -> exactly two of them stay
    logits = jnp.log(jnp.asarray([[0.4, 0.15, 0.15, 0.15, 0.15]]))
    out = np.asarray(_nucleus_filter(logits, 0.7))[0]
    assert np.isfinite(out).sum() == 3      # 0.4 + two tied tokens
    assert np.isfinite(out[:3]).all()       # stable: lowest ids first


def test_topk_filter_breaks_ties_by_rank():
    """Same tie-class bug as the nucleus filter: top_k=K on a tie plateau
    must expose exactly K tokens to the sampler, not every tied logit."""
    from repro.models.transformer import _select_token

    logits = jnp.zeros((1, 6))             # 6-way tie
    seen = set()
    for s in range(24):
        t, _ = _select_token(logits, jax.random.PRNGKey(s),
                             temperature=1.0, top_k=2, top_p=None)
        seen.add(int(t[0]))
    assert seen <= {0, 1}                  # stable: lowest vocab ids kept
    assert len(seen) == 2                  # and both really are sampled


def test_generate_sampling_deterministic_and_in_vocab():
    cfg, _, packed = _pruned_pair("qwen1.5-0.5b")
    caches = init_caches(cfg, 2, 10, jnp.float32)
    tokens = jax.random.randint(jax.random.PRNGKey(13), (2, 4), 0, cfg.vocab)
    logits, caches = lm_prefill(packed, caches, {"tokens": tokens}, cfg)
    first = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    plen = jnp.asarray(4, jnp.int32)
    kw = dict(temperature=0.9, top_k=8, top_p=0.95,
              key=jax.random.PRNGKey(4))
    a, _ = lm_generate(packed, caches, first, plen, 6, cfg, **kw)
    b, _ = lm_generate(packed, caches, first, plen, 6, cfg, **kw)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert (np.asarray(a) >= 0).all() and (np.asarray(a) < cfg.vocab).all()


def test_generate_eos_mask_and_early_exit():
    """Once a row emits eos_id it keeps emitting eos_id; rows that never
    hit it are untouched; the all-done lax.cond fast path emits eos for
    every remaining step."""
    cfg, _, packed = _pruned_pair("qwen1.5-0.5b")
    tokens = jax.random.randint(jax.random.PRNGKey(14), (2, 5), 0, cfg.vocab)
    gen = 6
    caches = init_caches(cfg, 2, 5 + gen, jnp.float32)
    logits, caches = lm_prefill(packed, caches, {"tokens": tokens}, cfg)
    first = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    plen = jnp.asarray(5, jnp.int32)
    base, _ = lm_generate(packed, caches, first, plen, gen, cfg)
    base = np.asarray(base)

    # choose an eos that row 0 emits mid-stream (greedy repeats tokens on
    # random smoke weights, so pick its token at step 2)
    eos = int(base[0, 2])
    got, _ = lm_generate(packed, caches, first, plen, gen, cfg, eos_id=eos)
    got = np.asarray(got)
    for r in range(base.shape[0]):
        hits = np.nonzero(base[r] == eos)[0]
        if hits.size == 0:
            np.testing.assert_array_equal(got[r], base[r], err_msg=f"row {r}")
        else:
            t = hits[0]
            np.testing.assert_array_equal(got[r, : t + 1], base[r, : t + 1])
            assert (got[r, t:] == eos).all()

    # all rows done from step 0: the cond skip path runs every step
    allc, _ = lm_generate(packed, caches,
                          jnp.full_like(first, eos), plen, gen, cfg,
                          eos_id=eos)
    assert (np.asarray(allc) == eos).all()


def test_knapsack_prune_respects_budget():
    cfg = make_smoke(get_config("qwen1.5-0.5b"))
    params = init_params(jax.random.PRNGKey(6), cfg)
    sel = knapsack_prune(params, sparsity=0.5,
                         blocking=BlockingSpec(bk=32, bn=32), min_size=1024)
    assert sel.result.feasible
    assert 0 < sel.kept < sel.total
    with pytest.raises(ValueError):
        knapsack_prune(params, sparsity=1.5,
                       blocking=BlockingSpec(bk=32, bn=32))
