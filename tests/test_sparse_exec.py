"""Sparse execution layer: pack/unpack round-trips and packed-vs-dense
logits equivalence through the full model stack (DESIGN.md §6).

The fp32 ref BSR path reconstructs exactly the masked dense weight, so
forward and decode logits on packed params must match the masked-dense
execution to numerical noise — the end-to-end guarantee that lets the
serving path swap in BSR kernels without touching the model code.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, make_smoke
from repro.core import BlockingSpec, apply_masks, build_structures, masks_from_knapsack
from repro.core.masks import _get_path
from repro.core.packing import BSRWeight
from repro.models import (
    init_caches,
    init_params,
    lm_decode,
    lm_forward,
    lm_generate,
    lm_prefill,
)
from repro.sparse import (
    BSRPlanes,
    knapsack_prune,
    pack_params,
    sparsity_summary,
    unpack_params,
)


def _assert_trees_close(a, b, atol=1e-6):
    flat_a = jax.tree_util.tree_leaves(a)
    flat_b = jax.tree_util.tree_leaves(b)
    assert len(flat_a) == len(flat_b)
    for la, lb in zip(flat_a, flat_b):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=atol)


def test_pack_unpack_roundtrip_property():
    """pack_params ∘ unpack_params == apply_masks over random selections
    (deterministic corpus: mixed shapes, blockings, keep fractions)."""
    rng = np.random.default_rng(0)
    cases = [
        ((96, 64), (16, 16), 0.6),
        ((128, 128), (32, 32), 0.3),
        ((100, 56), (32, 16), 0.5),   # ragged: blocks overhang both dims
        ((64, 72), (64, 8), 0.8),
        ((48, 48), (48, 48), 0.5),    # single-tile weight
    ]
    for (k, n), (bk, bn), keep in cases:
        params = {
            "layer": {"kernel": jnp.asarray(
                rng.normal(size=(k, n)).astype(np.float32))},
            "norm": {"scale": jnp.ones((n,), jnp.float32)},
        }
        structures = build_structures(
            params, BlockingSpec(bk=bk, bn=bn), min_size=16)
        sel = (rng.uniform(size=structures.total_structures) < keep
               ).astype(np.float32)
        masks = masks_from_knapsack(params, structures, sel)
        packed = pack_params(params, masks, structures)
        assert isinstance(packed["layer"]["kernel"], BSRWeight)
        # untouched leaves pass through identically
        assert packed["norm"]["scale"] is params["norm"]["scale"]
        recon = unpack_params(packed)
        masked = apply_masks(params, masks)
        _assert_trees_close(recon, masked)


def test_pack_unpack_roundtrip_planes():
    """3-D expert weights pack to BSRPlanes and round-trip exactly."""
    rng = np.random.default_rng(1)
    params = {"moe": {"experts_up": jnp.asarray(
        rng.normal(size=(4, 64, 48)).astype(np.float32))}}
    structures = build_structures(params, BlockingSpec(bk=16, bn=16), min_size=16)
    info = structures.infos[0]
    assert info.planes == 4
    sel = (rng.uniform(size=structures.total_structures) < 0.5).astype(np.float32)
    masks = masks_from_knapsack(params, structures, sel)
    packed = pack_params(params, masks, structures)
    leaf = packed["moe"]["experts_up"]
    assert isinstance(leaf, BSRPlanes) and len(leaf.planes) == 4
    recon = unpack_params(packed)
    masked = apply_masks(params, masks)
    _assert_trees_close(recon, masked)


def _pruned_pair(arch, *, sparsity=0.4, bk=32, bn=32, seed=0, **prune_kw):
    """(cfg, masked-dense params, packed params) for a pruned smoke model."""
    cfg = make_smoke(get_config(arch))
    if cfg.moe_experts:
        cfg = cfg.replace(capacity_factor=8.0)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    sel = knapsack_prune(
        params, sparsity=sparsity, blocking=BlockingSpec(bk=bk, bn=bn),
        min_size=1024, **prune_kw)
    masked = apply_masks(params, sel.masks)
    packed = pack_params(params, sel.masks, sel.structures)
    assert 0 < sparsity_summary(packed)["density"] < 1
    return cfg, masked, packed


def test_lm_forward_packed_equals_masked_dense():
    cfg, masked, packed = _pruned_pair("qwen1.5-0.5b")
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, cfg.vocab)
    ld, _ = lm_forward(masked, {"tokens": tokens}, cfg)
    lp, _ = lm_forward(packed, {"tokens": tokens}, cfg)
    np.testing.assert_allclose(
        np.asarray(lp), np.asarray(ld), atol=1e-3, rtol=1e-4)


def test_lm_decode_packed_equals_masked_dense():
    cfg, masked, packed = _pruned_pair("qwen1.5-0.5b")
    b, steps = 2, 6
    tokens = jax.random.randint(jax.random.PRNGKey(3), (b, steps), 0, cfg.vocab)
    caches_d = init_caches(cfg, b, steps + 1, jnp.float32)
    caches_p = init_caches(cfg, b, steps + 1, jnp.float32)
    for t in range(steps):
        tok = tokens[:, t:t + 1]
        ld, caches_d = lm_decode(masked, caches_d, {"tokens": tok},
                                 jnp.asarray(t, jnp.int32), cfg)
        lp, caches_p = lm_decode(packed, caches_p, {"tokens": tok},
                                 jnp.asarray(t, jnp.int32), cfg)
        np.testing.assert_allclose(
            np.asarray(lp), np.asarray(ld), atol=1e-3, rtol=1e-4,
            err_msg=f"decode step {t}")


def test_lm_decode_packed_jits():
    """The packed tree is a valid jit input (BSR leaves are pytrees)."""
    cfg, _, packed = _pruned_pair("qwen1.5-0.5b")
    b = 2
    caches = init_caches(cfg, b, 4, jnp.float32)
    decode = jax.jit(lambda p, c, t, l: lm_decode(p, c, {"tokens": t}, l, cfg))
    tok = jnp.zeros((b, 1), jnp.int32)
    logits, caches = decode(packed, caches, tok, jnp.asarray(0, jnp.int32))
    assert logits.shape == (b, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_moe_packed_equals_masked_dense():
    """Expert (plane) BSR path through the full MoE forward."""
    cfg, masked, packed = _pruned_pair(
        "granite-moe-1b-a400m", include=("moe", "mlp", "attn"))
    assert any(
        isinstance(leaf, BSRPlanes)
        for leaf in jax.tree_util.tree_leaves(
            packed, is_leaf=lambda x: isinstance(x, BSRPlanes))
        if isinstance(leaf, BSRPlanes)
    ), "expected at least one packed expert stack"
    tokens = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0, cfg.vocab)
    ld, _ = lm_forward(masked, {"tokens": tokens}, cfg)
    lp, _ = lm_forward(packed, {"tokens": tokens}, cfg)
    np.testing.assert_allclose(
        np.asarray(lp), np.asarray(ld), atol=1e-3, rtol=1e-4)


def test_unpack_is_masked_dense_oracle():
    """unpack_params(pack_params(p, m)) == apply_masks(p, m) on the model."""
    cfg = make_smoke(get_config("qwen1.5-0.5b"))
    params = init_params(jax.random.PRNGKey(5), cfg)
    sel = knapsack_prune(params, sparsity=0.5,
                         blocking=BlockingSpec(bk=32, bn=32), min_size=1024)
    packed = pack_params(params, sel.masks, sel.structures)
    recon = unpack_params(packed)
    masked = apply_masks(params, sel.masks)
    for info in sel.structures.infos:
        np.testing.assert_allclose(
            np.asarray(_get_path(recon, info.path)),
            np.asarray(_get_path(masked, info.path)),
            atol=1e-6, err_msg=info.path)


# ---------------------------------------------------------------------------
# Serving hot path: batched prefill + single-scan decode (DESIGN.md §7)
# ---------------------------------------------------------------------------

def _greedy_loop(cfg, params, tokens, gen):
    """The per-token reference loop the hot path replaced: prefill by
    feeding prompt tokens through lm_decode, then greedy decode with a
    host round-trip per token."""
    b, plen = tokens.shape
    caches = init_caches(cfg, b, plen + gen, jnp.float32)
    logits = None
    for t in range(plen):
        logits, caches = lm_decode(params, caches, {"tokens": tokens[:, t:t + 1]},
                                   jnp.asarray(t, jnp.int32), cfg)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out = []
    for i in range(gen):
        out.append(np.asarray(tok)[:, 0])
        logits, caches = lm_decode(params, caches, {"tokens": tok},
                                   jnp.asarray(plen + i, jnp.int32), cfg)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    return np.stack(out, axis=1)


def _hot_path(cfg, params, tokens, gen):
    """Batched lm_prefill + one lm_generate scan (two jitted calls)."""
    b, plen = tokens.shape
    caches = init_caches(cfg, b, plen + gen, jnp.float32)
    prefill = jax.jit(lambda p, c, t: lm_prefill(p, c, {"tokens": t}, cfg))
    generate = jax.jit(lambda p, c, t, l: lm_generate(p, c, t, l, gen, cfg))
    logits, caches = prefill(params, caches, tokens)
    first = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    toks, _ = generate(params, caches, first, jnp.asarray(plen, jnp.int32))
    return np.asarray(toks), logits


@pytest.mark.parametrize("arch,prune_kw", [
    ("qwen1.5-0.5b", {}),
    ("granite-moe-1b-a400m", {"include": ("moe", "mlp", "attn")}),
    ("jamba-v0.1-52b", {}),          # mamba_prefill SSM/conv state
    # mlstm/slstm prefill carries (xlstm has no mlp/attn paths to prune)
    ("xlstm-350m", {"include": ("mlstm", "slstm")}),
])
def test_hot_path_token_identical(arch, prune_kw):
    """Prefill+scan-decode reproduces the per-token loop token-for-token,
    on masked-dense AND packed params (transformer, MoE, SSM, xLSTM)."""
    cfg, masked, packed = _pruned_pair(arch, **prune_kw)
    tokens = jax.random.randint(jax.random.PRNGKey(7), (2, 10), 0, cfg.vocab)
    gen = 6
    for name, params in (("dense", masked), ("packed", packed)):
        want = _greedy_loop(cfg, params, tokens, gen)
        got, _ = _hot_path(cfg, params, tokens, gen)
        np.testing.assert_array_equal(got, want, err_msg=f"{arch}/{name}")


def test_hot_path_swa_ring_token_identical():
    """SWA ring cache (prompt longer than the window-sized cache):
    attention_prefill's last-alloc-tokens-at-t%alloc writes must match
    the per-token decode's ring placement."""
    cfg = make_smoke(get_config("mixtral-8x7b"), window=8, capacity_factor=8.0)
    params = init_params(jax.random.PRNGKey(10), cfg)
    b, plen, gen = 2, 14, 5
    tokens = jax.random.randint(jax.random.PRNGKey(11), (b, plen), 0, cfg.vocab)

    caches = init_caches(cfg, b, cfg.window, jnp.float32)  # alloc = window
    logits = None
    for t in range(plen):
        logits, caches = lm_decode(params, caches, {"tokens": tokens[:, t:t + 1]},
                                   jnp.asarray(t, jnp.int32), cfg)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    want = []
    for i in range(gen):
        want.append(np.asarray(tok)[:, 0])
        logits, caches = lm_decode(params, caches, {"tokens": tok},
                                   jnp.asarray(plen + i, jnp.int32), cfg)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)

    caches_p = init_caches(cfg, b, cfg.window, jnp.float32)
    pl, caches_p = lm_prefill(params, caches_p, {"tokens": tokens}, cfg)
    first = jnp.argmax(pl[:, -1], -1)[:, None].astype(jnp.int32)
    got, _ = lm_generate(params, caches_p, first,
                         jnp.asarray(plen, jnp.int32), gen, cfg)
    np.testing.assert_array_equal(np.asarray(got), np.stack(want, axis=1))


def test_prefill_logits_match_forward():
    """lm_prefill is lm_forward + cache fill: identical logits, dense and
    packed."""
    cfg, masked, packed = _pruned_pair("qwen1.5-0.5b")
    tokens = jax.random.randint(jax.random.PRNGKey(8), (2, 9), 0, cfg.vocab)
    for params in (masked, packed):
        want, _ = lm_forward(params, {"tokens": tokens}, cfg)
        caches = init_caches(cfg, 2, 12, jnp.float32)
        got, _ = lm_prefill(params, caches, {"tokens": tokens}, cfg)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-3, rtol=1e-4)


def test_hot_path_packed_equals_dense_tokens():
    """Packed and masked-dense params greedy-decode the same tokens
    through the new path (the end-to-end zero-skipping guarantee)."""
    cfg, masked, packed = _pruned_pair("qwen1.5-0.5b")
    tokens = jax.random.randint(jax.random.PRNGKey(9), (2, 8), 0, cfg.vocab)
    got_d, logits_d = _hot_path(cfg, masked, tokens, 5)
    got_p, logits_p = _hot_path(cfg, packed, tokens, 5)
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_d),
                               atol=1e-3, rtol=1e-4)
    np.testing.assert_array_equal(got_p, got_d)


def test_knapsack_prune_respects_budget():
    cfg = make_smoke(get_config("qwen1.5-0.5b"))
    params = init_params(jax.random.PRNGKey(6), cfg)
    sel = knapsack_prune(params, sparsity=0.5,
                         blocking=BlockingSpec(bk=32, bn=32), min_size=1024)
    assert sel.result.feasible
    assert 0 < sel.kept < sel.total
    with pytest.raises(ValueError):
        knapsack_prune(params, sparsity=1.5,
                       blocking=BlockingSpec(bk=32, bn=32))
