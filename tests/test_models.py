"""Per-arch smoke tests: reduced same-family config, one forward + one
train step on CPU, assert output shapes + finite values (assignment (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, make_smoke
from repro.models import (
    cross_entropy_loss,
    init_caches,
    init_params,
    lm_decode,
    lm_forward,
)
from repro.models.transformer import encode_kv_caches, encoder_forward
from repro.optim import AdamWConfig, constant_lr
from repro.train import init_train_state, make_train_step

ARCHS = list_archs()


def _smoke_batch(cfg, b=2, s=16):
    batch = {
        "tokens": jnp.full((b, s), 3, jnp.int32),
        "labels": jnp.ones((b, s), jnp.int32),
    }
    if cfg.mrope_sections:
        batch["positions"] = jnp.broadcast_to(jnp.arange(s)[None, :, None], (b, s, 3))
    if cfg.num_patches:
        batch["patch_embeds"] = jnp.ones((b, cfg.num_patches, cfg.d_model), jnp.float32)
    if cfg.enc_layers:
        batch["frames"] = jnp.ones((b, cfg.enc_frames, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_finite(arch):
    cfg = make_smoke(get_config(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _smoke_batch(cfg)
    logits, aux = lm_forward(params, batch, cfg)
    assert logits.shape == (2, 16, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert np.isfinite(float(aux["moe_aux"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_no_nans(arch):
    cfg = make_smoke(get_config(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(use_master=False, weight_decay=0.0)
    state = init_train_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg, constant_lr(1e-3)))
    batch = _smoke_batch(cfg)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["total_loss"]))
    for leaf in jax.tree.leaves(state["params"]):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))
    assert int(state["step"]) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = make_smoke(get_config(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, max_len = 2, 32
    caches = init_caches(cfg, b, max_len, jnp.float32)
    if cfg.enc_layers:
        enc = encoder_forward(
            params, jnp.ones((b, cfg.enc_frames, cfg.d_model), jnp.float32), cfg)
        caches = encode_kv_caches(params, enc, cfg, caches)
    logits, caches = lm_decode(
        params, caches, {"tokens": jnp.zeros((b, 1), jnp.int32)},
        jnp.asarray(0, jnp.int32), cfg)
    assert logits.shape == (b, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


def test_training_reduces_loss():
    """End-to-end learnability: a tiny dense LM fits the synthetic automaton."""
    from repro.data import TokenTask

    cfg = make_smoke(get_config("qwen1.5-0.5b"), n_layers=2, vocab=64)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(use_master=False, weight_decay=0.0)
    state = init_train_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg, constant_lr(2e-3)))
    task = TokenTask(vocab=cfg.vocab, noise=0.02)
    first = last = None
    for s in range(30):
        batch = task.batch(s, 8, 32)
        state, metrics = step(state, batch)
        if first is None:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert last < first * 0.8, (first, last)
