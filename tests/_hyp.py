"""Hypothesis shim: real property testing when ``hypothesis`` is
installed, a deterministic fixed-corpus fallback otherwise.

The container used for tier-1 verification has no network access, so
``hypothesis`` may be absent.  Instead of skipping the property tests we
degrade them to a seeded corpus: the same strategy expressions are drawn
from a ``numpy`` Generator with a fixed seed, and ``@given`` runs the
test body over ``FALLBACK_EXAMPLES`` deterministic examples.  Coverage is
narrower than real shrinking-enabled hypothesis but the invariants still
execute on every CI run.

Usage (in test modules):

    from _hyp import given, settings, st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import numpy as np

    HAVE_HYPOTHESIS = False
    FALLBACK_EXAMPLES = 25
    _SEED = 20260801

    class _Strategy:
        """A deterministic sampler standing in for a hypothesis strategy."""

        def __init__(self, sample):
            self._sample = sample

        def example(self, rng):
            return self._sample(rng)

    class _StrategiesShim:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def lists(elements, *, min_size=0, max_size=10):
            def sample(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.example(rng) for _ in range(n)]

            return _Strategy(sample)

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

        @staticmethod
        def composite(fn):
            def builder(*args, **kwargs):
                def sample(rng):
                    return fn(lambda s: s.example(rng), *args, **kwargs)

                return _Strategy(sample)

            return builder

    st = _StrategiesShim()

    def settings(**_kw):
        """No-op decorator (example counts are fixed in the fallback)."""

        def deco(fn):
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            # NOT functools.wraps: pytest would follow __wrapped__ to the
            # original signature and demand fixtures for its parameters
            def run():
                rng = np.random.default_rng(_SEED)
                for _ in range(FALLBACK_EXAMPLES):
                    args = [s.example(rng) for s in arg_strategies]
                    kwargs = {k: s.example(rng) for k, s in kw_strategies.items()}
                    fn(*args, **kwargs)

            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            run.__module__ = fn.__module__
            return run

        return deco
