"""Integration tests for Algorithm 2 (iterative resource-aware pruning)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BlockingSpec,
    IterativePruner,
    PruneConfig,
    TPUResourceModel,
    apply_masks,
    build_structures,
    constant_step,
    group_lasso,
    init_masks,
)
from repro.data import JetsTask
from repro.models.cnn import init_jets_mlp, jets_mlp_forward
from repro.optim import AdamWConfig, adamw_update, constant_lr, init_opt_state


def _accuracy(params, masks, batch):
    x, y = batch
    logits = jets_mlp_forward(apply_masks(params, masks), x)
    return float(jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32)))


def _train(params, masks, task, steps, lr=5e-3, reg=None):
    opt_cfg = AdamWConfig(use_master=False, weight_decay=0.0)
    opt = init_opt_state(params, opt_cfg)

    @jax.jit
    def step(params, opt, x, y):
        def loss_fn(p):
            logits = jets_mlp_forward(apply_masks(p, masks), x)
            onehot = jax.nn.one_hot(y, logits.shape[-1])
            loss = -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))
            if reg is not None:
                loss = loss + reg(p)
            return loss

        grads = jax.grad(loss_fn)(params)
        return adamw_update(params, grads, opt, opt_cfg, jnp.asarray(lr), masks=masks)

    for s in range(steps):
        x, y = task.batch(s, 256)
        params, opt = step(params, opt, x, y)
    return params


@pytest.fixture(scope="module")
def trained_jets():
    task = JetsTask()
    params = init_jets_mlp(jax.random.PRNGKey(0))
    st = build_structures(params, BlockingSpec(bk=8, bn=8), min_size=256)
    masks = init_masks(params, st)
    params = _train(params, masks, task, 150)
    acc = _accuracy(params, masks, task.batch(9999, 2048))
    assert acc > 0.85, f"baseline must train, got {acc}"
    return params, task


def test_iterative_pruning_preserves_accuracy(trained_jets):
    """Paper §IV-B: high structure sparsity within the accuracy tolerance."""
    params, task = trained_jets
    st = build_structures(params, BlockingSpec(bk=8, bn=8), min_size=256)
    rm = TPUResourceModel(precision="bf16")
    pruner = IterativePruner(
        st, rm,
        PruneConfig(schedule=constant_step([0.6, 0.6], step=0.2), tolerance=0.03),
    )
    val = task.batch(9999, 2048)

    def eval_fn(p, m):
        return _accuracy(p, m, val)

    def finetune_fn(p, m):
        return _train(p, m, task, 40)

    base_acc = eval_fn(params, init_masks(params, st))
    new_params, masks, logs = pruner.run(params, finetune_fn, eval_fn)
    assert logs, "at least one pruning iteration"
    final = logs[-1]
    assert final.structure_sparsity >= 0.35
    final_acc = eval_fn(new_params, masks)
    assert final_acc >= base_acc - 0.05
    # masked weights are exactly zero after apply
    mp = apply_masks(new_params, masks)
    for info in st.infos:
        m = np.asarray(masks[info.path.split("/")[0]][info.path.split("/")[1]])
        w = np.asarray(mp[info.path.split("/")[0]][info.path.split("/")[1]])
        assert np.all(w[m == 0] == 0)


def test_prune_step_respects_budget(trained_jets):
    params, _ = trained_jets
    st = build_structures(params, BlockingSpec(bk=8, bn=8), min_size=256)
    rm = TPUResourceModel(precision="bf16")
    pruner = IterativePruner(
        st, rm, PruneConfig(schedule=constant_step([0.5, 0.5], 0.5)))
    sparsity = np.array([0.5, 0.5])
    masks, result = pruner.prune_step(params, sparsity)
    budget = (1 - sparsity) * pruner.baseline_resources
    assert np.all(result.used <= budget + 1e-6)


def test_monotone_sparsity_no_revival(trained_jets):
    """exclude_zero: once pruned, structures stay pruned across iterations."""
    params, task = trained_jets
    st = build_structures(params, BlockingSpec(bk=8, bn=8), min_size=256)
    rm = TPUResourceModel()
    pruner = IterativePruner(
        st, rm, PruneConfig(schedule=constant_step([0.4, 0.4], 0.2)))
    masks1, _ = pruner.prune_step(params, np.array([0.2, 0.2]))
    p1 = apply_masks(params, masks1)
    masks2, _ = pruner.prune_step(p1, np.array([0.4, 0.4]))
    for path in ["fc_1", "fc_2", "fc_3"]:  # fc_4 < min_size: never pruned
        m1 = np.asarray(masks1[path]["kernel"])
        m2 = np.asarray(masks2[path]["kernel"])
        assert np.all(m2 <= m1 + 1e-6), f"revived structures in {path}"


def test_group_lasso_shrinks_structures():
    """Regularized fine-tuning drives whole structures toward zero."""
    task = JetsTask()
    params = init_jets_mlp(jax.random.PRNGKey(1))
    st = build_structures(params, BlockingSpec(bk=8, bn=8), min_size=256)
    masks = init_masks(params, st)
    # AdamW's per-parameter normalization blunts small penalties; 0.1 is the
    # empirically-calibrated strength at which groups actually die (§tests)
    reg = lambda p: group_lasso(p, st, strength=0.1)
    params = _train(params, masks, task, 150, reg=reg)
    from repro.core.structures import structure_norms_dense

    norms = np.concatenate([
        np.asarray(structure_norms_dense(params[i.path.split("/")[0]]["kernel"], i)).ravel()
        for i in st.infos
    ])
    # group lasso makes a meaningful fraction of structures near-dead
    frac_small = float(np.mean(norms < 0.1 * norms.max()))
    assert frac_small > 0.08, frac_small  # unregularized baseline: 0.00
