"""SLO-aware adaptive scheduling (DESIGN.md §15).

Two load-bearing properties:

* **Policy invariance** — the adaptive chunk policy (and any fixed
  ``ticks_per_sync``, and any priority assignment) moves only *when*
  chunk boundaries land, never *what* tokens a request emits: every
  stream stays bit-identical to its solo decode across all of them,
  dense AND packed.
* **The recompile contract** — the policy only ever requests chunk
  lengths from its frozen, declared ``compile_levels`` set, so adaptive
  traffic compiles at most ``len(compile_levels)`` ``_decode_chunk``
  variants and zero thereafter (a naive ``ticks = f(load)`` driver is a
  compile storm — see the recompile-hazard golden in test_analysis.py).

Plus the scheduler-side anti-starvation argument: aging promotes any
waiter one effective priority level per ``aging_ticks``, so sustained
higher-priority load bounds — not unbounds — a low-priority wait.
"""
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.serving import (
    DEFAULT_LEVELS,
    AdaptiveChunkPolicy,
    ChunkSignals,
    PagePool,
    Request,
    RequestStatus,
    Scheduler,
    ServingEngine,
)
from repro.serving.slo import percentiles
from test_serving_engine import _smoke_pair, _solo

_SMOKE = None


def _smoke():
    """Module-cached smoke pair (plain function, not a pytest fixture,
    so the _hyp property wrappers — which take no parameters — can use
    it too)."""
    global _SMOKE
    if _SMOKE is None:
        _SMOKE = _smoke_pair()
    return _SMOKE


def _req(rid, *, arrival=0, priority=0, plen=4, max_new=2, **kw):
    return Request(rid=rid, prompt=np.zeros(plen, np.int32),
                   max_new=max_new, arrival=arrival, priority=priority, **kw)


# ---------------------------------------------------------------------------
# AdaptiveChunkPolicy units
# ---------------------------------------------------------------------------

def test_policy_validates_levels_and_hot_queue():
    with pytest.raises(ValueError, match="levels"):
        AdaptiveChunkPolicy(levels=())
    with pytest.raises(ValueError, match="levels"):
        AdaptiveChunkPolicy(levels=(0, 4))
    with pytest.raises(ValueError, match="hot_queue"):
        AdaptiveChunkPolicy(hot_queue=0)
    # levels are deduped + sorted; compile_levels adds the degraded 1
    p = AdaptiveChunkPolicy(levels=(8, 4, 8, 16))
    assert p.levels == (4, 8, 16)
    assert p.compile_levels == (1, 4, 8, 16)
    assert AdaptiveChunkPolicy().compile_levels == DEFAULT_LEVELS


def test_policy_calm_runs_top_level():
    p = AdaptiveChunkPolicy()
    sig = ChunkSignals(tick=0, queue_depth=0, free_slots=2,
                       min_active_slack=7)          # no waiter: slack idle
    assert p.cap(sig) is None
    assert p.next_ticks(sig) == DEFAULT_LEVELS[-1]


def test_policy_rounds_down_never_overshoots_the_cap():
    """For every cap the pick is the largest level <= cap (the boundary
    lands at or before the slot-free event / SLO edge), bottoming out at
    the smallest level."""
    p = AdaptiveChunkPolicy()
    for slack in range(1, 40):
        sig = ChunkSignals(tick=0, queue_depth=1, min_active_slack=slack)
        t = p.next_ticks(sig)
        assert t in p.levels
        assert t <= max(slack, p.levels[0])
        # largest such level: the next one up would overshoot
        bigger = [l for l in p.levels if t < l <= slack]
        assert not bigger


def test_policy_queue_must_be_hot_for_slack_cap():
    p = AdaptiveChunkPolicy(hot_queue=2)
    sig1 = ChunkSignals(tick=0, queue_depth=1, min_active_slack=3)
    sig2 = ChunkSignals(tick=0, queue_depth=2, min_active_slack=3)
    assert p.next_ticks(sig1) == p.levels[-1]       # 1 waiter: not hot yet
    assert p.next_ticks(sig2) == 2                  # hot: round 3 down to 2


def test_policy_arrival_cap_and_busy_slot_shift():
    p = AdaptiveChunkPolicy()
    # a slot is free: land the boundary exactly at the scheduled arrival
    sig = ChunkSignals(tick=0, queue_depth=0, free_slots=1,
                       next_arrival_in=6)
    assert p.cap(sig) == 6 and p.next_ticks(sig) == 4
    # no slot free: a boundary at the arrival is a wasted sync — the
    # target shifts out to the slot-free event (the later of the two)
    sig = ChunkSignals(tick=0, queue_depth=0, free_slots=0,
                       min_active_slack=10, next_arrival_in=6)
    assert p.cap(sig) == 10
    sig = ChunkSignals(tick=0, queue_depth=0, free_slots=0,
                       min_active_slack=3, next_arrival_in=6)
    assert p.cap(sig) == 6                          # arrival is the later


def test_policy_slo_headroom_caps_and_min_of_caps_wins():
    p = AdaptiveChunkPolicy()
    sig = ChunkSignals(tick=0, queue_depth=1, min_active_slack=12,
                       slo_headroom=3, next_arrival_in=9)
    assert p.cap(sig) == 3 and p.next_ticks(sig) == 2
    # caps clamp at 1: a blown target shrinks to the smallest level,
    # never to zero
    sig = ChunkSignals(tick=5, queue_depth=1, min_active_slack=0,
                       slo_headroom=-4)
    assert p.cap(sig) == 1 and p.next_ticks(sig) == 1


def test_percentiles_empty_safe():
    assert percentiles([]) == {"p50": 0.0, "p99": 0.0}
    out = percentiles([2.0, 4.0], qs=(50,))
    assert out == {"p50": 3.0}


# ---------------------------------------------------------------------------
# Request soft-SLO accounting
# ---------------------------------------------------------------------------

def test_request_slo_accounting_properties():
    r = _req(0, arrival=2, ttft_target_ticks=3, tpot_target_ticks=2)
    assert r.ttft_ticks is None and not r.ttft_missed       # not terminal yet
    r.admitted_at = 8
    assert r.ttft_ticks == 6 and r.ttft_missed              # 6 > 3
    r.finished_at = 18
    r.tokens = np.zeros(3, np.int32)
    assert r.tpot_ticks == pytest.approx(5.0) and r.tpot_missed
    ok = _req(1, arrival=0, ttft_target_ticks=4, tpot_target_ticks=6)
    ok.admitted_at, ok.finished_at = 4, 10
    ok.tokens = np.zeros(4, np.int32)
    assert not ok.ttft_missed and not ok.tpot_missed
    # terminal without ever holding a slot: a set TTFT target counts missed
    never = _req(2, ttft_target_ticks=4)
    assert not never.ttft_missed                            # still queued
    never.status = RequestStatus.EXPIRED
    assert never.ttft_missed
    # no targets: nothing ever counts as missed
    plain = _req(3)
    plain.status = RequestStatus.FINISHED
    assert not plain.ttft_missed and not plain.tpot_missed


# ---------------------------------------------------------------------------
# Scheduler: priority classes + aging
# ---------------------------------------------------------------------------

def test_scheduler_priority_orders_admission_fifo_within_class():
    pool = PagePool(num_pages=64, page_size=4)
    sch = Scheduler(pool)
    sch.submit(_req(0, priority=2))
    sch.submit(_req(1, priority=0))
    sch.submit(_req(2, priority=1))
    sch.submit(_req(3, priority=0))                 # same class as rid 1
    assert [r.rid for r in sch.waiting] == [1, 3, 2, 0]
    got = sch.admit(tick=0, free_slots=4)
    assert [r.rid for r in got] == [1, 3, 2, 0]     # class, then submit order


def test_scheduler_default_priorities_reduce_to_arrival_fifo():
    """All-priority-0 traffic under the aging scheduler admits in exactly
    the PR-8 arrival-FIFO order, tick by tick."""
    rng = np.random.default_rng(11)
    pool = PagePool(num_pages=64, page_size=4)
    sch = Scheduler(pool, aging_ticks=8)
    reqs = [_req(rid, arrival=int(rng.integers(0, 6))) for rid in range(12)]
    for r in reqs:
        sch.submit(r)
    order = []
    for tick in range(8):
        order += [r.rid for r in sch.admit(tick, free_slots=2)]
    ref = [r.rid for r in sorted(reqs, key=lambda r: r.arrival)]
    assert order == ref


def test_scheduler_aging_bounds_low_priority_wait():
    """A priority-p waiter undercuts an endless stream of fresh
    priority-0 arrivals within (p+1)*aging_ticks — the starvation-freedom
    bound.  With aging disabled the same trace starves it."""
    for aging, expect_admitted in ((4, True), (None, False)):
        pool = PagePool(num_pages=256, page_size=4)
        sch = Scheduler(pool, aging_ticks=aging)
        victim = _req(0, priority=3)
        sch.submit(victim)
        admitted_at = None
        rid = 1
        for tick in range(40):                       # 2x the aging bound
            sch.submit(_req(rid, arrival=tick))      # sustained prio-0 load
            rid += 1
            for r in sch.admit(tick, free_slots=1):  # slot frees every tick
                if r.rid == 0:
                    admitted_at = tick
        if expect_admitted:
            assert admitted_at is not None
            assert admitted_at <= (victim.priority + 1) * aging
        else:
            assert admitted_at is None               # starved: aging off


def test_scheduler_effective_priority_math_and_head():
    pool = PagePool(num_pages=64, page_size=4)
    sch = Scheduler(pool, aging_ticks=5)
    old = _req(0, priority=2, arrival=0)
    fresh = _req(1, priority=0, arrival=14)
    sch.submit(old), sch.submit(fresh)
    assert sch.effective_priority(old, tick=4) == 2        # < one period
    assert sch.effective_priority(old, tick=5) == 1
    assert sch.effective_priority(old, tick=14) == 0       # ties with fresh
    # tie at equal effective priority: static queue position wins (fresh
    # prio-0 sorts ahead of a prio-2), so the victim needs to UNDERCUT
    assert sch.effective_head(14).rid == 1
    assert sch.effective_head(15).rid == 0                 # now -1 < 0
    # unarrived requests are invisible to the effective head
    assert sch.effective_head(13).rid == 0
    with pytest.raises(ValueError, match="aging_ticks"):
        Scheduler(pool, aging_ticks=0)


def test_scheduler_effective_head_of_line_blocks_lower_classes():
    """When the most-urgent arrived waiter does not fit the pool, nothing
    behind it is admitted either — skipping ahead would starve it."""
    pool = PagePool(num_pages=5, page_size=4)              # 4 usable pages
    sch = Scheduler(pool)
    sch.submit(_req(0, priority=0, plen=10, max_new=6))    # 4 pages
    sch.submit(_req(1, priority=1, plen=2, max_new=2))     # 1 page
    pool.alloc(8)                                          # 2 pages taken
    assert sch.admit(tick=0, free_slots=2) == []           # head blocks all


def test_scheduler_same_tick_mixed_priority_reservation():
    """Same-tick admissions reserve pages against each other in
    effective-priority order: the reservation-conservation invariant
    survives the priority reordering."""
    pool = PagePool(num_pages=5, page_size=4)              # 4 usable pages
    sch = Scheduler(pool)
    sch.submit(_req(0, priority=2, plen=8, max_new=4))     # 3 pages
    sch.submit(_req(1, priority=0, plen=8, max_new=4))     # 3 pages
    sch.submit(_req(2, priority=1, plen=8, max_new=4))     # 3 pages
    got = sch.admit(tick=0, free_slots=3)
    assert [r.rid for r in got] == [1]                     # 3 + 3 > 4 blocks
    assert sum(pool.pages_for(r.budget_tokens) for r in got) \
        <= pool.free_pages


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_property_priority_admission_conserves_reservations(seed):
    """The PR-8 reservation fuzz re-proven under priority-ordered
    admission with aging: random submit/admit/retire traffic with random
    priority classes never over-reserves the pool, and retirement
    returns exactly the reserved pages (eviction-freedom intact)."""
    rng = np.random.default_rng(seed)
    pool = PagePool(num_pages=9, page_size=4)
    aging = [None, 2, 8][int(rng.integers(3))]
    sch = Scheduler(pool, aging_ticks=aging)
    live, rid = [], 0
    for tick in range(30):
        for _ in range(int(rng.integers(0, 3))):
            sch.submit(Request(
                rid=rid, prompt=np.zeros(int(rng.integers(1, 12)), np.int32),
                max_new=int(rng.integers(1, 8)), arrival=tick,
                priority=int(rng.integers(0, 4))))
            rid += 1
        got = sch.admit(tick, free_slots=4 - len(live))
        assert len(got) <= 4 - len(live)
        assert sum(pool.pages_for(r.budget_tokens) for r in got) \
            <= pool.free_pages
        for r in got:
            pages = pool.alloc(r.budget_tokens)            # cannot raise
            live.append((r, pages))
        keep = []
        for r, pages in live:
            if rng.integers(2):
                before = pool.free_pages
                sch.retire(r, pages, tick)
                assert pool.free_pages == before + len(pages)
            else:
                keep.append((r, pages))
        live = keep
    for r, pages in live:
        sch.retire(r, pages, tick)
    assert pool.free_pages == pool.num_pages - 1


# ---------------------------------------------------------------------------
# Engine: submit validation + SLO plumbing
# ---------------------------------------------------------------------------

def test_engine_validates_slo_submit_args_and_aging():
    cfg, params, _ = _smoke()
    with pytest.raises(ValueError, match="aging_ticks"):
        ServingEngine(params, cfg, num_slots=1, page_size=4,
                      max_seq_len=16, aging_ticks=0)
    eng = ServingEngine(params, cfg, num_slots=1, page_size=4,
                        max_seq_len=16, aging_ticks=7)
    assert eng.scheduler.aging_ticks == 7                  # threaded through
    p = np.zeros(4, np.int32)
    with pytest.raises(ValueError, match="ttft_target_ticks"):
        eng.submit(p, 2, ttft_target_ticks=0)
    with pytest.raises(ValueError, match="tpot_target_ticks"):
        eng.submit(p, 2, tpot_target_ticks=0)
    rid = eng.submit(p, 2, priority=3, ttft_target_ticks=5,
                     tpot_target_ticks=4)
    req = eng.requests[rid]
    assert (req.priority, req.ttft_target_ticks, req.tpot_target_ticks) \
        == (3, 5, 4)


def test_adaptive_boundary_lands_at_slot_free_event():
    """The deterministic core of the tentpole: one busy slot, one arrived
    waiter.  Fixed tps=16 strands the waiter until tick 16; the adaptive
    ladder walks 4 -> 1 and lands the boundary exactly at tick 5, where
    the first stream's budget frees the slot (its first token came from
    the admission prefill, leaving 5 decode ticks) — and neither
    stream's tokens move."""
    cfg, params, _ = _smoke()
    rng = np.random.default_rng(3)
    p0 = rng.integers(0, cfg.vocab, size=5).astype(np.int32)
    p1 = rng.integers(0, cfg.vocab, size=7).astype(np.int32)

    def run(policy):
        eng = ServingEngine(params, cfg, num_slots=1, page_size=4,
                            max_seq_len=16, ticks_per_sync=16,
                            chunk_policy=policy)
        eng.submit(p0, 6)
        eng.submit(p1, 3)
        return eng, eng.run()

    fixed_eng, fixed = run(None)
    adapt_eng, adapt = run(AdaptiveChunkPolicy())
    assert fixed[1].admitted_at == 16                      # chunk-grid TTFT
    assert adapt[1].admitted_at == 5                       # exact slot-free
    assert adapt_eng.chunk_shrinks >= 1
    assert set(adapt_eng.chunks_by_ticks) <= \
        set(adapt_eng.chunk_policy.compile_levels)
    for rid, (p, g) in enumerate(((p0, 6), (p1, 3))):
        np.testing.assert_array_equal(adapt[rid].tokens,
                                      _solo(cfg, params, p, g))
        np.testing.assert_array_equal(fixed[rid].tokens, adapt[rid].tokens)
    stats = adapt_eng.slo_stats()
    assert stats["adaptive"] == 1 and stats["chunk_shrinks"] >= 1


def test_slo_stats_shape_and_per_priority_classes():
    cfg, params, _ = _smoke()
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, size=5).astype(np.int32)
               for _ in range(4)]
    eng = ServingEngine(params, cfg, num_slots=2, page_size=4,
                        max_seq_len=16, ticks_per_sync=8,
                        chunk_policy=AdaptiveChunkPolicy())
    for i, p in enumerate(prompts):
        eng.submit(p, 4, arrival=2 * i, priority=i % 2,
                   ttft_target_ticks=4 if i % 2 == 0 else None)
    done = eng.run()
    stats = eng.slo_stats()
    assert stats["adaptive"] == 1
    assert stats["chunk_levels"] == list(DEFAULT_LEVELS)
    assert set(stats["chunks_by_ticks"]) <= set(DEFAULT_LEVELS)
    assert sum(stats["chunks_by_ticks"].values()) >= 1
    assert set(stats["by_priority"]) == {0, 1}
    for cls in stats["by_priority"].values():
        assert cls["requests"] == 2
        assert cls["ttft_ticks_p50"] <= cls["ttft_ticks_p99"]
        assert cls["tpot_ticks_mean"] >= 0.0
    # miss counters recompute from the terminal requests exactly
    assert stats["ttft_target_misses"] == \
        sum(int(r.ttft_missed) for r in done.values())
    assert stats["tpot_target_misses"] == \
        sum(int(r.tpot_missed) for r in done.values())
    # a fixed engine reports its single configured level
    fixed = ServingEngine(params, cfg, num_slots=2, page_size=4,
                          max_seq_len=16, ticks_per_sync=4)
    s = fixed.slo_stats()
    assert s["adaptive"] == 0 and s["chunk_levels"] == [4]


# ---------------------------------------------------------------------------
# Tentpole property: policy invariance — streams never move
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_property_streams_bitmatch_across_policies(seed):
    """Seeded random arrival traces: every request's token stream is
    bit-identical across fixed ticks_per_sync 1/4/16 and the adaptive
    policy, and across priority reorderings — for dense and packed
    params (sampled per trace).  One randomly chosen stream per trace is
    additionally pinned to its solo decode, anchoring the whole
    equivalence class to the ground truth."""
    cfg, dense, packed = _smoke()
    rng = np.random.default_rng(seed)
    params = (dense, packed)[int(rng.integers(2))]
    n = int(rng.integers(3, 5))
    lens = [int(rng.choice([5, 7])) for _ in range(n)]     # 2 prefill buckets
    gens = [int(rng.integers(2, 6)) for _ in range(n)]
    arrivals = sorted(int(a) for a in rng.integers(0, 10, size=n))
    prios = [int(rng.integers(0, 3)) for _ in range(n)]
    ttfts = [int(rng.integers(4, 20)) if rng.integers(2) else None
             for _ in range(n)]
    prompts = [rng.integers(0, cfg.vocab, size=l).astype(np.int32)
               for l in lens]

    def serve(policy_kw, order):
        eng = ServingEngine(params, cfg, num_slots=2, page_size=4,
                            max_seq_len=16, **policy_kw)
        for i in range(n):
            eng.submit(prompts[i], gens[i], arrival=arrivals[i],
                       priority=order[i], ttft_target_ticks=ttfts[i])
        done = eng.run()
        assert all(r.status is RequestStatus.FINISHED for r in done.values())
        return {r: tuple(int(t) for t in done[r].tokens) for r in done}, eng

    base, _ = serve(dict(ticks_per_sync=1), prios)
    for tps in (4, 16):
        got, _ = serve(dict(ticks_per_sync=tps), prios)
        assert got == base, f"fixed tps={tps} moved a stream"
    adapt, eng = serve(dict(ticks_per_sync=16,
                            chunk_policy=AdaptiveChunkPolicy()), prios)
    assert adapt == base, "adaptive policy moved a stream"
    assert set(eng.chunks_by_ticks) <= set(eng.chunk_policy.compile_levels)
    flipped, _ = serve(dict(ticks_per_sync=16,
                            chunk_policy=AdaptiveChunkPolicy()),
                       [2 - p for p in prios])
    assert flipped == base, "priority reordering moved a stream"
    # anchor one stream to the ground truth solo decode
    pick = int(rng.integers(n))
    np.testing.assert_array_equal(
        np.asarray(base[pick], np.int32),
        _solo(cfg, params, prompts[pick], gens[pick]))


# ---------------------------------------------------------------------------
# Satellite: the recompile contract, proven with CompileTracker counters
# ---------------------------------------------------------------------------

def test_adaptive_policy_compiles_only_declared_levels():
    """Adaptive bursty traffic compiles at most len(compile_levels)
    _decode_chunk variants on first contact, and an identical second
    engine run compiles NOTHING (jit-cache hit for every chunk length the
    policy picks) — the CompileTracker-backed recompile regression."""
    from repro.analysis import runtime as analysis_runtime

    cfg, params, _ = _smoke()
    rng = np.random.default_rng(9)
    policy = AdaptiveChunkPolicy(levels=(1, 2, 4, 8))
    PLEN, GEN = 6, 4

    def build():
        return ServingEngine(params, cfg, num_slots=2, page_size=4,
                             max_seq_len=16, ticks_per_sync=8,
                             chunk_policy=policy, prefix_caching=False)

    def traffic(eng):
        for i in range(6):
            eng.submit(rng.integers(0, cfg.vocab,
                                    size=PLEN).astype(np.int32),
                       GEN, arrival=3 * i, priority=i % 2)

    warm = build()
    before = warm.analysis_stats()["compile_caches"]["_decode_chunk"]
    traffic(warm)
    assert len(warm.run()) == 6
    after = warm.analysis_stats()["compile_caches"]["_decode_chunk"]
    grew = after - before
    assert grew <= len(policy.compile_levels), \
        f"adaptive traffic compiled {grew} chunk variants, " \
        f"declared only {policy.compile_levels}"
    assert warm.chunk_shrinks >= 1                  # the trace really adapted
    assert set(warm.chunks_by_ticks) <= set(policy.compile_levels)

    eng = build()
    traffic(eng)
    snap = eng.analysis_stats()
    assert len(eng.run()) == 6
    out = eng.analysis_stats()
    assert out["compile_caches"] == snap["compile_caches"], \
        "second adaptive run recompiled a chunk variant"
    assert out["compile_events"] == snap["compile_events"], \
        "something compiled during the second adaptive run"
    assert eng.chunks_by_ticks == warm.chunks_by_ticks  # deterministic policy
