"""End-to-end behaviour tests: the paper's full flow on CPU scale.

1. train a paper benchmark model (jets) to baseline accuracy,
2. iteratively prune with the MDKP (DSP-aware and multi-dimensional),
3. pack surviving weights to BSR and serve through the zero-skipping
   kernel path, verifying (a) identical outputs, (b) resource reductions
   in the model's own accounting, mirroring paper Tables II/V.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BlockingSpec,
    IterativePruner,
    PruneConfig,
    TPUResourceModel,
    apply_masks,
    build_structures,
    constant_step,
    init_masks,
    pack_bsr,
)
from repro.data import JetsTask
from repro.kernels import bsr_matmul
from repro.models.cnn import init_jets_mlp, jets_mlp_forward
from tests.test_pruner import _accuracy, _train


@pytest.fixture(scope="module")
def pruned_jets():
    task = JetsTask()
    params = init_jets_mlp(jax.random.PRNGKey(0))
    st = build_structures(params, BlockingSpec(bk=8, bn=8), min_size=256)
    params = _train(params, init_masks(params, st), task, 150)
    pruner = IterativePruner(
        st, TPUResourceModel(precision="bf16"),
        PruneConfig(schedule=constant_step([0.5, 0.5], 0.25), tolerance=0.05),
    )
    val = task.batch(9999, 2048)
    params, masks, logs = pruner.run(
        params,
        lambda p, m: _train(p, m, task, 40),
        lambda p, m: _accuracy(p, m, val),
    )
    return params, masks, logs, st, task


def test_e2e_resource_reduction(pruned_jets):
    _, _, logs, _, _ = pruned_jets
    assert logs
    red = logs[-1].reduction()
    # paper Table II: multi-x reductions in both resources at tolerance
    assert red[0] > 1.5 and red[1] > 1.5, red


def test_e2e_bsr_serving_matches_masked_dense(pruned_jets):
    """§III-C codegen equivalence: serving through the BSR kernel equals
    the masked-dense reference on every layer."""
    params, masks, _, st, task = pruned_jets
    x, _ = task.batch(123, 64)
    mp = apply_masks(params, masks)

    act = x
    for i, name in enumerate(["fc_1", "fc_2", "fc_3", "fc_4"]):
        w = np.asarray(mp[name]["kernel"])
        m = masks[name]["kernel"]   # fc_4 < min_size => no mask (kept dense)
        bsr = pack_bsr(np.asarray(params[name]["kernel"]), BlockingSpec(bk=8, bn=8),
                       mask=None if m is None else np.asarray(m))
        y_bsr = bsr_matmul(act, bsr) + mp[name]["bias"]
        y_ref = act @ w + mp[name]["bias"]
        np.testing.assert_allclose(np.asarray(y_bsr), np.asarray(y_ref),
                                   atol=1e-4, rtol=1e-4)
        act = jax.nn.relu(y_ref) if i < 3 else y_ref

    # density actually dropped (pruned tiles are skipped, not multiplied)
    total_density = np.mean([
        pack_bsr(np.asarray(params[n]["kernel"]), BlockingSpec(bk=8, bn=8),
                 mask=np.asarray(masks[n]["kernel"])).density()
        for n in ["fc_1", "fc_2", "fc_3"]
    ])
    assert total_density < 0.75, total_density


def test_e2e_accuracy_within_tolerance(pruned_jets):
    params, masks, logs, st, task = pruned_jets
    val = task.batch(9999, 2048)
    acc = _accuracy(params, masks, val)
    assert acc > 0.80, acc
