"""Fault-tolerant serving (DESIGN.md §13): lifecycle, backpressure,
quarantine, crash-consistent stepping, and the chaos harness.

The load-bearing property here mirrors the engine's bit-identity
guarantee from test_serving_engine.py, under faults: whatever happens to
one request — NaN quarantine, cancellation, deadline expiry, a crash at
the chunk boundary — every OTHER co-batched stream must keep emitting
exactly the tokens it would emit decoded alone, and the page pool must
conserve pages exactly (never leak, never double-free).
"""
import numpy as np
import pytest

from repro.serving import (
    AdaptiveChunkPolicy,
    FaultInjector,
    InjectedFault,
    Request,
    RequestStatus,
    Scheduler,
    ServingEngine,
    TERMINAL_STATUSES,
    alloc_failure,
    chunk_exception,
    index_corruption,
    nan_logit,
)
from test_serving_engine import _smoke_pair, _solo


@pytest.fixture(scope="module")
def smoke():
    return _smoke_pair()


def _prompts(rng, cfg, lens):
    return [rng.integers(0, cfg.vocab, size=l).astype(np.int32)
            for l in lens]


def _pool_conserved(eng):
    """Exact refcount accounting: every pool reference is attributable
    to an active slot's table or the prefix-index ledger, and the free
    list holds exactly the rest."""
    refs = {}
    for s in eng.slots:
        if s is not None:
            for p in s.pages:
                refs[p] = refs.get(p, 0) + 1
    if eng.prefix_index is not None:
        for p, c in eng.prefix_index._owned.items():
            refs[p] = refs.get(p, 0) + c
    for p in range(1, eng.pool.num_pages):
        assert eng.pool.refcount(p) == refs.get(p, 0), \
            f"page {p}: pool says {eng.pool.refcount(p)}, " \
            f"slots+ledger say {refs.get(p, 0)}"
    assert eng.pool.free_pages == (eng.pool.num_pages - 1) - len(refs)


# ---------------------------------------------------------------------------
# Satellite: submit-time validation + bounded-queue backpressure
# ---------------------------------------------------------------------------

def test_submit_rejects_out_of_range_token_ids(smoke):
    cfg, params, _ = smoke
    eng = ServingEngine(params, cfg, num_slots=2, page_size=4,
                        max_seq_len=16)
    bad = np.array([1, 2, cfg.vocab, 3], np.int32)
    with pytest.raises(ValueError, match=f"id {cfg.vocab} at position 2"):
        eng.submit(bad, 4)
    with pytest.raises(ValueError, match="id -1 at position 0"):
        eng.submit(np.array([-1, 2], np.int32), 4)
    # a rejected submit consumes nothing: no rid, no queue entry
    assert not eng.requests and eng.scheduler.pending == 0


def test_bounded_queue_rejects_over_capacity(smoke):
    cfg, params, _ = smoke
    rng = np.random.default_rng(0)
    prompts = _prompts(rng, cfg, [5, 7, 6, 5])
    eng = ServingEngine(params, cfg, num_slots=1, page_size=4,
                        max_seq_len=16, max_queue=2)
    rids = [eng.submit(p, 3) for p in prompts]
    # slot admission happens at step time, so all 4 queue-or-reject now:
    # 2 fit the bounded queue, 2 are REJECTED terminally
    statuses = [eng.requests[r].status for r in rids]
    assert statuses[:2] == [RequestStatus.QUEUED] * 2
    assert statuses[2:] == [RequestStatus.REJECTED] * 2
    for r in rids[2:]:
        assert eng.requests[r].terminal
        assert len(eng.requests[r].tokens) == 0
        assert "queue full" in eng.requests[r].status_reason
    stats = eng.fault_stats
    assert stats["rejected"] == 2 and stats["max_queue"] == 2
    assert stats["queue_depth"] == 2 == stats["queue_high_water"]
    # the queued requests serve normally and bit-match solo
    done = eng.run()
    assert len(done) == 4
    for r, p in zip(rids[:2], prompts[:2]):
        assert done[r].status is RequestStatus.FINISHED
        np.testing.assert_array_equal(done[r].tokens, _solo(cfg, params, p, 3))
    _pool_conserved(eng)


# ---------------------------------------------------------------------------
# Tentpole: cancellation + deadlines
# ---------------------------------------------------------------------------

def test_cancel_waiting_and_active(smoke):
    cfg, params, _ = smoke
    rng = np.random.default_rng(1)
    prompts = _prompts(rng, cfg, [5, 9, 7])
    eng = ServingEngine(params, cfg, num_slots=2, page_size=4,
                        max_seq_len=16, ticks_per_sync=2)
    r0 = eng.submit(prompts[0], 6)
    r1 = eng.submit(prompts[1], 6)
    r2 = eng.submit(prompts[2], 6, arrival=50)       # stays waiting
    # waiting cancel: immediate, no tokens, queue entry gone
    assert eng.cancel(r2) is RequestStatus.CANCELLED
    assert eng.requests[r2].terminal
    assert len(eng.requests[r2].tokens) == 0
    assert eng.scheduler.pending == 2
    eng.step()                                       # admit r0/r1, 2 ticks
    # active cancel: pending until the chunk boundary, then honored with
    # the partial stream intact
    assert eng.cancel(r1) is RequestStatus.ACTIVE
    assert eng.requests[r1].status is RequestStatus.ACTIVE
    eng.step()
    req = eng.requests[r1]
    assert req.status is RequestStatus.CANCELLED
    assert 0 < len(req.tokens) < 6
    np.testing.assert_array_equal(                  # partials are correct
        req.tokens, _solo(cfg, params, prompts[1], 6)[:len(req.tokens)])
    _pool_conserved(eng)                            # release was refcount-exact
    # cancelling a terminal request is a no-op
    assert eng.cancel(r1) is RequestStatus.CANCELLED
    assert eng.fault_stats["cancelled"] == 2
    with pytest.raises(KeyError):
        eng.cancel(999)
    # the survivor never noticed: bit-identical to its solo decode
    done = eng.run()
    np.testing.assert_array_equal(done[r0].tokens,
                                  _solo(cfg, params, prompts[0], 6))
    # prefix-index entries outlive the cancelled request (readmit reuse):
    # dropping the cache must still drain the pool exactly
    eng.release_prefix_cache()
    assert eng.pool.free_pages == eng.pool.num_pages - 1


def test_deadline_expires_waiting_and_active(smoke):
    cfg, params, _ = smoke
    rng = np.random.default_rng(2)
    prompts = _prompts(rng, cfg, [5, 9])
    eng = ServingEngine(params, cfg, num_slots=1, page_size=4,
                        max_seq_len=16, ticks_per_sync=2)
    # one slot: r1 waits behind r0; r0's deadline aborts it mid-stream,
    # r1's deadline passes while it is still queued
    r0 = eng.submit(prompts[0], 10, deadline_ticks=5)
    r1 = eng.submit(prompts[1], 7, deadline_ticks=3)
    done = eng.run()
    assert done[r0].status is RequestStatus.EXPIRED
    assert 0 < len(done[r0].tokens) < 10            # partial stream kept
    np.testing.assert_array_equal(
        done[r0].tokens,
        _solo(cfg, params, prompts[0], 10)[:len(done[r0].tokens)])
    assert done[r1].status is RequestStatus.EXPIRED
    assert len(done[r1].tokens) == 0                # never held a slot
    assert "queued" in done[r1].status_reason
    assert eng.fault_stats["expired"] == 2
    _pool_conserved(eng)
    with pytest.raises(ValueError, match="deadline_ticks"):
        eng.submit(prompts[0], 2, deadline_ticks=0)


# ---------------------------------------------------------------------------
# Tentpole: NaN quarantine — the pinned fault-isolation property
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["dense", "packed"])
def test_nan_guard_quarantines_only_poisoned_row(smoke, kind):
    """Poison one request's K/V pages mid-stream: the guard must fail
    ONLY that row (terminal FAILED, partial pre-poison tokens correct,
    pages freed and purged from the prefix index) while every co-batched
    stream stays bit-identical to its solo decode — for dense AND
    packed-BSR params."""
    cfg, dense, packed = smoke
    params = dense if kind == "dense" else packed
    rng = np.random.default_rng(3)
    prompts = _prompts(rng, cfg, [5, 9, 7])
    inj = FaultInjector([nan_logit(2, rid=1)], seed=0)
    eng = ServingEngine(params, cfg, num_slots=3, page_size=4,
                        max_seq_len=16, ticks_per_sync=2,
                        fault_injector=inj)
    for p in prompts:
        eng.submit(p, 6)
    done = eng.run()
    assert not inj.pending
    assert done[1].status is RequestStatus.FAILED
    assert "non-finite" in done[1].status_reason
    # tokens emitted BEFORE the poison are clean: a solo-stream prefix
    solo1 = _solo(cfg, params, prompts[1], 6)
    assert 0 < len(done[1].tokens) < 6
    np.testing.assert_array_equal(done[1].tokens,
                                  solo1[:len(done[1].tokens)])
    # fault isolation: the other rows never noticed
    for r in (0, 2):
        assert done[r].status is RequestStatus.FINISHED
        np.testing.assert_array_equal(done[r].tokens,
                                      _solo(cfg, params, prompts[r], 6))
    stats = eng.fault_stats
    assert stats["failed"] == 1 and stats["guard_trips"] == 1
    # quarantined pages left the prefix index too: nothing in the cache
    # can hand poisoned K/V to a later admission, and the pool conserves
    _pool_conserved(eng)
    eng.release_prefix_cache()
    assert eng.pool.free_pages == eng.pool.num_pages - 1


def test_nan_guard_off_reproduces_unguarded_path(smoke):
    """nan_guard=False compiles the PR-7 chunk (no finite checks): clean
    traffic must serve identically — this is the bench baseline."""
    cfg, params, _ = smoke
    rng = np.random.default_rng(4)
    prompts = _prompts(rng, cfg, [5, 9])
    eng = ServingEngine(params, cfg, num_slots=2, page_size=4,
                        max_seq_len=16, ticks_per_sync=2, nan_guard=False)
    rids = [eng.submit(p, 6) for p in prompts]
    done = eng.run()
    for r, p in zip(rids, prompts):
        np.testing.assert_array_equal(done[r].tokens,
                                      _solo(cfg, params, p, 6))
    assert eng.fault_stats["nan_guard"] == 0


# ---------------------------------------------------------------------------
# Tentpole: crash-consistent stepping
# ---------------------------------------------------------------------------

def test_chunk_exception_restores_snapshot_and_degrades(smoke):
    cfg, params, _ = smoke
    rng = np.random.default_rng(5)
    prompts = _prompts(rng, cfg, [5, 9])
    inj = FaultInjector([chunk_exception(2)], seed=0)
    eng = ServingEngine(params, cfg, num_slots=2, page_size=4,
                        max_seq_len=16, ticks_per_sync=2,
                        fault_injector=inj)
    rids = [eng.submit(p, 6) for p in prompts]
    done = eng.run()
    # the crash cost wall-clock, not correctness: every stream completes
    # bit-identically to solo (the snapshot restore put every host
    # mirror back to the last committed boundary)
    for r, p in zip(rids, prompts):
        assert done[r].status is RequestStatus.FINISHED
        np.testing.assert_array_equal(done[r].tokens,
                                      _solo(cfg, params, p, 6))
    stats = eng.fault_stats
    assert stats["chunk_failures"] == 1
    assert stats["degraded"] == 1
    assert eng.ticks_per_sync == 1                  # smallest replayable unit
    assert eng.configured_ticks_per_sync == 2
    assert "InjectedFault" in eng.last_chunk_error
    _pool_conserved(eng)


def test_repeated_chunk_failures_give_up_loudly(smoke):
    cfg, params, _ = smoke
    rng = np.random.default_rng(6)
    [p] = _prompts(rng, cfg, [5])
    inj = FaultInjector([chunk_exception(t) for t in range(40)], seed=0)
    eng = ServingEngine(params, cfg, num_slots=1, page_size=4,
                        max_seq_len=16, max_chunk_failures=3,
                        fault_injector=inj)
    eng.submit(p, 8)
    with pytest.raises(RuntimeError, match="consecutive decode-chunk"):
        eng.run()
    assert eng.fault_stats["chunk_failures"] == 4   # 3 tolerated + final


# ---------------------------------------------------------------------------
# Tentpole: prefix-index self-check + alloc-failure unwinding
# ---------------------------------------------------------------------------

def test_index_corruption_detected_dropped_and_served_through(smoke):
    cfg, params, _ = smoke
    rng = np.random.default_rng(7)
    prompts = _prompts(rng, cfg, [9, 9, 7])
    inj = FaultInjector([index_corruption(3)], seed=0)
    eng = ServingEngine(params, cfg, num_slots=2, page_size=4,
                        max_seq_len=16, ticks_per_sync=2,
                        fault_injector=inj)
    rids = [eng.submit(p, 6, arrival=a)
            for p, a in zip(prompts, (0, 0, 6))]
    done = eng.run()
    assert [k for k, _, _ in inj.fired] == ["index_corrupt"]
    assert eng.fault_stats["index_drops"] == 1
    # serving continued (merely uncached) and every stream is exact
    for r, p in zip(rids, prompts):
        assert done[r].status is RequestStatus.FINISHED
        np.testing.assert_array_equal(done[r].tokens,
                                      _solo(cfg, params, p, 6))
    # the drop released by ledger: conservation is exact even though an
    # entry's page field was scrambled when the cache was released
    _pool_conserved(eng)
    eng.release_prefix_cache()
    assert eng.pool.free_pages == eng.pool.num_pages - 1


def test_alloc_failure_unwinds_and_retries(smoke):
    cfg, params, _ = smoke
    rng = np.random.default_rng(8)
    prompts = _prompts(rng, cfg, [5, 7])
    inj = FaultInjector([alloc_failure(0, count=2)], seed=0)
    eng = ServingEngine(params, cfg, num_slots=2, page_size=4,
                        max_seq_len=16, fault_injector=inj)
    rids = [eng.submit(p, 6) for p in prompts]
    done = eng.run()
    assert eng.fault_stats["alloc_failures"] == 2
    # both admissions were unwound (no leaked refs) and re-admitted in
    # their original order on later ticks
    assert done[rids[0]].admitted_at <= done[rids[1]].admitted_at
    for r, p in zip(rids, prompts):
        assert done[r].status is RequestStatus.FINISHED
        np.testing.assert_array_equal(done[r].tokens,
                                      _solo(cfg, params, p, 6))
    _pool_conserved(eng)


# ---------------------------------------------------------------------------
# Chaos x SLO interplay (DESIGN.md §15): faults mid-adaptive-chunk
# ---------------------------------------------------------------------------

def test_lifecycle_faults_fire_mid_adaptive_chunk(smoke):
    """Deadline expiry, cancellation and REJECTED backpressure all fire
    correctly while the adaptive policy is varying chunk lengths: the
    terminal statuses land, the survivors stay bit-identical to solo,
    the pool conserves, and every committed chunk length came from the
    policy's declared compile set."""
    cfg, params, _ = smoke
    rng = np.random.default_rng(31)
    prompts = _prompts(rng, cfg, [5, 7, 6, 5, 5])
    eng = ServingEngine(params, cfg, num_slots=2, page_size=4,
                        max_seq_len=16, ticks_per_sync=16,
                        chunk_policy=AdaptiveChunkPolicy(), max_queue=4)
    r0 = eng.submit(prompts[0], 8)                       # survivor
    r1 = eng.submit(prompts[1], 8, deadline_ticks=4)     # expires mid-stream
    r2 = eng.submit(prompts[2], 6, arrival=2)            # cancelled queued
    r3 = eng.submit(prompts[3], 6, arrival=3)            # survivor, late
    r4 = eng.submit(prompts[4], 4)                       # over max_queue
    assert eng.requests[r4].status is RequestStatus.REJECTED
    assert eng.cancel(r2) is RequestStatus.CANCELLED
    done = eng.run()
    assert done[r1].status is RequestStatus.EXPIRED
    assert len(done[r1].tokens) < 8                      # cut mid-stream
    np.testing.assert_array_equal(                       # partials correct
        done[r1].tokens,
        _solo(cfg, params, prompts[1], 8)[:len(done[r1].tokens)])
    for r, g in ((r0, 8), (r3, 6)):
        assert done[r].status is RequestStatus.FINISHED
        np.testing.assert_array_equal(
            done[r].tokens,
            _solo(cfg, params, eng.requests[r].prompt, g))
    stats = eng.fault_stats
    assert stats["rejected"] == 1 and stats["cancelled"] == 1
    assert stats["expired"] == 1
    slo = eng.slo_stats()
    assert set(slo["chunks_by_ticks"]) <= \
        set(eng.chunk_policy.compile_levels)
    # the policy really varied the chunk length around the fault events
    # (this trace caps at the scheduled arrival, then grows back calm)
    assert len(slo["chunks_by_ticks"]) >= 2
    assert slo["chunk_shrinks"] + slo["chunk_grows"] >= 1
    _pool_conserved(eng)


def test_chunk_crash_degrades_adaptive_without_deadlock(smoke):
    """A chunk exception under the adaptive policy: the degraded
    single-tick fallback OVERRIDES the policy (recovery owns the chunk
    length), the engine still drains — no deadlock between the two chunk
    deciders — every stream completes bit-identically, and slo_stats
    stays consistent (only declared levels in the histogram, tail all
    1-tick chunks)."""
    cfg, params, _ = smoke
    rng = np.random.default_rng(32)
    prompts = _prompts(rng, cfg, [5, 9])
    inj = FaultInjector([chunk_exception(2)], seed=0)
    eng = ServingEngine(params, cfg, num_slots=2, page_size=4,
                        max_seq_len=16, ticks_per_sync=16,
                        chunk_policy=AdaptiveChunkPolicy(),
                        fault_injector=inj)
    # the scheduled arrival at tick 4 caps the first chunk (a calm
    # 16-tick chunk would finish everything before the crash could fire
    # at a boundary); the second chunk's start then trips the fault
    rids = [eng.submit(p, 6, arrival=4 * i) for i, p in enumerate(prompts)]
    done = eng.run()
    for r, p in zip(rids, prompts):
        assert done[r].status is RequestStatus.FINISHED
        np.testing.assert_array_equal(done[r].tokens,
                                      _solo(cfg, params, p, 6))
    assert eng.fault_stats["chunk_failures"] == 1
    assert eng.fault_stats["degraded"] == 1
    assert eng.ticks_per_sync == 1                   # recovery's pick...
    slo = eng.slo_stats()
    assert slo["adaptive"] == 1
    assert set(slo["chunks_by_ticks"]) <= \
        set(eng.chunk_policy.compile_levels)
    assert slo["chunks_by_ticks"].get(1, 0) >= 1     # ...actually decoded
    _pool_conserved(eng)


# ---------------------------------------------------------------------------
# Satellite: property-based chaos traces — conservation under any mix
# ---------------------------------------------------------------------------

def test_property_chaos_traces_conserve_pages(smoke):
    """Randomized admit/cancel/expire/fail/crash traces: after EVERY
    engine step the page pool must balance exactly against the active
    tables plus the index ledger (never a leaked or double-freed page),
    every request must end in exactly one terminal status, and draining
    the cache must return the pool to fully free.  Half the traces run
    the adaptive chunk policy (with mixed priorities and soft SLO
    targets), so conservation is proven under varying chunk lengths
    too."""
    cfg, params, _ = smoke
    for seed in range(6):
        rng = np.random.default_rng(100 + seed)
        faults = []
        for t in sorted(rng.integers(0, 12, size=3)):
            kind = rng.choice(["nan", "alloc", "chunk", "corrupt"])
            faults.append({"nan": nan_logit(int(t)),
                           "alloc": alloc_failure(int(t)),
                           "chunk": chunk_exception(int(t)),
                           "corrupt": index_corruption(int(t))}[kind])
        inj = FaultInjector(faults, seed=seed)
        policy = AdaptiveChunkPolicy((1, 2, 4)) if seed % 2 else None
        eng = ServingEngine(params, cfg, num_slots=2, page_size=4,
                            max_seq_len=16,
                            ticks_per_sync=int(rng.choice([1, 2])),
                            max_queue=4, fault_injector=inj,
                            chunk_policy=policy)
        rids = []
        for _ in range(int(rng.integers(3, 7))):
            prompt = rng.integers(0, cfg.vocab,
                                  size=int(rng.integers(3, 10)))
            dl = (int(rng.integers(2, 15))
                  if rng.integers(3) == 0 else None)
            rids.append(eng.submit(prompt.astype(np.int32),
                                   int(rng.integers(2, 7)),
                                   arrival=int(rng.integers(0, 8)),
                                   deadline_ticks=dl,
                                   priority=int(rng.integers(0, 3)),
                                   ttft_target_ticks=(int(rng.integers(2, 10))
                                                      if rng.integers(2)
                                                      else None)))
        steps = 0
        while (eng.scheduler.pending
               or any(s is not None for s in eng.slots)
               or not all(eng.requests[r].terminal for r in rids)):
            if rng.integers(4) == 0 and rids:
                eng.cancel(int(rng.choice(rids)))
            eng.step()
            _pool_conserved(eng)                   # after EVERY step
            steps += 1
            assert steps < 200, f"trace {seed} did not converge"
        for r in rids:
            req = eng.requests[r]
            assert req.status in TERMINAL_STATUSES, (seed, r, req.status)
            assert req.tokens is not None
        eng.release_prefix_cache()
        assert eng.pool.free_pages == eng.pool.num_pages - 1, seed
        assert eng.pool.live_refs() == 0
        if policy is not None:       # adaptive traces kept the contract
            assert set(eng.chunks_by_ticks) <= set(policy.compile_levels)


# ---------------------------------------------------------------------------
# Satellite: scheduler bounded queue + ordered-insert unit coverage
# ---------------------------------------------------------------------------

def test_scheduler_bounded_queue_unit():
    from repro.serving import PagePool
    pool = PagePool(num_pages=64, page_size=4)
    sch = Scheduler(pool, max_queue=2)
    reqs = [Request(rid=i, prompt=np.arange(4, dtype=np.int32), max_new=2)
            for i in range(4)]
    assert sch.submit(reqs[0]) and sch.submit(reqs[1])
    assert not sch.submit(reqs[2]) and not sch.submit(reqs[3])
    assert [r.rid for r in sch.waiting] == [0, 1]
    assert reqs[2].status is RequestStatus.REJECTED
    assert reqs[2] in sch.finished
    # draining the queue reopens admission
    sch.admit(0, free_slots=2)
    r4 = Request(rid=4, prompt=np.arange(4, dtype=np.int32), max_new=2)
    assert sch.submit(r4)
    with pytest.raises(ValueError, match="max_queue"):
        Scheduler(pool, max_queue=0)


def test_scheduler_requeue_restores_head_position():
    from repro.serving import PagePool
    pool = PagePool(num_pages=64, page_size=4)
    sch = Scheduler(pool)
    mk = lambda rid, arr: Request(rid=rid, max_new=2, arrival=arr,
                                  prompt=np.arange(4, dtype=np.int32))
    for rid, arr in ((0, 0), (1, 0), (2, 1)):
        sch.submit(mk(rid, arr))
    got = sch.admit(1, free_slots=3)
    assert [r.rid for r in got] == [0, 1, 2]
    # alloc failed mid-batch: requeueing [1, 2] must put 1 back BEFORE
    # any later equal-arrival submit and keep batch order
    sch.submit(mk(3, 0))
    sch.requeue(got[1:])
    assert [r.rid for r in sch.waiting] == [1, 3, 2]
