"""Analyzer self-tests (DESIGN.md §14).

Every rule gets fixture snippets it must fire on (golden findings) and
clean snippets it must stay silent on; plus framework behavior —
suppression comments, rule toggles, baseline diffing, stable keys — and
positive controls for the runtime layer (compile tracking, sync-region
counting, stray-pull interception).
"""
import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import lint
from repro.analysis.rules import all_rules, rule_names

REPO_ROOT = Path(__file__).resolve().parents[1]


def _scan(tmp_path, source, enabled=None):
    """Lint one fixture module; returns (findings, inline_suppressed)."""
    f = tmp_path / "fixture.py"
    f.write_text(textwrap.dedent(source))
    index = lint.build_index(tmp_path, [tmp_path])
    enabled_set = {enabled} if isinstance(enabled, str) else enabled
    return lint.run_rules(index, all_rules(), enabled=enabled_set)


def _rules_hit(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# host-sync
# ---------------------------------------------------------------------------

def test_host_sync_in_trace_reachable_from_hot_root(tmp_path):
    """`.item()` two calls below lm_prefill is flagged via reachability."""
    findings, _ = _scan(tmp_path, """
        import jax.numpy as jnp

        def _helper(x):
            return _inner(x)

        def _inner(x):
            return x.item()

        def lm_prefill(params, caches, batch, cfg):
            return _helper(jnp.ones(3))
        """, enabled="host-sync")
    assert len(findings) == 1
    assert findings[0].symbol == "_inner"
    assert ".item()" in findings[0].message


def test_host_sync_driver_loop_flags_and_coercion_heuristic(tmp_path):
    """np.asarray + int() on jit results inside a driver loop are flagged;
    int() on config scalars is not."""
    findings, _ = _scan(tmp_path, """
        import jax, numpy as np

        @jax.jit
        def fwd(x):
            return x * 2

        def drive(xs, cfg):
            out = []
            for x in xs:
                y = fwd(x)
                out.append(np.asarray(y))       # flagged
                n = int(y[0])                   # flagged
                m = int(cfg.d_model * 4)        # static python: silent
            return out
        """, enabled="host-sync")
    assert len(findings) == 2
    assert all(f.symbol == "drive" for f in findings)


def test_host_sync_declared_sync_region_is_exempt(tmp_path):
    findings, _ = _scan(tmp_path, """
        import jax, numpy as np
        from repro.analysis.runtime import sync_region

        @jax.jit
        def fwd(x):
            return x * 2

        def drive(xs):
            out = []
            for x in xs:
                y = fwd(x)
                with sync_region("drive"):
                    out.append(np.asarray(y))   # declared: exempt
            return out
        """, enabled="host-sync")
    assert findings == []


def test_host_sync_static_argnames_not_device(tmp_path):
    """Params declared static in the jit decorator are python values."""
    findings, _ = _scan(tmp_path, """
        import functools, jax

        @functools.partial(jax.jit, static_argnames=("ticks",))
        def _decode_chunk(tok, ticks):
            n = int(ticks) + 1        # static: silent
            m = float(tok)            # traced param: flagged
            return tok * n * m
        """, enabled="host-sync")
    assert len(findings) == 1
    assert "`float()`" in findings[0].message


# ---------------------------------------------------------------------------
# prng-reuse
# ---------------------------------------------------------------------------

def test_prng_consumed_twice(tmp_path):
    findings, _ = _scan(tmp_path, """
        import jax

        def sample(key):
            a = jax.random.normal(key, (3,))
            b = jax.random.uniform(key, (3,))
            return a + b
        """, enabled="prng-reuse")
    assert len(findings) == 1
    assert "consumed twice" in findings[0].message


def test_prng_consume_then_derive(tmp_path):
    findings, _ = _scan(tmp_path, """
        import jax

        def init(key):
            w = my_init(key, 16)
            k2 = jax.random.fold_in(key, 1)
            return w, my_init(k2, 16)
        """, enabled="prng-reuse")
    assert len(findings) == 1
    assert "split/fold_in parent" in findings[0].message


def test_prng_loop_consumption(tmp_path):
    findings, _ = _scan(tmp_path, """
        import jax

        def roll(key, n):
            outs = []
            for i in range(n):
                outs.append(jax.random.normal(key, (2,)))
            return outs
        """, enabled="prng-reuse")
    assert len(findings) >= 1
    assert "inside a loop" in findings[0].message


def test_prng_clean_patterns_stay_silent(tmp_path):
    """split-reassign, per-iteration fold_in, exclusive return branches,
    and keys passed through jnp selectors are all fine."""
    findings, _ = _scan(tmp_path, """
        import jax, jax.numpy as jnp

        def good_split(key):
            key, sub = jax.random.split(key)
            a = jax.random.normal(sub, (3,))
            b = jax.random.normal(key, (3,))
            return a + b

        def good_fold_loop(key, n):
            return [jax.random.normal(jax.random.fold_in(key, i), (2,))
                    for i in range(n)]

        def good_branches(key, kind):
            if kind == "a":
                return init_a(key)
            if kind == "b":
                return init_b(key)
            raise ValueError(kind)

        def good_select(key, t):
            k2, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, t)
            return tok, jnp.where(t > 0, k2, key)
        """, enabled="prng-reuse")
    assert findings == []


# ---------------------------------------------------------------------------
# recompile-hazard
# ---------------------------------------------------------------------------

def test_recompile_jit_in_loop_and_immediate(tmp_path):
    findings, _ = _scan(tmp_path, """
        import jax

        def bench(xs):
            for x in xs:
                f = jax.jit(lambda v: v * 2)    # flagged: jit in loop
                f(x)
            return jax.jit(lambda v: v + 1)(xs[0])   # flagged: immediate
        """, enabled="recompile-hazard")
    msgs = sorted(f.message for f in findings)
    assert len(findings) == 2
    assert any("inside a loop" in m for m in msgs)
    assert any("invoked immediately" in m for m in msgs)


def test_recompile_static_arg_hazards(tmp_path):
    findings, _ = _scan(tmp_path, """
        import functools, jax

        @functools.partial(jax.jit, static_argnames=("cfg", "start"))
        def prefill(tokens, cfg, start=0):
            return tokens[start:]

        def admit(reqs, tokens):
            prefill(tokens, cfg=[1, 2, 3])            # unhashable static
            prefill(tokens, cfg=lambda: 3)            # fresh lambda static
            for r in reqs:
                start = r.hit_len
                prefill(tokens, cfg=(), start=start)  # loop-varying static
        """, enabled="recompile-hazard")
    msgs = sorted(f.message for f in findings)
    assert len(findings) == 3
    assert any("unhashable literal" in m for m in msgs)
    assert any("fresh lambda" in m for m in msgs)
    assert any("reassigned inside the enclosing loop" in m for m in msgs)


def test_recompile_stable_static_calls_stay_silent(tmp_path):
    findings, _ = _scan(tmp_path, """
        import functools, jax

        @functools.partial(jax.jit, static_argnames=("ticks",))
        def chunk(tok, ticks):
            return tok * ticks

        def drive(tok, n):
            f = jax.jit(lambda v: v * 2)   # bound outside any loop
            for _ in range(n):
                tok = chunk(tok, ticks=4)  # constant static: one compile
                tok = f(tok)
            return tok
        """, enabled="recompile-hazard")
    assert findings == []


def test_recompile_naive_adaptive_driver_antipattern(tmp_path):
    """The DESIGN.md §15 hazard the AdaptiveChunkPolicy exists to avoid:
    a serving loop that feeds an unbounded load signal straight into the
    static chunk-length argument compiles one XLA variant per distinct
    load level — the rule must flag the loop-varying static."""
    findings, _ = _scan(tmp_path, """
        import functools, jax

        @functools.partial(jax.jit, static_argnames=("ticks",))
        def decode_chunk(tok, ticks):
            return tok * ticks

        def serve(engine, tok):
            while engine.pending:
                ticks = engine.queue_depth        # unbounded load signal
                tok = decode_chunk(tok, ticks=ticks)
            return tok
        """, enabled="recompile-hazard")
    assert len(findings) == 1
    assert "reassigned inside the enclosing loop" in findings[0].message


def test_recompile_sweep_clean_over_adaptive_serving_path():
    """The real adaptive code path (serving/slo.py + the engine's
    _next_ticks -> step wiring) must carry zero NEW recompile-hazard
    findings: the policy's frozen level ladder, not a loop-varying
    static, feeds the ``ticks`` static of ``_decode_chunk``.  (The one
    baselined finding — the justified per-prefix-bucket ``start`` static
    of ``_paged_prefill_step`` — is allowed to survive, nothing else.)"""
    serving = REPO_ROOT / "src" / "repro" / "serving"
    index = lint.build_index(REPO_ROOT, [serving])
    findings, _ = lint.run_rules(index, all_rules(),
                                 enabled={"recompile-hazard"})
    stray = [f.format() for f in findings
             if not ("_paged_prefill_step" in f.message
                     and "`start`" in f.message)]
    assert stray == []
    # and nothing — baselined or not — implicates the adaptive path
    assert not [f for f in findings
                if "slo" in f.path or "`ticks`" in f.message]


# ---------------------------------------------------------------------------
# pallas-constraints
# ---------------------------------------------------------------------------

def test_pallas_missing_interpret_path(tmp_path):
    findings, _ = _scan(tmp_path, """
        import jax.experimental.pallas as pl
        import jax.numpy as jnp

        def kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def run(x):
            return pl.pallas_call(
                kernel, grid=(4,),
                out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            )(x)
        """, enabled="pallas-constraints")
    assert len(findings) == 1
    assert "interpret" in findings[0].message


def test_pallas_index_map_arity_and_coords(tmp_path):
    findings, _ = _scan(tmp_path, """
        import jax.experimental.pallas as pl

        def run(x, *, interpret=False):
            return pl.pallas_call(
                kern, grid=(4, 2),
                in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j, 0)),
                interpret=interpret,
            )(x)
        """, enabled="pallas-constraints")
    msgs = sorted(f.message for f in findings)
    assert len(findings) == 2
    assert any("takes 1 args but grid rank 2" in m for m in msgs)
    assert any("returns 3 coords but block_shape has 2 dims" in m for m in msgs)


def test_pallas_traced_capture_flagged_and_static_capture_not(tmp_path):
    findings, _ = _scan(tmp_path, """
        import jax.experimental.pallas as pl
        import jax.numpy as jnp

        def run(x, table, *, bm: int = 8, interpret=False):
            ps = x.shape[1]                  # provably static
            live = jnp.sum(table)            # traced!
            def pool_map(i, j):
                return (live + i * bm, ps)
            return pl.pallas_call(
                kern, grid=(4, 2),
                in_specs=[pl.BlockSpec((bm, ps), pool_map)],
                interpret=interpret,
            )(x)
        """, enabled="pallas-constraints")
    assert len(findings) == 1
    assert "captures `live`" in findings[0].message


def test_pallas_prefetch_grid_spec_arity(tmp_path):
    """index_map params = grid rank + num_scalar_prefetch, resolved
    through a local grid_spec binding."""
    findings, _ = _scan(tmp_path, """
        from jax.experimental import pallas as pl
        from jax.experimental.pallas import tpu as pltpu

        def run(x, tbl, *, interpret=False):
            grid_spec = pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1,
                grid=(4, 2),
                in_specs=[pl.BlockSpec((8, 8), lambda i, j, t: (i, j))],
                out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
            )
            return pl.pallas_call(
                kern, grid_spec=grid_spec, interpret=interpret)(x, tbl)
        """, enabled="pallas-constraints")
    # out_specs map misses the prefetch ref: 2 params != 2 + 1
    assert len(findings) == 1
    assert "takes 2 args but grid rank 2 + 1" in findings[0].message


# ---------------------------------------------------------------------------
# framework: suppressions, toggles, baseline, keys
# ---------------------------------------------------------------------------

SUPPRESSED_SRC = """
    import jax

    def sample(key):
        a = jax.random.normal(key, (3,))
        b = jax.random.uniform(key, (3,))  # lint: ignore[prng-reuse]
        return a + b
    """


def test_inline_suppression_comment(tmp_path):
    findings, suppressed = _scan(tmp_path, SUPPRESSED_SRC)
    assert [f for f in findings if f.rule == "prng-reuse"] == []
    assert suppressed == 1


def test_inline_suppression_is_rule_scoped(tmp_path):
    findings, suppressed = _scan(tmp_path, SUPPRESSED_SRC.replace(
        "ignore[prng-reuse]", "ignore[host-sync]"))
    assert len([f for f in findings if f.rule == "prng-reuse"]) == 1
    assert suppressed == 0


def test_rule_toggles(tmp_path):
    src = """
        import jax

        def bad(key, xs):
            a = jax.random.normal(key, (3,))
            b = jax.random.normal(key, (3,))
            for x in xs:
                f = jax.jit(lambda v: v)
                f(x)
            return a + b
        """
    both, _ = _scan(tmp_path, src)
    only_prng, _ = _scan(tmp_path, src, enabled="prng-reuse")
    assert _rules_hit(both) == {"prng-reuse", "recompile-hazard"}
    assert _rules_hit(only_prng) == {"prng-reuse"}


def test_baseline_diff_and_stale(tmp_path):
    findings, _ = _scan(tmp_path, SUPPRESSED_SRC.replace(
        "  # lint: ignore[prng-reuse]", ""))
    base_path = tmp_path / "baseline.json"
    lint.write_baseline(base_path, findings)
    baseline = lint.load_baseline(base_path)
    # same findings: all known, none new
    diff = lint.diff_baseline(findings, baseline)
    assert diff.new == [] and len(diff.known) == 1 and diff.stale == []
    # a new violation shows up as new without touching known
    diff2 = lint.diff_baseline(findings + [lint.Finding(
        rule="prng-reuse", path="other.py", line=3, col=0,
        symbol="g", message="key `k` consumed twice without an interleaving split/fold_in")],
        baseline)
    assert len(diff2.new) == 1 and len(diff2.known) == 1
    # fixed finding -> stale baseline entry
    diff3 = lint.diff_baseline([], baseline)
    assert len(diff3.stale) == 1


def test_finding_keys_are_line_number_free(tmp_path):
    src = SUPPRESSED_SRC.replace("  # lint: ignore[prng-reuse]", "")
    f1, _ = _scan(tmp_path, src)
    f2, _ = _scan(tmp_path, "import os\nimport sys\n\n" + textwrap.dedent(src))
    assert [f.key() for f in f1] == [f.key() for f in f2]
    assert f1[0].line != f2[0].line


def test_repo_sweep_is_clean_against_checked_in_baseline():
    """The gate check.sh runs: the tree must lint clean vs the baseline,
    with no stale entries left behind either."""
    report = lint.run_project(REPO_ROOT)
    assert [f.format() for f in report.diff.new] == []
    assert report.diff.stale == []
    # every baselined suppression carries a real justification
    baseline = lint.load_baseline(REPO_ROOT / lint.BASELINE_NAME)
    assert len(baseline) == len(report.diff.known)
    for key, entry in baseline.items():
        assert entry.get("note") and "TODO" not in entry["note"], key


def test_every_rule_has_a_baselined_or_fixed_real_finding():
    """Acceptance: each rule produced at least one real finding in the
    sweep — surviving ones must be baselined (the fixed ones are gone)."""
    baseline = lint.load_baseline(REPO_ROOT / lint.BASELINE_NAME)
    rules_in_baseline = {e["rule"] for e in baseline.values()}
    assert rules_in_baseline == set(rule_names())


# ---------------------------------------------------------------------------
# runtime layer: positive controls
# ---------------------------------------------------------------------------

def test_runtime_compile_tracker_sees_fresh_compile():
    import jax
    import jax.numpy as jnp

    from repro.analysis import runtime as art

    f = jax.jit(lambda x: x * 3 + 1)
    tracker = art.CompileTracker(f=f)
    before = tracker.snapshot()
    f(jnp.ones((4,)))                        # first call compiles
    mid = tracker.snapshot()
    f(jnp.ones((4,)))                        # cache hit
    after = tracker.snapshot()
    assert art.CompileTracker.new_compiles(before, mid)["f"] == 1
    assert art.CompileTracker.new_compiles(mid, after)["f"] == 0
    assert art.CompileTracker.new_compiles(mid, after)["_events"] == 0


def test_runtime_sync_region_counts_and_pull_attribution():
    import jax.numpy as jnp
    import numpy as np

    from repro.analysis import runtime as art

    x = jnp.arange(8)
    before_regions = art.region_counts().get("unit-test", 0)
    with art.measure_pulls() as pulls:
        with art.sync_region("unit-test"):
            np.asarray(x)
    assert art.region_counts()["unit-test"] == before_regions + 1
    assert pulls.get("unit-test", 0) >= 1


def test_runtime_no_host_sync_raises_on_stray_pull():
    import jax.numpy as jnp
    import numpy as np

    from repro.analysis import runtime as art

    x = jnp.arange(8)
    with pytest.raises(art.HostSyncError):
        with art.no_host_sync(strict=True):
            np.asarray(x)                    # undeclared pull
    # declared pulls pass, and the patch is removed afterwards
    with art.no_host_sync(strict=True):
        with art.sync_region("declared"):
            assert int(np.asarray(x)[3]) == 3
    assert np.asarray(x).shape == (8,)
