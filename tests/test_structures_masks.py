"""Structures, resource model (Eq. 1), masks, and packing invariants.

Property tests run under hypothesis when installed and degrade to a
deterministic fixed corpus otherwise (tests/_hyp.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import (
    BlockingSpec,
    TPUResourceModel,
    block_partition,
    build_structures,
    bsr_to_dense,
    consecutive_groups,
    count_zero_structures,
    init_masks,
    mask_from_selection,
    masks_from_knapsack,
    pack_bsr,
    sparsity_report,
    structure_norms_dense,
)


def test_eq1_consecutive_groups():
    # paper's cases: P=18 -> C=2; P=9 -> C=4; P=16 -> ceil(72/16)=5
    assert consecutive_groups(36, 18) == 2
    assert consecutive_groups(36, 9) == 4
    assert consecutive_groups(36, 16) == 5
    assert consecutive_groups(36, 36) == 1
    assert consecutive_groups(36, 50) == 1


def test_fpga_resource_vector():
    dsp, bram = TPUResourceModel.fpga_dsp_bram(16, rf=4)
    assert dsp == 1.0 and bram == pytest.approx(64 / (36 * 1024))
    dsp, _ = TPUResourceModel.fpga_dsp_bram(9, rf=4)
    assert dsp == 0.0  # paper footnote 3: <10 bits -> LUTs


@given(
    k=st.integers(1, 300), n=st.integers(1, 300),
    bk=st.sampled_from([8, 32, 128]), bn=st.sampled_from([32, 128]),
)
@settings(max_examples=40, deadline=None)
def test_partition_covers_everything(k, n, bk, bn):
    info = block_partition("w", (k, n), BlockingSpec(bk=bk, bn=bn))
    assert info.grid_k * info.blocking.bk >= k
    assert info.grid_n * info.blocking.bn >= n
    sel = np.ones(info.num_structures)
    mask = mask_from_selection(sel, info)
    assert mask.shape == (k, n)
    assert mask.min() == 1.0


@given(
    k=st.integers(8, 128), n=st.integers(8, 128), seed=st.integers(0, 100),
)
@settings(max_examples=30, deadline=None)
def test_mask_roundtrip_and_pack(k, n, seed):
    rng = np.random.default_rng(seed)
    spec = BlockingSpec(bk=16, bn=16)
    info = block_partition("w", (k, n), spec)
    sel = (rng.uniform(size=info.num_structures) < 0.6).astype(np.float32)
    mask = mask_from_selection(sel, info)
    w = rng.normal(size=(k, n)).astype(np.float32)
    bsr = pack_bsr(w, spec, mask=mask)
    dense = np.asarray(bsr_to_dense(bsr))
    assert np.allclose(dense, w * mask)
    # pruned weights are exactly zero after packing
    assert np.all(dense[mask == 0] == 0)


def test_structure_norms_match_manual():
    w = jnp.arange(24, dtype=jnp.float32).reshape(4, 6)
    info = block_partition("w", (4, 6), BlockingSpec(bk=2, bn=3))
    norms = np.asarray(structure_norms_dense(w, info))
    manual = np.zeros((1, 2, 2))
    wn = np.asarray(w)
    for i in range(2):
        for j in range(2):
            manual[0, i, j] = np.linalg.norm(wn[2*i:2*i+2, 3*j:3*j+3])
    assert np.allclose(norms, manual, atol=1e-5)


def test_build_structures_excludes_non_matmul():
    params = {
        "attn": {"wq": {"kernel": jnp.ones((64, 64))}},
        "norm": {"scale": jnp.ones((64,))},
        "tiny": {"kernel": jnp.ones((4, 4))},
    }
    st_ = build_structures(params, BlockingSpec(bk=32, bn=32), min_size=1024)
    paths = [i.path for i in st_.infos]
    assert paths == ["attn/wq/kernel"]


def test_sparsity_report_counts():
    params = {"l": {"kernel": jnp.ones((64, 64))}}
    st_ = build_structures(params, BlockingSpec(bk=32, bn=32), min_size=16)
    sel = np.array([1, 0, 0, 1], dtype=np.float32)
    masks = masks_from_knapsack(params, st_, sel)
    rep = sparsity_report(params, masks, st_)
    assert rep["structure_sparsity"] == pytest.approx(0.5)
    assert rep["weight_sparsity"] == pytest.approx(0.5)
    pruned, total = count_zero_structures(masks, st_)
    assert (pruned, total) == (2, 4)


def test_moe_expert_planes():
    """3-D expert weights: expert dim becomes independent planes so the
    knapsack can drop whole experts (paper's coarse structures)."""
    params = {"moe": {"experts_up": jnp.ones((4, 64, 64))}}
    st_ = build_structures(params, BlockingSpec(bk=64, bn=64), min_size=16)
    assert st_.infos[0].planes == 4
    assert st_.infos[0].num_structures == 4
    sel = np.array([1, 1, 0, 1], dtype=np.float32)
    masks = masks_from_knapsack(params, st_, sel)
    m = np.asarray(masks["moe"]["experts_up"])
    assert m[2].sum() == 0 and m[0].min() == 1
