"""Property + oracle tests for the MDKP solvers (paper Eq. 5-8).

Property tests run under hypothesis when installed and degrade to a
deterministic fixed corpus otherwise (tests/_hyp.py).
"""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import solve_brute, solve_dp, solve_greedy, solve_mdkp


def _rand_instance(draw, n_max=12, m_max=3):
    n = draw(st.integers(1, n_max))
    m = draw(st.integers(1, m_max))
    values = draw(st.lists(st.floats(0.0, 1.0), min_size=n, max_size=n))
    weights = [
        draw(st.lists(st.floats(0.01, 1.0), min_size=n, max_size=n))
        for _ in range(m)
    ]
    frac = draw(st.floats(0.1, 0.9))
    w = np.array(weights)
    c = w.sum(axis=1) * frac
    return np.array(values), w, c


@st.composite
def instances(draw):
    return _rand_instance(draw)


@given(instances())
@settings(max_examples=80, deadline=None)
def test_mdkp_always_feasible(inst):
    v, w, c = inst
    r = solve_mdkp(v, w, c)
    assert np.all(w @ r.x <= c + 1e-6), "capacity violated"
    assert r.value == pytest.approx(float(v @ r.x))


@given(instances())
@settings(max_examples=40, deadline=None)
def test_mdkp_near_optimal_vs_brute(inst):
    v, w, c = inst
    exact = solve_brute(v, w, c)
    approx = solve_mdkp(v, w, c)
    assert approx.value >= 0.9 * exact.value - 1e-9


@given(st.integers(1, 16), st.floats(0.1, 0.9))
@settings(max_examples=40, deadline=None)
def test_uniform_weights_is_topk(n, frac):
    rng = np.random.default_rng(n)
    v = rng.uniform(0, 1, n)
    w = np.ones((2, n))
    k = int(np.floor(n * frac))
    r = solve_mdkp(v, w, np.array([k, k], dtype=float))
    assert r.method == "mdkp-topk"
    expected = np.zeros(n, bool)
    expected[np.argsort(-v, kind="stable")[:k]] = True
    assert np.array_equal(r.x, expected)


def test_dp_exact_integer():
    v = np.array([60.0, 100.0, 120.0])
    w = np.array([[10.0, 20.0, 30.0]])
    r = solve_dp(v, w, np.array([50.0]))
    assert r.value == 220.0
    assert r.x.tolist() == [False, True, True]


@given(instances())
@settings(max_examples=30, deadline=None)
def test_dp_matches_brute_1d(inst):
    v, w, c = inst
    r_dp = solve_dp(v, w[:1], c[:1])
    r_b = solve_brute(v, w[:1], c[:1])
    assert np.all(w[:1] @ r_dp.x <= c[:1] + 1e-6)
    assert r_dp.value >= 0.95 * r_b.value - 1e-9


def test_feasible_flag_tracks_capacity():
    """feasible is computed from used <= capacity, not hardcoded True."""
    v = np.array([1.0, 2.0, 3.0])
    w = np.array([[1.0, 1.0, 1.0]])
    for solver in (solve_dp, solve_greedy, solve_mdkp):
        r = solver(v, w, np.array([2.0]))
        assert r.feasible
        assert np.all(r.used <= 2.0 + 1e-9)
    # negative capacity: even the empty selection violates the constraint
    r = solve_dp(v, w, np.array([-1.0]))
    assert not r.x.any()
    assert not r.feasible


def test_dp_scaled_float_stays_feasible():
    """Float weights take the FPTAS scaling (+ repair) path of solve_dp;
    the result must satisfy the *real* (unscaled) constraint and say so."""
    rng = np.random.default_rng(7)
    for _ in range(20):
        n = int(rng.integers(3, 14))
        v = rng.uniform(0.0, 1.0, n)
        w = rng.uniform(0.01, 1.0, (1, n))
        c = w.sum(axis=1) * float(rng.uniform(0.1, 0.9))
        r = solve_dp(v, w, c)
        assert np.all(r.used <= c + 1e-9)
        assert r.feasible
        assert r.value == pytest.approx(float(v @ r.x))


def test_greedy_zero_capacity():
    v = np.array([1.0, 2.0])
    w = np.ones((1, 2))
    r = solve_mdkp(v, w, np.array([0.0]))
    assert not r.x.any()


def test_heterogeneous_lenet_case():
    """Paper Table IV/V: conv structures [1,0], fc structures [2,1] —
    one global knapsack trades them off correctly."""
    # 4 conv structures (cheap on memory) + 4 fc structures (expensive)
    v = np.array([0.9, 0.8, 0.1, 0.05, 0.85, 0.7, 0.2, 0.1])
    w = np.array([
        [1, 1, 1, 1, 2, 2, 2, 2],     # DSP/MXU
        [0, 0, 0, 0, 1, 1, 1, 1],     # BRAM/HBM
    ], dtype=float)
    c = np.array([6.0, 2.0])
    r = solve_mdkp(v, w, c)
    assert np.all(w @ r.x <= c + 1e-9)
    # the two high-value fc structures fit the BRAM budget exactly
    assert r.x[4] and r.x[5]
    assert r.x[0] and r.x[1]
