"""Small-mesh (8 fake devices) dry-run smoke: the production sharding specs
lower+compile for a reduced config.  Runs in a subprocess because the fake
device count must be set before jax initializes."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import SHAPES, get_config, input_specs, make_smoke
    from repro.configs.base import ShapeCell
    from repro.distributed.sharding import axis_rules, cost_analysis, use_mesh
    from repro.launch.mesh import make_test_mesh
    from repro.launch.specs import cell_shardings, rules_for_cell, tree_named
    from repro.models.transformer import init_params
    from repro.optim.adamw import AdamWConfig
    from repro.optim.schedule import constant_lr
    from repro.train.train_step import init_train_state, make_train_step, make_decode_step
    from repro.models.transformer import init_caches

    arch = %(arch)r
    cfg = make_smoke(get_config(arch), d_model=256, n_heads=4, kv_heads=2,
                     head_dim=64, vocab=512)
    mesh = make_test_mesh((4, 2), ("data", "model"))
    cell = ShapeCell("t", "train", 64, 8)
    specs = input_specs(cfg, cell)
    opt_cfg = AdamWConfig(use_master=False)
    state_shapes = jax.eval_shape(
        lambda: init_train_state(init_params(jax.random.PRNGKey(0), cfg), opt_cfg))
    sh = cell_shardings(cfg, cell, mesh, False, specs, state_shapes=state_shapes)
    rules = rules_for_cell(cell, mesh, False)
    with use_mesh(mesh), axis_rules(rules):
        step = make_train_step(cfg, opt_cfg, constant_lr(1e-3))
        fn = jax.jit(step,
                     in_shardings=(tree_named(sh["state"], mesh),
                                   tree_named(sh["batch"], mesh)),
                     out_shardings=(tree_named(sh["state"], mesh), None))
        compiled = fn.lower(state_shapes, specs["batch"]).compile()
        ca = cost_analysis(compiled)   # shim normalizes pre-0.5 list form
        assert ca["flops"] > 0

        # decode cell too
        dcell = ShapeCell("d", "decode", 64, 8)
        dspecs = input_specs(cfg, dcell)
        dsh = cell_shardings(cfg, dcell, mesh, False, dspecs,
                             state_shapes={"params": state_shapes["params"]})
        dstep = make_decode_step(cfg)
        dfn = jax.jit(dstep,
                      in_shardings=(tree_named(dsh["params"], mesh),
                                    tree_named(dsh["caches"], mesh),
                                    tree_named(dsh["batch"], mesh),
                                    NamedSharding(mesh, P())),
                      out_shardings=(None, tree_named(dsh["caches"], mesh)))
        dcompiled = dfn.lower(state_shapes["params"], dspecs["caches"],
                              dspecs["batch"], dspecs["cache_len"]).compile()
    print(json.dumps({"ok": True, "flops": ca["flops"]}))
""")

ARCHS = ["qwen1.5-0.5b", "granite-moe-1b-a400m", "jamba-v0.1-52b", "xlstm-350m"]


@pytest.mark.parametrize("arch", ARCHS)
def test_small_mesh_lower_compile(arch):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT % {"arch": arch}],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["flops"] > 0


_MOE_EQUIV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.sharding import axis_rules, make_train_rules, use_mesh
    from repro.launch.mesh import make_test_mesh
    from repro.models.moe import moe_apply, moe_init
    from repro.models.moe_alltoall import moe_alltoall_apply

    mesh = make_test_mesh((4, 2), ("data", "model"))
    E, K, D, F = 4, 2, 32, 64
    p = moe_init(jax.random.PRNGKey(0), D, F, E)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, D))
    kw = dict(num_experts=E, top_k=K, capacity_factor=8.0)  # no drops

    with use_mesh(mesh), axis_rules(make_train_rules(False)):
        xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
        ps = jax.tree.map(lambda a: jax.device_put(a), p)
        y_ref, aux_ref = jax.jit(lambda pp, xx: moe_apply(pp, xx, **kw))(ps, xs)
        y_a2a, aux_a2a = jax.jit(
            lambda pp, xx: moe_alltoall_apply(pp, xx, **kw))(ps, xs)
    err = float(jnp.abs(y_ref - y_a2a).max())
    aerr = abs(float(aux_ref) - float(aux_a2a))
    print(json.dumps({"err": err, "aux_err": aerr}))
    assert err < 1e-3, err
    assert aerr < 1e-3, aerr
""")


def test_moe_alltoall_equivalence():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _MOE_EQUIV],
                       capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, r.stderr[-3000:]


_MOE_A2A_PACKED = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import (BlockingSpec, apply_masks, build_structures,
                            masks_from_knapsack)
    from repro.core.packing import BSRPlanes
    from repro.distributed.sharding import axis_rules, make_train_rules, use_mesh
    from repro.launch.mesh import make_test_mesh
    from repro.models.moe import moe_apply, moe_init
    from repro.models.moe_alltoall import moe_alltoall_apply
    from repro.sparse import pack_params

    mesh = make_test_mesh((4, 2), ("data", "model"))
    E, K, D, F = 4, 2, 32, 64
    p = moe_init(jax.random.PRNGKey(0), D, F, E)
    # prune ~half the expert tiles, pack to BSRPlanes (router stays dense)
    structures = build_structures(p, BlockingSpec(bk=16, bn=16), min_size=256)
    rng = np.random.default_rng(0)
    sel = (rng.uniform(size=structures.total_structures) < 0.6
           ).astype(np.float32)
    masks = masks_from_knapsack(p, structures, sel)
    masked = apply_masks(p, masks)
    packed = pack_params(p, masks, structures)
    assert isinstance(packed["experts_up"], BSRPlanes)

    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, D))
    kw = dict(num_experts=E, top_k=K, capacity_factor=8.0)  # no drops

    with use_mesh(mesh), axis_rules(make_train_rules(False)):
        xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
        # masked-dense GSPMD path is the oracle; the packed tree runs the
        # fused zero-skipping expert FFN behind the all_to_all dispatch
        y_ref, aux_ref = jax.jit(lambda pp, xx: moe_apply(pp, xx, **kw))(masked, xs)
        y_a2a, aux_a2a = jax.jit(
            lambda pp, xx: moe_alltoall_apply(pp, xx, **kw))(packed, xs)
    err = float(jnp.abs(y_ref - y_a2a).max())
    aerr = abs(float(aux_ref) - float(aux_a2a))
    print(json.dumps({"err": err, "aux_err": aerr}))
    assert err < 1e-3, err
    assert aerr < 1e-3, aerr
""")


def test_moe_alltoall_packed_equivalence():
    """BSRPlanes-packed expert weights through the explicit all-to-all
    dispatch (2-way expert sharding) match the masked-dense GSPMD MoE —
    the packed MoE all-to-all path of DESIGN.md §8."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _MOE_A2A_PACKED],
                       capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
