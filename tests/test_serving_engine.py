"""Continuous batching + paged KV caches (DESIGN.md §9).

The load-bearing property: a sequence that joins the engine mid-stream —
sharing its decode batch with strangers, its KV scattered over pool
pages — must emit exactly the tokens it would emit decoded alone, for
dense AND packed-BSR params.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, make_smoke
from repro.core import BlockingSpec
from repro.models import init_caches, init_params, lm_generate, lm_prefill
from repro.models.attention import (
    attention_decode,
    attention_init,
    attention_prefill,
)
from repro.serving import NULL_PAGE, PagePool, Request, Scheduler, ServingEngine
from repro.sparse import knapsack_prune, pack_params


# ---------------------------------------------------------------------------
# PagePool / Scheduler units
# ---------------------------------------------------------------------------

def test_page_pool_alloc_free_recycle():
    pool = PagePool(num_pages=6, page_size=4)
    assert pool.free_pages == 5            # page 0 reserved (null)
    a = pool.alloc(10)                     # ceil(10/4) = 3 pages
    assert len(a) == 3 and NULL_PAGE not in a
    assert pool.used_pages == 3
    b = pool.alloc(4)
    assert len(b) == 1 and set(a).isdisjoint(b)
    assert not pool.can_alloc(8)           # 1 page left, need 2
    pool.free(a)
    assert pool.can_alloc(8)
    c = pool.alloc(8)                      # LIFO: freed pages come back
    assert set(c) <= set(a)
    with pytest.raises(ValueError):
        pool.free([NULL_PAGE])
    with pytest.raises(ValueError):
        pool.free([b[0], b[0]])            # double free


def test_scheduler_fifo_admission_and_head_of_line():
    pool = PagePool(num_pages=5, page_size=4)    # 4 usable pages
    sched = Scheduler(pool)
    big = Request(rid=0, prompt=np.zeros(10, np.int32), max_new=6)   # 4 pages
    small = Request(rid=1, prompt=np.zeros(2, np.int32), max_new=2)  # 1 page
    late = Request(rid=2, prompt=np.zeros(2, np.int32), max_new=2,
                   arrival=5)
    sched.submit(big), sched.submit(small), sched.submit(late)

    got = sched.admit(tick=0, free_slots=4)
    assert [r.rid for r in got] == [0]     # big takes the whole pool
    pages = pool.alloc(big.budget_tokens)
    # head-of-line: small would fit zero pages now; late hasn't arrived
    assert sched.admit(tick=0, free_slots=3) == []
    sched.retire(big, pages, tick=3)
    got = sched.admit(tick=3, free_slots=3)
    assert [r.rid for r in got] == [1]     # FIFO order, late still future
    pool.alloc(small.budget_tokens)
    assert [r.rid for r in sched.admit(tick=5, free_slots=2)] == [2]


def test_scheduler_same_tick_admissions_reserve_against_each_other():
    """Full-budget admission must never over-reserve the pool: requests
    admitted on the SAME tick reserve pages against each other, before
    any page is physically allocated."""
    pool = PagePool(num_pages=5, page_size=4)            # 4 usable pages
    sched = Scheduler(pool)
    for rid in range(3):                                 # 3 pages each
        sched.submit(Request(rid=rid, prompt=np.zeros(8, np.int32),
                             max_new=4))
    got = sched.admit(tick=0, free_slots=3)
    assert [r.rid for r in got] == [0]                   # 3 + 3 > 4 blocks #1
    assert sum(pool.pages_for(r.budget_tokens) for r in got) \
        <= pool.free_pages


def test_scheduler_admission_and_retirement_invariants_fuzz():
    """Random submit/admit/retire traffic: admitted budgets always fit
    the pool at admission time, and retirement returns EXACTLY the page
    count that was reserved."""
    rng = np.random.default_rng(3)
    pool = PagePool(num_pages=9, page_size=4)
    sched = Scheduler(pool)
    live, rid = [], 0
    for tick in range(60):
        for _ in range(int(rng.integers(0, 3))):
            sched.submit(Request(
                rid=rid, prompt=np.zeros(int(rng.integers(1, 12)), np.int32),
                max_new=int(rng.integers(1, 8)), arrival=tick))
            rid += 1
        free_slots = 4 - len(live)
        got = sched.admit(tick, free_slots)
        assert len(got) <= free_slots
        # the whole same-tick batch fits the pool as it stands
        assert sum(pool.pages_for(r.budget_tokens) for r in got) \
            <= pool.free_pages
        for r in got:
            pages = pool.alloc(r.budget_tokens)          # cannot raise
            assert len(pages) == pool.pages_for(r.budget_tokens)
            live.append((r, pages))
        keep = []
        for r, pages in live:
            if rng.integers(2):
                before = pool.free_pages
                sched.retire(r, pages, tick)
                assert pool.free_pages == before + len(pages)
            else:
                keep.append((r, pages))
        live = keep
    for r, pages in live:
        sched.retire(r, pages, tick)
    assert pool.free_pages == pool.num_pages - 1


def test_scheduler_orders_queue_by_arrival_not_submit_order():
    """An early-arrival request submitted late must not wait behind an
    unarrived head — the queue keeps (arrival, submit) order."""
    pool = PagePool(num_pages=5, page_size=4)
    sched = Scheduler(pool)
    sched.submit(Request(rid=0, prompt=np.zeros(2, np.int32), max_new=2,
                         arrival=100))
    sched.submit(Request(rid=1, prompt=np.zeros(2, np.int32), max_new=2,
                         arrival=0))
    assert [r.rid for r in sched.admit(tick=0, free_slots=2)] == [1]


def test_scheduler_insort_matches_stable_sort_semantics():
    """Regression for the O(n log n)-total ordered-insert queue: random
    submit traffic (with duplicate arrivals) must leave the queue in
    EXACTLY the order the old per-submit stable re-sort produced —
    sorted by arrival, equal arrivals in submit order."""
    rng = np.random.default_rng(7)
    for trial in range(20):
        pool = PagePool(num_pages=64, page_size=4)
        sched = Scheduler(pool)
        reqs = []
        for rid in range(int(rng.integers(1, 40))):
            r = Request(rid=rid, prompt=np.zeros(2, np.int32), max_new=2,
                        arrival=int(rng.integers(0, 6)))  # heavy duplicates
            reqs.append(r)
            sched.submit(r)
        reference = sorted(reqs, key=lambda r: r.arrival)  # stable
        assert [r.rid for r in sched.waiting] == \
            [r.rid for r in reference], f"trial {trial}"


def test_scheduler_priority_key_insort_matches_stable_sort():
    """The DESIGN.md §15 queue key: random (priority, arrival) traffic
    must leave the queue stably sorted by (priority, arrival) — equal
    keys in submit order — exactly what a full re-sort would produce."""
    rng = np.random.default_rng(15)
    for trial in range(20):
        pool = PagePool(num_pages=64, page_size=4)
        sched = Scheduler(pool)
        reqs = []
        for rid in range(int(rng.integers(1, 40))):
            r = Request(rid=rid, prompt=np.zeros(2, np.int32), max_new=2,
                        arrival=int(rng.integers(0, 4)),
                        priority=int(rng.integers(0, 3)))
            reqs.append(r)
            sched.submit(r)
        reference = sorted(reqs, key=lambda r: (r.priority, r.arrival))
        assert [r.rid for r in sched.waiting] == \
            [r.rid for r in reference], f"trial {trial}"


# ---------------------------------------------------------------------------
# Paged attention_decode == contiguous attention_decode
# ---------------------------------------------------------------------------

def test_attention_decode_paged_matches_contiguous():
    """Same KV scattered over pool pages (in shuffled physical order)
    must attend identically to the contiguous cache, per row."""
    b, ps, npages_seq, kvh, h, dh, d = 2, 4, 3, 2, 4, 16, 64
    max_len = ps * npages_seq
    key = jax.random.PRNGKey(0)
    p = attention_init(key, d, h, kvh, dh)
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, 1, d))
    k0 = jax.random.normal(jax.random.fold_in(key, 2), (b, max_len, kvh, dh))
    v0 = jax.random.normal(jax.random.fold_in(key, 3), (b, max_len, kvh, dh))
    cache_len = jnp.asarray([5, 9], jnp.int32)

    out_c, cc = attention_decode(
        p, x, {"k": k0, "v": v0}, cache_len,
        num_heads=h, kv_heads=kvh, head_dim=dh)

    # pool: rows own disjoint, deliberately non-contiguous page ids
    tables = jnp.asarray([[3, 1, 5], [2, 6, 4]], jnp.int32)
    pool_k = jnp.zeros((7, ps, kvh, dh))
    pool_v = jnp.zeros((7, ps, kvh, dh))
    for r in range(b):
        for j in range(npages_seq):
            pool_k = pool_k.at[tables[r, j]].set(k0[r, j * ps:(j + 1) * ps])
            pool_v = pool_v.at[tables[r, j]].set(v0[r, j * ps:(j + 1) * ps])

    out_p, cp = attention_decode(
        p, x, {"k": pool_k, "v": pool_v}, cache_len,
        num_heads=h, kv_heads=kvh, head_dim=dh, page_table=tables)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_c),
                               atol=1e-6)
    # the write landed in the right physical slot of each row's own page
    for r, L in enumerate([5, 9]):
        want = cc["k"][r, L]
        got = cp["k"][tables[r, L // ps], L % ps]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-6)


def test_attention_prefill_paged_writes_match_contiguous():
    """Paged prefill scatters the prompt K/V straight into pool pages:
    same attention output as the contiguous cache, and every logical
    slot lands at pool[table[t // ps], t % ps] of the row's own table."""
    b, ps, npages_seq, kvh, h, dh, d, s = 2, 4, 3, 2, 4, 16, 64, 10
    key = jax.random.PRNGKey(0)
    p = attention_init(key, d, h, kvh, dh)
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, s, d))

    cache_c = {"k": jnp.zeros((b, ps * npages_seq, kvh, dh)),
               "v": jnp.zeros((b, ps * npages_seq, kvh, dh))}
    out_c, cc = attention_prefill(p, x, cache_c, num_heads=h, kv_heads=kvh,
                                  head_dim=dh)

    tables = jnp.asarray([[3, 1, 5], [2, 6, 4]], jnp.int32)
    pool = {"k": jnp.zeros((7, ps, kvh, dh)), "v": jnp.zeros((7, ps, kvh, dh))}
    out_p, cp = attention_prefill(p, x, pool, num_heads=h, kv_heads=kvh,
                                  head_dim=dh, page_table=tables)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_c),
                               atol=1e-6)
    for r in range(b):
        for t in range(s):
            np.testing.assert_allclose(
                np.asarray(cp["k"][tables[r, t // ps], t % ps]),
                np.asarray(cc["k"][r, t]), atol=1e-6)
            np.testing.assert_allclose(
                np.asarray(cp["v"][tables[r, t // ps], t % ps]),
                np.asarray(cc["v"][r, t]), atol=1e-6)


def test_attention_paged_rejects_windows_with_clear_error():
    """SWA over a paged cache is unsupported: both entry points must say
    so loudly (NotImplementedError naming the combo), not silently
    mis-compute or raise a generic error."""
    p = attention_init(jax.random.PRNGKey(0), 32, 2, 2, 16)
    cache = {"k": jnp.zeros((4, 2, 2, 16)), "v": jnp.zeros((4, 2, 2, 16))}
    table = jnp.zeros((1, 2), jnp.int32)
    with pytest.raises(NotImplementedError, match="window=8.*page_table"):
        attention_decode(p, jnp.zeros((1, 1, 32)), cache,
                         jnp.zeros((1,), jnp.int32),
                         num_heads=2, kv_heads=2, head_dim=16, window=8,
                         page_table=table)
    with pytest.raises(NotImplementedError, match="window=8.*page_table"):
        attention_prefill(p, jnp.zeros((1, 3, 32)), cache,
                          num_heads=2, kv_heads=2, head_dim=16, window=8,
                          page_table=table)


def test_attention_paged_rejects_unknown_impl():
    p = attention_init(jax.random.PRNGKey(0), 32, 2, 2, 16)
    cache = {"k": jnp.zeros((4, 2, 2, 16)), "v": jnp.zeros((4, 2, 2, 16))}
    with pytest.raises(ValueError, match="paged_impl"):
        attention_decode(p, jnp.zeros((1, 1, 32)), cache,
                         jnp.zeros((1,), jnp.int32),
                         num_heads=2, kv_heads=2, head_dim=16,
                         page_table=jnp.zeros((1, 2), jnp.int32),
                         paged_impl="bogus")


# ---------------------------------------------------------------------------
# Engine: mid-stream joins token-identical to solo decode
# ---------------------------------------------------------------------------

def _smoke_pair(arch="qwen1.5-0.5b", *, sparsity=0.5):
    cfg = make_smoke(get_config(arch), n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    sel = knapsack_prune(params, sparsity=sparsity,
                         blocking=BlockingSpec(bk=32, bn=32), min_size=1024)
    packed = pack_params(params, sel.masks, sel.structures)
    return cfg, params, packed


def _solo(cfg, params, prompt, gen, eos_id=None):
    toks = jnp.asarray(prompt[None])
    caches = init_caches(cfg, 1, toks.shape[1] + gen, jnp.float32)
    logits, caches = lm_prefill(params, caches, {"tokens": toks}, cfg)
    first = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out, _ = lm_generate(params, caches, first,
                         jnp.asarray(toks.shape[1], jnp.int32), gen, cfg,
                         eos_id=eos_id)
    return np.asarray(out)[0]


def test_engine_midstream_join_token_identical_dense_and_packed():
    cfg, dense, packed = _smoke_pair()
    rng = np.random.default_rng(0)
    lens, gens = [5, 9, 7, 5], [6, 4, 6, 5]
    arrivals = [0, 0, 3, 5]            # requests 2/3 join mid-stream
    prompts = [rng.integers(0, cfg.vocab, size=l).astype(np.int32)
               for l in lens]
    for name, params in (("dense", dense), ("packed", packed)):
        eng = ServingEngine(params, cfg, num_slots=2, page_size=4,
                            max_seq_len=16)
        for p, g, a in zip(prompts, gens, arrivals):
            eng.submit(p, g, arrival=a)
        done = eng.run()
        assert len(done) == len(prompts)
        for i, (p, g) in enumerate(zip(prompts, gens)):
            assert done[i].admitted_at >= arrivals[i]
            np.testing.assert_array_equal(
                done[i].tokens, _solo(cfg, params, p, g),
                err_msg=f"{name}/request {i}")
        # joins really were interleaved: some request admitted after
        # another had already started decoding
        assert max(r.admitted_at for r in done.values()) > 0
        # the prefix index deliberately retains full prompt blocks after
        # retirement (readmit reuse); dropping it must drain the pool
        eng.release_prefix_cache()
        assert eng.pool.free_pages == eng.pool.num_pages - 1  # all freed


@pytest.mark.parametrize("kind,impl", [
    ("dense", "fused"), ("packed", "fused"), ("dense", "gather"),
])
def test_engine_null_page_poison_streams_bitmatch_solo(kind, impl):
    """Fill the null page (page 0) of every layer pool with NaN before
    serving: streamed tokens must stay bit-identical to solo decode.
    This proves the attention read path — fused page walk AND legacy
    gather — never takes a value from an unallocated page (a single NaN
    would poison the softmax and change the argmax)."""
    cfg, dense_p, packed_p = _smoke_pair()
    cfg = cfg.replace(paged_attn_impl=impl)
    params = dense_p if kind == "dense" else packed_p
    rng = np.random.default_rng(2)
    lens, gens, arrivals = [5, 9, 7], [6, 4, 5], [0, 0, 3]
    prompts = [rng.integers(0, cfg.vocab, size=l).astype(np.int32)
               for l in lens]
    eng = ServingEngine(params, cfg, num_slots=2, page_size=4,
                        max_seq_len=16)
    for i, c in enumerate(eng.caches):
        if "k" in c:
            eng.caches[i] = {**c,
                             "k": c["k"].at[NULL_PAGE].set(jnp.nan),
                             "v": c["v"].at[NULL_PAGE].set(jnp.nan)}
    for p, g, a in zip(prompts, gens, arrivals):
        eng.submit(p, g, arrival=a)
    done = eng.run()
    assert len(done) == len(prompts)
    for i, (p, g) in enumerate(zip(prompts, gens)):
        got = np.asarray(done[i].tokens)
        assert np.isfinite(got).all()
        np.testing.assert_array_equal(
            got, _solo(cfg, params, p, g),
            err_msg=f"{kind}/{impl}/request {i}")


def test_engine_eos_retires_slot_and_readmits():
    """EOS ends a stream early, frees its pages, and the freed slot picks
    up the next queued request; tokens still match the solo decode."""
    cfg, dense, _ = _smoke_pair()
    rng = np.random.default_rng(1)
    p0 = rng.integers(0, cfg.vocab, size=6).astype(np.int32)
    p1 = rng.integers(0, cfg.vocab, size=6).astype(np.int32)
    base = _solo(cfg, dense, p0, 6)
    eos = int(base[2])                 # a token p0 emits mid-stream
    eng = ServingEngine(dense, cfg, num_slots=1, page_size=4,
                        max_seq_len=16, eos_id=eos)
    eng.submit(p0, 6)
    eng.submit(p1, 3)                  # must wait for the only slot
    done = eng.run()
    want0 = _solo(cfg, dense, p0, 6, eos_id=eos)
    stop = int(np.argmax(want0 == eos)) + 1 if (want0 == eos).any() else 6
    np.testing.assert_array_equal(done[0].tokens, want0[:stop])
    assert done[0].tokens[-1] == eos and len(done[0].tokens) < 6
    np.testing.assert_array_equal(done[1].tokens,
                                  _solo(cfg, dense, p1, 3, eos_id=eos))
    assert done[1].admitted_at >= done[0].finished_at


def test_engine_priority_reorders_admission_not_tokens():
    """Priority classes (DESIGN.md §15) through the full engine: a
    same-tick submission burst admits urgent-first, eviction freedom
    holds per admission (every admitted stream runs to its last token),
    and every stream stays bit-identical to its solo decode."""
    cfg, dense, _ = _smoke_pair()
    rng = np.random.default_rng(21)
    prompts = [rng.integers(0, cfg.vocab, size=5).astype(np.int32)
               for _ in range(3)]
    eng = ServingEngine(dense, cfg, num_slots=1, page_size=4,
                        max_seq_len=16, ticks_per_sync=2)
    rids = [eng.submit(p, 3, priority=pr)
            for p, pr in zip(prompts, (2, 0, 1))]
    done = eng.run()
    order = sorted(rids, key=lambda r: done[r].admitted_at)
    assert order == [1, 2, 0]          # urgency order, not submit order
    for r, p in zip(rids, prompts):
        assert done[r].status.name == "FINISHED"
        np.testing.assert_array_equal(done[r].tokens,
                                      _solo(cfg, dense, p, 3),
                                      err_msg=f"request {r}")


# ---------------------------------------------------------------------------
# Prefix caching (DESIGN.md §12)
# ---------------------------------------------------------------------------

def _shared_prompts(rng, cfg, *, prefix_len, tails):
    """Prompts sharing their first ``prefix_len`` tokens, each with a
    unique random tail."""
    prefix = rng.integers(0, cfg.vocab, size=prefix_len)
    return [np.concatenate([prefix, rng.integers(0, cfg.vocab, size=t)])
            .astype(np.int32) for t in tails]


@pytest.mark.parametrize("kind", ["dense", "packed"])
def test_engine_shared_prefix_streams_bitmatch_solo(kind):
    """Requests sharing a 3-page prompt prefix, joining mid-burst: hit
    requests map the cached pages and prefill only their tails, yet every
    stream stays bit-identical to its solo decode — the load-bearing
    property of DESIGN.md §12, for dense AND packed params."""
    cfg, dense_p, packed_p = _smoke_pair()
    params = dense_p if kind == "dense" else packed_p
    rng = np.random.default_rng(13)
    prompts = _shared_prompts(rng, cfg, prefix_len=12, tails=[3, 5, 2, 4])
    gens = [5, 4, 6, 4]
    arrivals = [0, 1, 4, 6]            # later requests join mid-stream
    eng = ServingEngine(params, cfg, num_slots=2, page_size=4,
                        max_seq_len=24, ticks_per_sync=2)
    for p, g, a in zip(prompts, gens, arrivals):
        eng.submit(p, g, arrival=a)
    done = eng.run()
    for i, (p, g) in enumerate(zip(prompts, gens)):
        np.testing.assert_array_equal(
            done[i].tokens, _solo(cfg, params, p, g),
            err_msg=f"{kind}/request {i}")
    st = eng.prefix_stats
    assert st["enabled"] and st["hit_requests"] == 3
    assert st["pages_shared"] == 9     # 3 later requests x 3 prefix pages
    assert done[0].prefix_hit_pages == 0
    assert all(done[i].prefix_hit_pages == 3 for i in (1, 2, 3))
    # the index deliberately retains prompt blocks past retirement
    # (readmit reuse); dropping it must drain the pool completely
    eng.release_prefix_cache()
    assert eng.pool.free_pages == eng.pool.num_pages - 1


def test_engine_prefix_reuse_after_retirement():
    """EOS-retire-readmit reuse: the cached blocks survive the request
    that computed them, so the same prompt submitted long after the
    original finished maps its prefix instead of re-prefilling."""
    cfg, dense, _ = _smoke_pair()
    rng = np.random.default_rng(17)
    p0 = rng.integers(0, cfg.vocab, size=13).astype(np.int32)
    eng = ServingEngine(dense, cfg, num_slots=1, page_size=4,
                        max_seq_len=24)
    eng.submit(p0, 4)
    eng.submit(p0.copy(), 4, arrival=30)   # long after request 0 retired
    done = eng.run()
    want = _solo(cfg, dense, p0, 4)
    np.testing.assert_array_equal(done[0].tokens, want)
    np.testing.assert_array_equal(done[1].tokens, want)
    assert done[0].prefix_hit_pages == 0
    assert done[1].prefix_hit_pages == 3   # (13 - 1) // 4: proper prefix
    assert done[1].admitted_at >= done[0].finished_at
    assert eng.prefix_stats["hit_requests"] == 1


def test_engine_identical_sampled_prompts_keep_independent_streams():
    """Three byte-identical sampled prompts in one burst: every request
    keeps its own rid (dedupe-safe) and its own fold_in(base, rid) PRNG
    stream, so sharing the ENTIRE cached prefix never collapses the
    samples — each stream replays against its own solo decode."""
    cfg, dense, _ = _smoke_pair()
    rng = np.random.default_rng(19)
    p0 = rng.integers(0, cfg.vocab, size=13).astype(np.int32)
    base = jax.random.PRNGKey(5)
    eng = ServingEngine(dense, cfg, num_slots=3, page_size=4,
                        max_seq_len=24, seed=5, temperature=0.9, top_k=8)
    rids = [eng.submit(p0.copy(), 5) for _ in range(3)]
    assert len(set(rids)) == 3
    done = eng.run()
    for rid in rids:
        want = _solo_sampled(cfg, dense, p0, 5, 0.9, 8, None,
                             jax.random.fold_in(base, rid))
        np.testing.assert_array_equal(done[rid].tokens, want,
                                      err_msg=f"request {rid}")
    assert eng.prefix_stats["hit_requests"] == 2  # 2nd/3rd hit the 1st's


def test_engine_cow_guard_copies_shared_write_page():
    """COW backstop: the standard path never decodes into a shared page,
    but if an external holder maps a live tail page anyway, the guard
    must copy it to a fresh page before the chunk — the stream stays
    bit-identical and the sharer's page is never written."""
    cfg, dense, _ = _smoke_pair()
    rng = np.random.default_rng(23)
    p0 = rng.integers(0, cfg.vocab, size=6).astype(np.int32)
    want = _solo(cfg, dense, p0, 6)
    eng = ServingEngine(dense, cfg, num_slots=1, page_size=4,
                        max_seq_len=16, ticks_per_sync=2)
    eng.submit(p0, 6)
    eng.step()                               # admit + first decode chunk
    # an external reference on the page the NEXT chunk writes into
    idx = int(eng._cache_len[0]) // eng.pool.page_size
    pid = int(eng._tables[0, idx])
    eng.pool.share([pid])
    done = eng.run()
    assert eng.pool.cow_copies >= 1
    assert eng.prefix_stats["cow_copies"] >= 1
    np.testing.assert_array_equal(done[0].tokens, want)
    assert eng.pool.refcount(pid) == 1       # only the external ref left
    eng.pool.free([pid])
    eng.release_prefix_cache()
    assert eng.pool.free_pages == eng.pool.num_pages - 1


def test_engine_stalls_loudly_when_pool_too_small():
    """A pool that can never fit the head request must fail FAST — on the
    first drained tick, not after burning max_ticks — and the error must
    carry enough state (waiting queue, pool occupancy, page math) to
    diagnose the sizing mistake."""
    cfg, dense, _ = _smoke_pair()
    eng = ServingEngine(dense, cfg, num_slots=1, page_size=4,
                        max_seq_len=16, num_pages=2)   # 1 usable page
    eng.submit(np.zeros(6, np.int32), 4)               # needs 3 pages
    with pytest.raises(RuntimeError, match="admission stalled") as ei:
        eng.run(max_ticks=50_000)
    assert eng.tick <= 1, "stall must be detected immediately"
    msg = str(ei.value)
    assert "needs 3 pages" in msg
    assert "waiting" in msg and "pool=" in msg and "1/1 pages free" in msg


# ---------------------------------------------------------------------------
# Multi-tick on-device decode chunks (DESIGN.md §10)
# ---------------------------------------------------------------------------

_SAMPLING_PALETTE = [
    (0.0, None, None),             # greedy
    (0.8, 5, None),                # temperature + top-k
    (1.3, None, 0.9),              # temperature + nucleus
    (0.9, 8, 0.95),                # everything at once
]


def _solo_sampled(cfg, params, prompt, gen, t, k, p, key, eos_id=None):
    toks = jnp.asarray(prompt[None])
    caches = init_caches(cfg, 1, toks.shape[1] + gen, jnp.float32)
    logits, caches = lm_prefill(params, caches, {"tokens": toks}, cfg)
    first = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out, _ = lm_generate(params, caches, first,
                         jnp.asarray(toks.shape[1], jnp.int32), gen, cfg,
                         temperature=t, top_k=k, top_p=p, key=key,
                         eos_id=eos_id)
    return np.asarray(out)[0]


@pytest.mark.parametrize("kind", ["dense", "packed"])
def test_engine_fuzz_streams_bitmatch_solo(kind):
    """Randomized arrival-trace differential fuzz: seeded random prompts,
    arrival ticks, budgets and PER-SLOT sampling params, streamed through
    the chunked engine at every ticks_per_sync — each request's stream
    must be bit-identical to its solo ``lm_generate`` run (same per-slot
    key derivation: fold_in(base, rid)).  Budget-exhausted rows freeze
    mid-chunk (gen < 16 while ticks_per_sync = 16), so the done-mask path
    is always exercised."""
    cfg, dense_p, packed_p = _smoke_pair()
    params = dense_p if kind == "dense" else packed_p
    seed = 7 if kind == "dense" else 11
    rng = np.random.default_rng(seed)
    n = 6
    lens = rng.integers(3, 10, size=n)
    gens = rng.integers(2, 8, size=n)
    arrivals = np.sort(rng.integers(0, 12, size=n))
    samp = [_SAMPLING_PALETTE[i]
            for i in rng.integers(0, len(_SAMPLING_PALETTE), size=n)]
    prompts = [rng.integers(0, cfg.vocab, size=int(l)).astype(np.int32)
               for l in lens]
    base = jax.random.PRNGKey(5)
    solos = {}
    for tps in (1, 4, 16):
        eng = ServingEngine(params, cfg, num_slots=2, page_size=4,
                            max_seq_len=24, ticks_per_sync=tps, seed=5)
        rids = [eng.submit(pr, int(g), arrival=int(a), temperature=t,
                           top_k=k, top_p=p)
                for pr, g, a, (t, k, p)
                in zip(prompts, gens, arrivals, samp)]
        done = eng.run()
        assert len(done) == n
        for i, rid in enumerate(rids):
            if rid not in solos:
                t, k, p = samp[i]
                solos[rid] = _solo_sampled(
                    cfg, params, prompts[i], int(gens[i]), t, k, p,
                    jax.random.fold_in(base, rid))
            assert len(done[rid].tokens) == gens[i]
            np.testing.assert_array_equal(
                done[rid].tokens, solos[rid],
                err_msg=f"{kind}/tps={tps}/request {rid}")
        eng.release_prefix_cache()   # index refs survive retirement
        assert eng.pool.free_pages == eng.pool.num_pages - 1


def test_engine_chunked_eos_freezes_midchunk_and_readmits():
    """EOS inside a chunk: the row freezes mid-scan (its remaining chunk
    ticks emit nothing), retires at the chunk boundary, and the freed
    slot re-admits the queue head — tokens still match the solo decode."""
    cfg, dense, _ = _smoke_pair()
    rng = np.random.default_rng(1)
    p0 = rng.integers(0, cfg.vocab, size=6).astype(np.int32)
    p1 = rng.integers(0, cfg.vocab, size=6).astype(np.int32)
    base = _solo(cfg, dense, p0, 6)
    eos = int(base[2])                 # fires mid-chunk at ticks_per_sync=4
    eng = ServingEngine(dense, cfg, num_slots=1, page_size=4,
                        max_seq_len=16, eos_id=eos, ticks_per_sync=4)
    eng.submit(p0, 6)
    eng.submit(p1, 3)
    done = eng.run()
    want0 = _solo(cfg, dense, p0, 6, eos_id=eos)
    stop = int(np.argmax(want0 == eos)) + 1 if (want0 == eos).any() else 6
    np.testing.assert_array_equal(done[0].tokens, want0[:stop])
    assert done[0].tokens[-1] == eos and len(done[0].tokens) < 6
    np.testing.assert_array_equal(done[1].tokens,
                                  _solo(cfg, dense, p1, 3, eos_id=eos))
    assert done[1].admitted_at >= done[0].finished_at


# ---------------------------------------------------------------------------
# Steady-state invariants (DESIGN.md §14): 0 recompiles, 1 transfer/chunk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["dense", "packed"])
def test_engine_steady_state_zero_recompiles_one_sync_per_chunk(kind):
    """After warm-up, N chunks of mixed admit/retire traffic must hit the
    jit cache every time (0 new compiles, engine-wide compile-event
    tripwire included) and perform exactly ONE declared host round-trip
    per decode chunk plus one per admission — proven with the
    analysis/runtime.py counters, and with stray-pull interception armed
    so any undeclared device->host pull raises."""
    from repro.analysis import runtime as analysis_runtime

    cfg, dense_p, packed_p = _smoke_pair()
    params = dense_p if kind == "dense" else packed_p
    rng = np.random.default_rng(7)
    PLEN, GEN = 6, 3                   # one shape bucket for every request

    def build():
        # prefix caching off so every admission prefills from start=0 —
        # a single static-start bucket for _paged_prefill_step
        return ServingEngine(params, cfg, num_slots=2, page_size=4,
                             max_seq_len=16, ticks_per_sync=2,
                             prefix_caching=False)

    def traffic(eng, n, spread):
        for i in range(n):
            eng.submit(rng.integers(0, cfg.vocab, size=PLEN).astype(np.int32),
                       GEN, arrival=i * spread)

    # warm-up: compile every (shape, static) combo the steady engine uses
    warm = build()
    traffic(warm, 3, spread=2)
    assert len(warm.run()) == 3

    eng = build()
    traffic(eng, 6, spread=2)          # staggered: retire/admit churn
    before = eng.analysis_stats()
    chunks = 0
    with analysis_runtime.no_host_sync(strict=True):
        while eng.scheduler.pending or any(s is not None for s in eng.slots):
            regions0 = dict(eng.sync_regions)
            admitted = eng.step()
            active = any(s is not None for s in eng.slots)
            d_chunk = eng.sync_regions["decode_chunk"] - regions0["decode_chunk"]
            d_admit = eng.sync_regions["admission"] - regions0["admission"]
            assert d_chunk <= 1, "more than one transfer boundary in a chunk"
            assert d_admit == admitted, "admission sync without an admission"
            chunks += d_chunk
            if not active and not eng.scheduler.pending and d_chunk == 0:
                break
    after = eng.analysis_stats()

    assert chunks >= 3                 # the loop really decoded in chunks
    assert after["compile_caches"] == before["compile_caches"], \
        "steady-state traffic recompiled a hot-path function"
    assert after["compile_events"] == before["compile_events"], \
        "something compiled during steady-state traffic"
    assert after["sync_regions"]["decode_chunk"] - \
        before["sync_regions"]["decode_chunk"] == chunks
    assert after["sync_regions"]["admission"] - \
        before["sync_regions"]["admission"] == 6
    assert all(r.status.name == "FINISHED" for r in eng.requests.values())
