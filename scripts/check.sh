#!/usr/bin/env bash
# Smoke gate: deterministic test subset + the pruned-serving entrypoints.
#
# The full tier-1 command is `PYTHONPATH=src python -m pytest -x -q`; it
# currently carries 7 known seed failures (jax version drift in
# test_sharding_dryrun / test_substrate — see ROADMAP "Open items"), so
# this gate runs the modules that must stay green plus the serving smoke.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -q \
    tests/test_knapsack.py \
    tests/test_structures_masks.py \
    tests/test_kernels.py \
    tests/test_sparse_exec.py \
    tests/test_serve_equiv.py \
    tests/test_models.py \
    tests/test_pruner.py \
    tests/test_system.py

python examples/serve_pruned.py

python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
    --pruned 0.5 --prompt-len 4 --gen 8

echo "check.sh: OK"
