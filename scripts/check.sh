#!/usr/bin/env bash
# Smoke gate: deterministic test subset + the pruned-serving entrypoints
# + the serving benchmark (writes BENCH_serving.json).
#
# The full tier-1 command is `PYTHONPATH=src python -m pytest -x -q`;
# since PR 2 (jax-version gates in distributed/sharding.py) it should be
# fully green on the container jax, so this gate is a fast subset.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -q \
    tests/test_knapsack.py \
    tests/test_structures_masks.py \
    tests/test_kernels.py \
    tests/test_sparse_exec.py \
    tests/test_serve_equiv.py \
    tests/test_models.py \
    tests/test_pruner.py \
    tests/test_system.py

python examples/serve_pruned.py

python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
    --pruned 0.5 --prompt-len 4 --gen 8

# serving benchmark: dense vs packed {prefill, decode} -> BENCH_serving.json
# (full default size on purpose — ~10s on CPU, and the committed numbers
# should show the real packed-over-dense margin, which --quick thins out)
python benchmarks/bench_serving.py

echo "check.sh: OK"
