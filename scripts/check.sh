#!/usr/bin/env bash
# Smoke gate: deterministic test subset + the pruned-serving entrypoints
# + the serving benchmark (writes BENCH_serving.json) + perf gates.
#
# The full tier-1 command is `PYTHONPATH=src python -m pytest -x -q`;
# since PR 2 (jax-version gates in distributed/sharding.py) it should be
# fully green on the container jax, so this gate is a fast subset.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# static-analysis gate first (DESIGN.md §14): ~1.5s, and a tracer-lint
# regression should fail the run before 3 minutes of tests do
scripts/lint.sh

python -m pytest -q \
    tests/test_analysis.py \
    tests/test_knapsack.py \
    tests/test_structures_masks.py \
    tests/test_kernels.py \
    tests/test_paged_attention.py \
    tests/test_sparse_exec.py \
    tests/test_serve_equiv.py \
    tests/test_serving_engine.py \
    tests/test_serving_faults.py \
    tests/test_slo_scheduling.py \
    tests/test_page_pool_props.py \
    tests/test_models.py \
    tests/test_pruner.py \
    tests/test_system.py

# the bm-tiled kernel grid must stay covered in BOTH serving shapes:
# decode-shaped (M=1) and prefill-shaped (M=64, >1 row tile) interpret-mode
# runs of the real Pallas kernel body.  pytest exits 5 ("no tests
# collected") if these ever get renamed away — the gate fails loudly
# instead of the tiling branch silently going dead.
python -m pytest -q tests/test_kernels.py -k "interpret_grid_epilogue"

# same contract for the fused paged-attention kernels (DESIGN.md §11):
# the decode (M=1) and prefill (bm-tiled, M=64) page-walk grids must
# keep running under the interpreter against the non-gathering ref
python -m pytest -q tests/test_paged_attention.py -k "kernel_interpret"

python examples/serve_pruned.py

python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
    --pruned 0.5 --prompt-len 4 --gen 8

# sampled + EOS-early-exit decode through the same hot path
python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
    --pruned 0.5 --prompt-len 4 --gen 8 \
    --temperature 0.8 --top-k 16 --top-p 0.95 --eos-id 2

# continuous batching + paged KV pool (DESIGN.md §9): ragged prompts
# arrive mid-stream, join decode slots freed by finished sequences, and
# every stream is verified token-identical against its solo decode (the
# command exits nonzero on any divergence).  ticks-per-sync 1 keeps the
# PR-4 host-sync-per-token loop covered
python -m repro.launch.serve --arch qwen1.5-0.5b --smoke --stream \
    --pruned 0.75 --prompt-len 12 --gen 8 --requests 5 --arrive-every 2 \
    --ticks-per-sync 1

# chunked decode (DESIGN.md §10): 4 decode ticks per on-device chunk,
# mixed per-request sampling (greedy + temperature 0.8 cycled through
# the stream) — sampled streams verify too, replayed with the engine's
# per-slot fold_in(base, rid) keys
python -m repro.launch.serve --arch qwen1.5-0.5b --smoke --stream \
    --pruned 0.75 --prompt-len 12 --gen 8 --requests 5 --arrive-every 2 \
    --ticks-per-sync 4 --request-temperatures 0,0.8 --top-k 16

# prefix caching (DESIGN.md §12): a burst of requests sharing one long
# prompt prefix — later arrivals map the cached pages (refcount bump)
# and prefill only their unique tails.  The command exits nonzero if any
# stream diverges from its solo decode OR if no admission actually hit
# the prefix cache, so the sharing path can't silently go dead
python -m repro.launch.serve --arch qwen1.5-0.5b --smoke --stream \
    --pruned 0.75 --prompt-len 16 --gen 8 --requests 5 --arrive-every 1 \
    --ticks-per-sync 4 --page-size 4 --shared-prefix

# fault tolerance (DESIGN.md §13): seeded chaos smoke — NaN poisoning,
# allocator failure, index corruption, a chunk crash, a cancel, a
# deadline and queue-overflow rejects, all injected into one stream.
# The command exits nonzero unless every request reaches a terminal
# status, every fault counter trips, non-faulted streams stay
# bit-identical to solo decode, and the page pool drains exactly
python -m repro.launch.serve --arch qwen1.5-0.5b --smoke --chaos \
    --pruned 0.75 --prompt-len 12 --gen 16 --requests 4 --batch 3 \
    --arrive-every 2 --ticks-per-sync 4 --page-size 8

# SLO-aware adaptive chunking (DESIGN.md §15): a same-tick burst of 8
# requests over 4 slots under the adaptive policy — the command exits
# nonzero unless every stream stays bit-identical to solo decode, at
# least one chunk-shrink event fired (the policy actually adapted), and
# every committed chunk length came from the declared compile set
python -m repro.launch.serve --arch qwen1.5-0.5b --smoke --stream \
    --adaptive --pruned 0.75 --prompt-len 12 --gen 8 --requests 8 \
    --batch 4 --arrive-every 0 --ticks-per-sync 16

# serving benchmark: dense vs packed {prefill, decode} -> BENCH_serving.json
# (full default size on purpose — ~10s on CPU, and the committed numbers
# should show the real packed-over-dense margin, which --quick thins out)
python benchmarks/bench_serving.py

# perf gates on the numbers just measured: packed decode must stay well
# ahead of dense, and packed prefill must not regress past 2x dense (it
# should BEAT dense — see BENCH_serving.json for the committed margin)
python - <<'PY'
import json
r = json.load(open("BENCH_serving.json"))
ds = r["decode_speedup"]
dp, pp = r["dense_prefill_ms"], r["packed_prefill_ms"]
assert ds >= 1.5, f"decode_speedup regressed: {ds:.2f}x < 1.5x"
assert pp <= 2.0 * dp, \
    f"packed prefill regressed >2x vs dense: {pp:.1f}ms vs {dp:.1f}ms"
# chunked streamed serving (DESIGN.md §10): batching >= 4 decode ticks
# into one on-device chunk must beat the single-tick (PR-4) loop on
# packed streamed throughput — the whole point of amortizing the host
# sync over the chunk
cb = r["continuous_batching"]
tick1 = cb["by_ticks_per_sync"]["1"]["packed_tok_s"]
tick4 = cb["by_ticks_per_sync"]["4"]["packed_tok_s"]
assert tick4 > tick1, \
    f"chunked streamed decode lost to single-tick: {tick4:.0f} vs {tick1:.0f} tok/s"
# fused paged-attention decode (DESIGN.md §11): the page walk must not
# lose to the legacy O(max_len) gather even at the LONGEST swept context
# (where both touch every live page — the fused win comes from never
# materializing the logical view); at short contexts the O(cache_len)
# scaling makes the margin much larger
pa = r["paged_attention"]
sp = pa["speedup_at_longest"]
assert sp >= 1.0, \
    f"fused paged decode lost to gather at ctx {pa['max_len']}: {sp:.2f}x"
# prefix caching (DESIGN.md §12): in the shared-prefix burst, requests
# that hit the cache skip the shared prefill entirely — their p50 TTFT
# must be at least 2x better than the same burst positions uncached,
# and the overall burst p50 must improve too
pc = r["prefix_caching"]["burst"]
hit = pc["ttft_speedup_hit_p50"]
assert pc["hit_requests"] > 0, "shared-prefix burst produced no cache hits"
assert hit >= 2.0, \
    f"prefix-cache hit TTFT speedup regressed: {hit:.2f}x < 2.0x"
assert pc["shared"]["ttft_p50_ms"] < pc["unshared"]["ttft_p50_ms"], \
    "shared-prefix burst p50 TTFT did not beat the uncached run"
# fault tolerance (DESIGN.md §13): the non-finite guard compiled into
# the decode chunk must cost < 5% streamed throughput on clean traffic
# vs the unguarded (PR-7) chunk — isolation is an isfinite reduction,
# not a second pass over the logits
ft = r["fault_tolerance"]
ov = ft["overhead_pct"]
assert ov < 5.0, \
    f"fault-guard overhead regressed: {ov:.1f}% >= 5% " \
    f"({ft['guard_on_tok_s']:.0f} vs {ft['guard_off_tok_s']:.0f} tok/s)"
# SLO-aware adaptive chunking (DESIGN.md §15): under the burst arrival
# pattern the adaptive policy must beat fixed ticks_per_sync=16 on p99
# TTFT (deterministic tick-space metric — boundaries land at slot-free
# events instead of the 16-tick grid) while keeping aggregate streamed
# throughput within 10% (wall clock, median of reps)
slo = r["slo_scheduling"]["burst"]
impr = slo["ttft_ticks_p99_improvement"]
ratio = slo["throughput_ratio"]
assert impr > 1.0, \
    f"adaptive p99 TTFT lost to fixed tps=16 on burst: " \
    f"{slo['adaptive']['ttft_ticks_p99']:.1f} vs " \
    f"{slo['fixed']['ttft_ticks_p99']:.1f} ticks ({impr:.2f}x)"
assert ratio >= 0.9, \
    f"adaptive throughput fell >10% behind fixed tps=16: " \
    f"{slo['adaptive']['tok_s']:.0f} vs {slo['fixed']['tok_s']:.0f} " \
    f"tok/s (ratio {ratio:.2f})"
print(f"bench gate: decode {ds:.2f}x, prefill {r['prefill_speedup']:.2f}x, "
      f"chunked stream {tick4 / tick1:.2f}x over single-tick, "
      f"fused paged decode {sp:.2f}x over gather at ctx {pa['max_len']}, "
      f"prefix-cache hit TTFT {hit:.2f}x, "
      f"fault-guard overhead {ov:+.1f}%, "
      f"adaptive burst p99 TTFT {impr:.2f}x at {ratio:.2f}x throughput OK")
PY

echo "check.sh: OK"
