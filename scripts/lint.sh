#!/usr/bin/env bash
# Static-analysis gate (DESIGN.md §14): run the JAX/Pallas-aware tracer
# lint over src/repro + benchmarks + examples and fail on any finding
# not in the checked-in analysis_baseline.json.
#
#   scripts/lint.sh                  # gate (what check.sh runs)
#   scripts/lint.sh --json           # machine-readable report
#   scripts/lint.sh --write-baseline # re-baseline after triage
#
# Extra args pass straight through to `python -m repro.analysis`.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m repro.analysis --fail-on-new "$@"
