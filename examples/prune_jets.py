"""Paper flagship: jet classification pruning with FPGA resource units.

    PYTHONPATH=src python examples/prune_jets.py [--rf 4] [--md]

Reproduces the Table II flow end-to-end: DSP-aware (--rf N) or
multi-dimensional DSP+BRAM-aware (--md, 18-bit) structures, iterative
knapsack pruning to the accuracy tolerance, reporting reductions in the
paper's own units (DSP blocks / BRAM36 blocks).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks/

from benchmarks.fpga_repro import FpgaResourceModel, bram_c, run_prune_experiment
from repro.core import BlockingSpec
from repro.data import JetsTask
from repro.models.cnn import init_jets_mlp, jets_mlp_forward


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rf", type=int, default=4)
    ap.add_argument("--md", action="store_true", help="BRAM-aware (18-bit)")
    ap.add_argument("--target", type=float, default=0.9)
    args = ap.parse_args()

    task = JetsTask()
    if args.md:
        bits = 18
        c = bram_c(bits)
        blocking = BlockingSpec(bk=args.rf * c, bn=1, consecutive=c)
        rm = FpgaResourceModel(rf=args.rf, precision_bits=bits, multi_dim=True)
        print(f"multi-dimensional pruning: RF={args.rf}, P={bits}b, C={c}")
    else:
        bits = 16
        blocking = BlockingSpec(bk=args.rf, bn=1)
        rm = FpgaResourceModel(rf=args.rf, precision_bits=bits)
        print(f"DSP-aware pruning: RF={args.rf}, P={bits}b")

    res = run_prune_experiment(
        init_fn=init_jets_mlp,
        forward=jets_mlp_forward,
        batch_fn=lambda s: task.batch(s, 256),
        val_batch=task.batch(99_999, 2048),
        blocking_per_layer={"default": blocking},
        models_per_layer=rm,
        target=(args.target, args.target),
        step_size=0.15,
        min_size=256,
    )
    print(f"baseline acc {res['baseline_acc']:.3f} -> pruned {res['pruned_acc']:.3f} "
          f"({res['iterations']} iterations)")
    print(f"DSP reduction:  {res['dsp_reduction']:.2f}x "
          f"(paper Table II, RF={args.rf}: 12.2x/11.9x/7.9x/5.8x for RF 2/4/8/16)")
    print(f"BRAM reduction: {res['bram_reduction']:.2f}x")
    print(f"structure sparsity: {res['structure_sparsity']:.1%}")


if __name__ == "__main__":
    main()
