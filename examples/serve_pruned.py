"""Serving with pruned weights through the zero-skipping BSR path.

    PYTHONPATH=src python examples/serve_pruned.py

Trains a small LM briefly, knapsack-prunes it at MXU-tile granularity,
packs the survivors with ``repro.sparse.pack_params``, and serves batched
greedy decoding straight on the packed params: every matmul routes through
the ``models/layers.matmul`` dispatch, so pruned tiles are *skipped* (the
paper's §III-C codegen on TPU).  The packed-vs-masked-dense equivalence is
spot-checked with ``unpack_params`` — the same oracle the tier-1 tests use.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import BlockingSpec, apply_masks
from repro.core.masks import _get_path
from repro.data import TokenTask
from repro.models import (
    init_caches,
    init_params,
    lm_decode,
    lm_generate,
    lm_prefill,
)
from repro.optim import AdamWConfig, constant_lr
from repro.sparse import knapsack_prune, pack_params, sparsity_summary, unpack_params
from repro.train import init_train_state, make_train_step


def main():
    cfg = get_config("qwen1.5-0.5b").replace(
        name="serve-demo", vocab=512, d_model=256, n_layers=2, n_heads=4,
        kv_heads=4, head_dim=64, d_ff=512, param_dtype="float32",
        activ_dtype="float32", remat="none", attn_chunk=64)
    params = init_params(jax.random.PRNGKey(0), cfg)

    # brief training so magnitudes are meaningful
    opt_cfg = AdamWConfig(use_master=False)
    state = init_train_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg, constant_lr(1e-3)))
    task = TokenTask(vocab=cfg.vocab, noise=0.02)
    for s in range(30):
        state, metrics = step(state, task.batch(s, 8, 64))
    params = state["params"]
    print(f"trained: loss={float(metrics['total_loss']):.3f}")

    # knapsack-prune the MLP weights at tile granularity, pack to BSR
    sel = knapsack_prune(
        params, sparsity=0.5, blocking=BlockingSpec(bk=128, bn=128),
        include=("mlp",), min_size=4096)
    print(f"knapsack kept {sel.kept}/{sel.total} structures "
          f"({sel.result.method}, feasible={sel.result.feasible}; "
          f"budget 50% MXU + 50% HBM)")
    packed = pack_params(params, sel.masks, sel.structures)
    summ = sparsity_summary(packed)
    for path, d in sorted(summ["per_path"].items()):
        print(f"  {path}: BSR density {d:.2f} "
              f"(skips {1-d:.0%} of MXU passes + HBM pages)")

    # serve through the hot path (DESIGN.md §7): batched prefill fills the
    # caches in one jitted call, then ONE lax.scan greedy-decodes with the
    # argmax on device — no host round-trip per token
    b, plen, steps = 4, 8, 16
    caches = init_caches(cfg, b, plen + steps, jnp.float32)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (b, plen), 0, cfg.vocab)
    prefill_fn = jax.jit(lambda p, c, t: lm_prefill(p, c, {"tokens": t}, cfg))
    generate_fn = jax.jit(lambda p, c, t, l: lm_generate(p, c, t, l, steps, cfg))
    logits, caches = prefill_fn(packed, caches, prompt)
    first = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    tokens, caches = generate_fn(packed, caches, first, jnp.asarray(plen, jnp.int32))
    tokens = np.asarray(tokens)          # the single host transfer

    # spot-check: the packed tree reconstructs to exactly masked dense,
    # and one decode step agrees between the two executions
    masked = apply_masks(params, sel.masks)
    recon = unpack_params(packed)
    path = sel.structures.infos[0].path
    np.testing.assert_allclose(
        np.asarray(_get_path(recon, path)),
        np.asarray(_get_path(masked, path)), atol=1e-6)

    caches_d = init_caches(cfg, b, 2, jnp.float32)
    caches_p = init_caches(cfg, b, 2, jnp.float32)
    tok0 = jnp.zeros((b, 1), jnp.int32)
    ld, _ = lm_decode(masked, caches_d, {"tokens": tok0},
                      jnp.asarray(0, jnp.int32), cfg)
    lp, _ = lm_decode(packed, caches_p, {"tokens": tok0},
                      jnp.asarray(0, jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(ld),
                               atol=1e-3, rtol=1e-4)
    print(f"decoded {steps} tokens x {b} seqs; BSR path == masked dense. done.")


if __name__ == "__main__":
    main()
