"""Serving with pruned weights through the zero-skipping BSR path.

    PYTHONPATH=src python examples/serve_pruned.py

Trains a small LM briefly, prunes its MLP weights at MXU-tile granularity,
packs survivors to BSR, and serves batched greedy decoding where every
pruned tile is *skipped* (the paper's §III-C codegen on TPU): resource
accounting shows the per-layer MXU-pass and HBM-page savings.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (
    BlockingSpec,
    TPUResourceModel,
    apply_masks,
    build_structures,
    masks_from_knapsack,
    pack_bsr,
    solve_mdkp,
)
from repro.core.masks import _get_path
from repro.core.structures import structure_norms_dense
from repro.data import TokenTask
from repro.kernels import bsr_matmul
from repro.models import init_caches, init_params, lm_decode
from repro.optim import AdamWConfig, constant_lr
from repro.train import init_train_state, make_train_step


def main():
    cfg = get_config("qwen1.5-0.5b").replace(
        name="serve-demo", vocab=512, d_model=256, n_layers=2, n_heads=4,
        kv_heads=4, head_dim=64, d_ff=512, param_dtype="float32",
        activ_dtype="float32", remat="none", attn_chunk=64)
    params = init_params(jax.random.PRNGKey(0), cfg)

    # brief training so magnitudes are meaningful
    opt_cfg = AdamWConfig(use_master=False)
    state = init_train_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg, constant_lr(1e-3)))
    task = TokenTask(vocab=cfg.vocab, noise=0.02)
    for s in range(30):
        state, metrics = step(state, task.batch(s, 8, 64))
    params = state["params"]
    print(f"trained: loss={float(metrics['total_loss']):.3f}")

    # knapsack-prune the MLP weights at tile granularity
    blocking = BlockingSpec(bk=128, bn=128)
    structures = build_structures(params, blocking, include=("mlp",),
                                  min_size=4096)
    rm = TPUResourceModel(precision="bf16")
    values, weights = [], []
    for info in structures.infos:
        w = _get_path(params, info.path)
        norms = np.asarray(structure_norms_dense(w, info)).ravel()
        values.append(norms / max(norms.max(), 1e-9))
        weights.append(np.tile(rm.structure_cost(info.blocking)[:, None],
                               (1, info.num_structures)))
    v = np.concatenate(values)
    u = np.concatenate(weights, axis=1)
    budget = u.sum(axis=1) * 0.5
    sel = solve_mdkp(v, u, budget)
    masks = masks_from_knapsack(params, structures, sel.x.astype(np.float32))
    print(f"knapsack kept {sel.x.sum()}/{len(sel.x)} structures "
          f"(budget 50% MXU + 50% HBM)")

    # serve: greedy decode with BSR-packed MLP weights
    mp = apply_masks(params, masks)
    bsr_weights = {}
    for info in structures.infos:
        w = _get_path(params, info.path)
        m = _get_path(masks, info.path)
        bsr_weights[info.path] = pack_bsr(np.asarray(w), info.blocking,
                                          mask=np.asarray(m))
        d = bsr_weights[info.path].density()
        print(f"  {info.path}: BSR density {d:.2f} "
              f"(skips {1-d:.0%} of MXU passes + HBM pages)")

    b, steps = 4, 16
    caches = init_caches(cfg, b, steps + 1, jnp.float32)
    tok = jnp.zeros((b, 1), jnp.int32)
    out = []
    for t in range(steps):
        logits, caches = lm_decode(mp, caches, {"tokens": tok},
                                   jnp.asarray(t, jnp.int32), cfg)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok[:, 0]))

    # spot-check: BSR matmul == masked dense
    info = structures.infos[0]
    wd = _get_path(mp, info.path)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, wd.shape[0]))
    np.testing.assert_allclose(
        np.asarray(bsr_matmul(x, bsr_weights[info.path])),
        np.asarray(x @ wd), atol=1e-4)
    print(f"decoded {steps} tokens x {b} seqs; BSR path == masked dense. done.")


if __name__ == "__main__":
    main()
