"""Quickstart: resource-aware structured pruning in ~60 seconds.

    PYTHONPATH=src python examples/quickstart.py

1. trains a 2-layer MLP on a synthetic task,
2. partitions its weights into MXU-tile structures (the paper's DSP-group
   analogue, §III-A),
3. solves the multi-dimensional knapsack (§III-B) to keep the most
   valuable structures under a 50% compute + 50% memory budget,
4. fine-tunes, packs survivors to block-sparse (BSR) and runs the
   zero-skipping kernel path, comparing resources before/after.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BlockingSpec,
    IterativePruner,
    PruneConfig,
    TPUResourceModel,
    apply_masks,
    build_structures,
    constant_step,
    init_masks,
    pack_bsr,
)
from repro.data import JetsTask
from repro.kernels import bsr_matmul
from repro.models.cnn import init_jets_mlp, jets_mlp_forward
from repro.optim import AdamWConfig, adamw_update, init_opt_state


def _train(params, masks, task, steps, lr=5e-3):
    opt_cfg = AdamWConfig(use_master=False, weight_decay=0.0)
    opt = init_opt_state(params, opt_cfg)

    @jax.jit
    def step(params, opt, x, y):
        def loss_fn(p):
            logits = jets_mlp_forward(apply_masks(p, masks), x)
            onehot = jax.nn.one_hot(y, logits.shape[-1])
            return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))

        grads = jax.grad(loss_fn)(params)
        return adamw_update(params, grads, opt, opt_cfg, jnp.asarray(lr), masks=masks)

    for s in range(steps):
        x, y = task.batch(s, 256)
        params, opt = step(params, opt, x, y)
    return params


def _accuracy(params, masks, batch):
    x, y = batch
    logits = jets_mlp_forward(apply_masks(params, masks), x)
    return float(jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32)))


def main():
    task = JetsTask()
    params = init_jets_mlp(jax.random.PRNGKey(0))

    # -- 1. resource-aware structures ------------------------------------
    blocking = BlockingSpec(bk=8, bn=8)        # the "RF" analogue
    structures = build_structures(params, blocking, min_size=256)
    rm = TPUResourceModel(precision="bf16")
    print(f"structures: {structures.total_structures} "
          f"(cost per structure = {rm.structure_cost(blocking)})")

    # -- 2. baseline training ----------------------------------------------
    masks = init_masks(params, structures)
    params = _train(params, masks, task, 150)
    val = task.batch(9_999, 2048)
    print(f"baseline accuracy: {_accuracy(params, masks, val):.3f}")

    # -- 3. iterative knapsack pruning (Algorithm 2) -------------------------
    pruner = IterativePruner(
        structures, rm,
        PruneConfig(schedule=constant_step([0.5, 0.5], 0.25), tolerance=0.03),
    )
    params, masks, logs = pruner.run(
        params,
        lambda p, m: _train(p, m, task, 40),
        lambda p, m: _accuracy(p, m, val),
    )
    for log in logs:
        red = log.reduction()
        print(f"  iter {log.iteration}: acc={log.metric:.3f} "
              f"structure sparsity={log.structure_sparsity:.1%} "
              f"MXU reduction={red[0]:.2f}x HBM reduction={red[1]:.2f}x")

    # -- 4. zero-skipping serving path ------------------------------------
    x, _ = task.batch(7, 32)
    mp = apply_masks(params, masks)
    w1 = params["fc_1"]["kernel"]
    bsr = pack_bsr(np.asarray(w1), blocking, mask=np.asarray(masks["fc_1"]["kernel"]))
    y_sparse = bsr_matmul(x, bsr)
    y_dense = x @ np.asarray(mp["fc_1"]["kernel"])
    print(f"BSR serving: density={bsr.density():.2f}, "
          f"max|sparse-dense|={float(jnp.abs(y_sparse - y_dense).max()):.2e}")
    print("done.")


if __name__ == "__main__":
    main()
