"""End-to-end driver: train an LM, then resource-aware-prune it, with
fault-tolerant checkpointing throughout.

    PYTHONPATH=src python examples/train_lm_pruned.py            # ~10M params, CPU-sized
    PYTHONPATH=src python examples/train_lm_pruned.py --full     # ~100M params, few hundred steps

Exercises the whole stack: deterministic data pipeline, Trainer
(preemption-safe, straggler monitor, async checkpoints), AdamW with fp32
state, then Algorithm-2 pruning of the attention/MLP weights at MXU-tile
granularity with knapsack selection and masked fine-tuning.
"""
import argparse
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (
    BlockingSpec,
    IterativePruner,
    PruneConfig,
    TPUResourceModel,
    apply_masks,
    build_structures,
    constant_step,
)
from repro.data import LMPipeline, TokenTask
from repro.models import cross_entropy_loss, init_params, lm_forward
from repro.optim import AdamWConfig, warmup_cosine
from repro.train import Trainer, TrainerConfig, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params / 300 steps (hours on CPU; sized for TPU)")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    base = get_config("qwen1.5-0.5b")
    if args.full:
        cfg = base.replace(
            name="lm-100m", vocab=32768, d_model=640, n_layers=12, n_heads=10,
            kv_heads=10, head_dim=64, d_ff=2560, param_dtype="float32",
            activ_dtype="float32", remat="none", attn_chunk=256)
        steps = args.steps or 300
        batch, seq = 16, 512
    else:
        cfg = base.replace(
            name="lm-10m", vocab=2048, d_model=256, n_layers=4, n_heads=4,
            kv_heads=4, head_dim=64, d_ff=1024, param_dtype="float32",
            activ_dtype="float32", remat="none", attn_chunk=128)
        steps = args.steps or 60
        batch, seq = 8, 128

    n_params = cfg.param_count()
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params, {steps} steps")

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_lm_")
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(use_master=False)
    state = init_train_state(params, opt_cfg)
    step_fn = jax.jit(make_train_step(
        cfg, opt_cfg, warmup_cosine(3e-4, max(steps // 10, 1), steps)))
    task = TokenTask(vocab=cfg.vocab, noise=0.02)
    pipe = LMPipeline(task, batch, seq)

    trainer = Trainer(
        step_fn, state, pipe.batch_at,
        TrainerConfig(total_steps=steps, ckpt_every=max(steps // 4, 10),
                      ckpt_dir=ckpt_dir, log_every=max(steps // 10, 1)),
    )
    result = trainer.run()
    m = result["metrics"]
    print(f"training: loss {m[0]['total_loss']:.3f} -> {m[-1]['total_loss']:.3f} "
          f"({result['final_step']} steps, ckpts in {ckpt_dir})")

    # ---- paper technique: prune the trained LM ------------------------------
    params = trainer.state["params"]
    structures = build_structures(params, BlockingSpec(bk=64, bn=128),
                                  min_size=16_384)
    rm = TPUResourceModel(precision="bf16")
    pruner = IterativePruner(
        structures, rm,
        PruneConfig(schedule=constant_step([0.4, 0.4], 0.2), tolerance=0.10,
                    higher_is_better=False),
    )
    val = pipe.batch_at(1_000_000)

    def eval_fn(p, masks):
        logits, _ = lm_forward(apply_masks(p, masks), val, cfg)
        return float(cross_entropy_loss(logits, val["labels"]))

    def finetune_fn(p, masks):
        st = init_train_state(p, opt_cfg, masks=masks)
        fstep = jax.jit(make_train_step(cfg, opt_cfg, warmup_cosine(1e-4, 2, 30)))
        for s in range(15):
            st, _ = fstep(st, pipe.batch_at(2_000_000 + s))
        return st["params"]

    params, masks, logs = pruner.run(params, finetune_fn, eval_fn)
    for log in logs:
        red = log.reduction()
        print(f"prune iter {log.iteration}: val loss={log.metric:.3f} "
              f"structures pruned={log.structure_sparsity:.1%} "
              f"MXU={red[0]:.2f}x HBM={red[1]:.2f}x")
    print("done.")
    if args.ckpt_dir is None:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
