"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import math
from typing import Callable

import jax.numpy as jnp

__all__ = ["warmup_cosine", "constant_lr", "linear_decay"]

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def constant_lr(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1) -> Schedule:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac * peak + (1 - final_frac) * peak * 0.5 * (1 + jnp.cos(math.pi * t))
        return jnp.where(step < warmup_steps, warm, cos)

    return fn


def linear_decay(peak: float, total_steps: int) -> Schedule:
    def fn(step):
        t = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0.0, 1.0)
        return peak * (1.0 - t)

    return fn
