"""Gradient compression for cross-pod all-reduce: int8 quantized psum with
error feedback (1-bit-Adam-family trick, adapted to jax collectives).

Used on the "pod" mesh axis where inter-pod links are the scarce resource
(DESIGN.md §4): per-step gradient traffic shrinks 4x vs fp32 / 2x vs bf16
at equal step quality (the error-feedback buffer re-injects quantization
residuals next step).

Protocol (inside shard_map over the compressed axis):
  1. shared scale  s = psum_max(|g|) / 127           (tiny collective)
  2. q  = round((g + e) / s)  -> int8, clip [-127,127]
  3. Q  = psum(q as int32)                            (the big collective, 1B/elem)
  4. out = Q * s / n_shards ; e' = (g + e) - q * s

The public entry is ``compressed_psum_tree`` for a grad pytree, plus a
``none`` passthrough. On meshes without the axis it degrades gracefully.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["compressed_psum", "compressed_psum_tree", "init_error_buffers"]


def compressed_psum(
    g: jnp.ndarray, err: jnp.ndarray, axis_name: str
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """int8 error-feedback psum over ``axis_name`` (call under shard_map)."""
    gf = g.astype(jnp.float32) + err
    amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    out = (total.astype(jnp.float32) * scale / n.astype(jnp.float32)).astype(g.dtype)
    new_err = gf - q.astype(jnp.float32) * scale
    return out, new_err


def init_error_buffers(grads) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum_tree(grads, errors, mesh, axis_name: str = "pod",
                         pspecs=None):
    """Mean-reduce a grad pytree over ``axis_name`` with int8 compression.

    ``pspecs``: PartitionSpec pytree describing how each leaf is laid out
    over the *other* mesh axes (the leaves must be replicated over
    ``axis_name`` — the standard per-pod partial-gradient layout).  Without
    it, leaves are treated as replicated."""
    if axis_name not in mesh.axis_names or mesh.shape[axis_name] == 1:
        return grads, errors

    from repro.distributed.sharding import shard_map

    flat_g, td = jax.tree.flatten(grads)
    flat_e = td.flatten_up_to(errors)
    if pspecs is None:
        flat_s = [P() for _ in flat_g]
    else:
        flat_s = [s if s is not None else P() for s in td.flatten_up_to(pspecs)]

    out = []
    for g, e, spec in zip(flat_g, flat_e, flat_s):
        fn = shard_map(
            lambda gs, es: compressed_psum(gs, es, axis_name),
            mesh=mesh, in_specs=(spec, spec), out_specs=(spec, spec),
            check=False,
        )
        out.append(fn(g, e))
    return (
        jax.tree.unflatten(td, [o[0] for o in out]),
        jax.tree.unflatten(td, [o[1] for o in out]),
    )
