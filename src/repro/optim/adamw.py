"""AdamW with fp32 state, optional fp32 master weights, and mask-aware
updates (pruned structures receive no updates and stay exactly zero).

No optax offline — this is a from-scratch, pytree-native implementation.
State layout (a pytree mirroring params):

    {"m": fp32, "v": fp32, "master": fp32 (optional), "count": ()}

Masking semantics for iterative pruning (paper Alg. 2 fine-tuning): the
forward uses ``params * mask``; gradients are therefore already
mask-scaled, but weight decay and Adam moments would drift pruned weights
off zero — so the update itself is re-masked.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update", "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    use_master: bool = True     # fp32 master copies for bf16 params


def _is_leaf(x):
    return x is None


def init_opt_state(params, cfg: AdamWConfig) -> Dict[str, Any]:
    zeros32 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    state = {
        "m": zeros32,
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "count": jnp.zeros((), jnp.int32),
    }
    if cfg.use_master:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(
    params,
    grads,
    state: Dict[str, Any],
    cfg: AdamWConfig,
    lr: jnp.ndarray,
    masks: Optional[Mapping[str, Any]] = None,
) -> Tuple[Any, Dict[str, Any]]:
    """One AdamW step. Returns (new_params, new_state)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v, master, mask):
        gf = g.astype(jnp.float32)
        if mask is not None:
            gf = gf * mask.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * gf
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(gf)
        mh = m / b1c
        vh = v / b2c
        base = master if master is not None else p.astype(jnp.float32)
        step = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * base
        new_master = base - lr * step
        if mask is not None:
            new_master = new_master * mask.astype(jnp.float32)
            m = m * mask.astype(jnp.float32)
            v = v * mask.astype(jnp.float32)
        return new_master.astype(p.dtype), m, v, new_master

    mask_tree = masks if masks is not None else jax.tree.map(lambda _: None, params)
    master_tree = state.get("master", jax.tree.map(lambda _: None, params))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_ma = treedef.flatten_up_to(master_tree)
    flat_mask = treedef.flatten_up_to(mask_tree) if masks is not None else [None] * len(flat_p)

    new_p, new_m, new_v, new_master = [], [], [], []
    for p, g, m, v, ma, mk in zip(flat_p, flat_g, flat_m, flat_v, flat_ma, flat_mask):
        np_, nm, nv, nma = upd(p, g, m, v, ma, mk)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
        new_master.append(nma)

    new_state = {
        "m": jax.tree.unflatten(treedef, new_m),
        "v": jax.tree.unflatten(treedef, new_v),
        "count": count,
    }
    if "master" in state:
        new_state["master"] = jax.tree.unflatten(treedef, new_master)
    return jax.tree.unflatten(treedef, new_p), new_state
