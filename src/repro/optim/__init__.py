"""Optimization substrate: AdamW (masked), schedules, grad compression."""
from .adamw import AdamWConfig, adamw_update, clip_by_global_norm, global_norm, init_opt_state
from .compression import compressed_psum, compressed_psum_tree, init_error_buffers
from .schedule import constant_lr, linear_decay, warmup_cosine

__all__ = [
    "AdamWConfig", "adamw_update", "clip_by_global_norm", "global_norm",
    "init_opt_state", "compressed_psum", "compressed_psum_tree",
    "init_error_buffers", "constant_lr", "linear_decay", "warmup_cosine",
]
