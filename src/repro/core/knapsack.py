"""Knapsack solvers for resource-aware pruning (paper §III-B, Eq. 5-8).

The paper solves the 0-1 multi-dimensional knapsack (MDKP) with OR-Tools
branch-and-cut.  OR-Tools is unavailable offline, so this module provides:

* ``solve_dp``          exact dynamic program for the 1-D integer knapsack
                        (FPTAS via value scaling for float weights),
* ``solve_greedy``      density greedy for MDKP (Toyoda-style aggregate),
* ``solve_mdkp``        greedy + Lagrangian tightening + 1-swap local
                        search — the production solver,
* ``solve_brute``       exact enumeration for <= 22 items (test oracle).

All solvers take ``values (n,)``, ``weights (m, n)``, ``capacity (m,)`` and
return a boolean selection ``x (n,)`` with the paper's semantics
(Eq. 6: x_i = 0 => structure pruned).

Scale note: the assigned LMs have 1e5-1e6 structures.  The greedy path is
O(n log n) with vectorized numpy; the DP path is used for per-layer refine
and tests.  For the (very common) special case where every item consumes
the same resource vector — a homogeneous layer — MDKP degenerates to top-k
by value, which the solver detects and short-circuits.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "KnapsackResult",
    "solve_dp",
    "solve_greedy",
    "solve_brute",
    "solve_mdkp",
]


@dataclasses.dataclass
class KnapsackResult:
    x: np.ndarray            # bool (n,)
    value: float
    used: np.ndarray         # (m,) resources consumed
    method: str
    feasible: bool = True    # used <= capacity at construction time


def _make_result(x, values, weights, capacity, method) -> KnapsackResult:
    """Build a result with ``feasible`` computed from used <= capacity."""
    used = weights @ x
    return KnapsackResult(
        x=x,
        value=float(values @ x),
        used=used,
        method=method,
        feasible=bool(np.all(used <= capacity + 1e-9)),
    )


def _validate(values, weights, capacity):
    values = np.asarray(values, dtype=np.float64)
    weights = np.atleast_2d(np.asarray(weights, dtype=np.float64))
    capacity = np.atleast_1d(np.asarray(capacity, dtype=np.float64))
    if weights.shape[0] != capacity.shape[0]:
        raise ValueError(
            f"weights {weights.shape} vs capacity {capacity.shape}: resource dims differ"
        )
    if weights.shape[1] != values.shape[0]:
        raise ValueError(f"{weights.shape[1]} items in weights vs {values.shape[0]} values")
    if np.any(weights < 0):
        raise ValueError("negative resource weights")
    return values, weights, capacity


def solve_brute(values, weights, capacity) -> KnapsackResult:
    """Exact enumeration — oracle for tests. O(2^n), n <= 22."""
    values, weights, capacity = _validate(values, weights, capacity)
    n = values.shape[0]
    if n > 22:
        raise ValueError("brute force limited to 22 items")
    best_v, best_x = -1.0, np.zeros(n, dtype=bool)
    for code in range(1 << n):
        x = np.array([(code >> i) & 1 for i in range(n)], dtype=bool)
        used = weights @ x
        if np.all(used <= capacity + 1e-9):
            v = float(values @ x)
            if v > best_v:
                best_v, best_x = v, x
    return _make_result(best_x, values, weights, capacity, "brute")


def solve_dp(values, weights, capacity, *, scale: int = 4096) -> KnapsackResult:
    """Exact 1-D 0/1 knapsack via DP over integerized weights.

    Float weights are scaled to integers (floor for weights — optimistic,
    then a feasibility repair pass drops lowest-density items if the real
    constraint is violated; with integer inputs this is exact).
    """
    values, weights, capacity = _validate(values, weights, capacity)
    if weights.shape[0] != 1:
        raise ValueError("solve_dp is 1-D; use solve_mdkp")
    w = weights[0]
    c = float(capacity[0])
    n = values.shape[0]
    if c <= 0:
        x = np.zeros(n, dtype=bool)
        return _make_result(x, values, weights, capacity, "dp")

    int_like = np.allclose(w, np.round(w)) and abs(c - round(c)) < 1e-9
    if int_like:
        wi = np.round(w).astype(np.int64)
        ci = int(round(c))
    else:
        f = scale / max(c, 1e-12)
        wi = np.ceil(w * f - 1e-12).astype(np.int64)  # ceil => never infeasible
        ci = int(np.floor(c * f + 1e-12))
    wi = np.maximum(wi, 0)

    NEG = -np.inf
    dp = np.full(ci + 1, NEG)
    dp[0] = 0.0
    choice = np.zeros((n, ci + 1), dtype=bool)
    for i in range(n):
        if wi[i] > ci:
            continue
        if wi[i] == 0:
            if values[i] > 0:
                dp = dp + values[i]
                choice[i, :] = True
            continue
        cand = np.full(ci + 1, NEG)
        cand[wi[i]:] = dp[:-wi[i]] + values[i]
        take = cand > dp
        choice[i, :] = take
        dp = np.where(take, cand, dp)

    # backtrack
    x = np.zeros(n, dtype=bool)
    j = int(np.argmax(dp))
    for i in range(n - 1, -1, -1):
        if choice[i, j]:
            x[i] = True
            j -= int(wi[i])
    used = weights @ x
    # repair (only possible in scaled-float mode)
    if used[0] > c + 1e-9:
        order = np.argsort(values[x] / np.maximum(w[x], 1e-12))
        idx = np.flatnonzero(x)[order]
        for i in idx:
            if used[0] <= c + 1e-9:
                break
            x[i] = False
            used = weights @ x
    return _make_result(x, values, weights, capacity, "dp")


def _greedy_order(values, weights, capacity, mults) -> np.ndarray:
    """Items sorted by Toyoda density with Lagrange multipliers."""
    denom = mults @ weights  # (n,)
    denom = np.where(denom <= 0, 1e-18, denom)
    zero_cost = np.all(weights <= 0, axis=0)
    density = np.where(zero_cost, np.inf, values / denom)
    return np.argsort(-density, kind="stable")


def _greedy_fill(values, weights, capacity, order) -> np.ndarray:
    """Vectorized greedy fill along ``order``.

    Fast path: prefix sums + searchsorted to find the fill frontier, then a
    short scalar pass from the frontier onward (items skipped for one
    resource may still fit later ones).
    """
    n = values.shape[0]
    x = np.zeros(n, dtype=bool)
    w_ord = weights[:, order]
    pref = np.cumsum(w_ord, axis=1)
    fits = np.all(pref <= capacity[:, None] + 1e-9, axis=0)
    frontier = int(np.searchsorted(~fits, True))  # first False
    x[order[:frontier]] = True
    used = weights[:, order[:frontier]].sum(axis=1) if frontier else np.zeros(weights.shape[0])
    # scalar tail: try remaining items individually
    for idx in order[frontier:]:
        wi = weights[:, idx]
        if np.all(used + wi <= capacity + 1e-9):
            x[idx] = True
            used = used + wi
    return x


def solve_greedy(values, weights, capacity, *, mults: Optional[np.ndarray] = None) -> KnapsackResult:
    values, weights, capacity = _validate(values, weights, capacity)
    m = weights.shape[0]
    if mults is None:
        # normalize each resource by its capacity so dims are comparable
        mults = 1.0 / np.maximum(capacity, 1e-12)
    order = _greedy_order(values, weights, capacity, mults)
    x = _greedy_fill(values, weights, capacity, order)
    return _make_result(x, values, weights, capacity, "greedy")


def _uniform_rows(weights: np.ndarray) -> bool:
    """True if every item has the identical resource vector."""
    if weights.shape[1] == 0:
        return True
    first = weights[:, :1]
    return bool(np.all(np.abs(weights - first) <= 1e-12 * (1 + np.abs(first))))


def solve_mdkp(
    values,
    weights,
    capacity,
    *,
    refine_iters: int = 8,
    swap_budget: int = 512,
) -> KnapsackResult:
    """Production MDKP solver: homogeneous shortcut → greedy → Lagrangian
    multiplier search → 1-swap local improvement.

    Returns a feasible solution always; on homogeneous instances it is
    exactly optimal (top-k), on small instances tests compare it against
    ``solve_brute`` (observed gap < 2%).
    """
    values, weights, capacity = _validate(values, weights, capacity)
    n = values.shape[0]
    m = weights.shape[0]
    if n == 0:
        return _make_result(np.zeros(0, bool), values, weights, capacity, "mdkp")

    if n <= 20 and not _uniform_rows(weights):
        return solve_brute(values, weights, capacity)   # exact on small instances

    if _uniform_rows(weights):
        # top-k by value: k limited by the tightest resource
        w0 = weights[:, 0]
        with np.errstate(divide="ignore", invalid="ignore"):
            kmax = np.where(w0 > 0, np.floor(capacity / np.maximum(w0, 1e-300) + 1e-9), np.inf)
        k = int(min(n, np.min(kmax)))
        x = np.zeros(n, dtype=bool)
        if k > 0:
            x[np.argsort(-values, kind="stable")[:k]] = True
        return _make_result(x, values, weights, capacity, "mdkp-topk")

    best = solve_greedy(values, weights, capacity)
    if m == 1:
        # exact-ish DP beats greedy on adversarial 1-D instances
        cand = solve_dp(values, weights, capacity)
        if cand.value > best.value and np.all(cand.used <= capacity + 1e-9):
            best = cand
    # Lagrangian multiplier search: upweight violated/tight dims
    mults = 1.0 / np.maximum(capacity, 1e-12)
    for _ in range(refine_iters):
        used_frac = best.used / np.maximum(capacity, 1e-12)
        mults = mults * (0.5 + used_frac)  # tighten binding constraints
        mults = mults / max(mults.sum(), 1e-18)
        cand = solve_greedy(values, weights, capacity, mults=mults)
        if cand.value > best.value:
            best = cand

    # Sahni-style forced-item repair: greedy misses "one big valuable item"
    # solutions; force each of the top-valued items in, greedy the rest.
    if n <= 4096:
        top = np.argsort(-values)[: min(16, n)]
        base_mults = 1.0 / np.maximum(capacity, 1e-12)
        for i in top:
            if best.x[i]:
                continue
            wi = weights[:, i]
            if np.any(wi > capacity + 1e-9):
                continue
            rem_cap = capacity - wi
            v2 = values.copy()
            v2[i] = 0.0
            order = _greedy_order(v2, weights, rem_cap, base_mults)
            order = order[order != i]
            x2 = _greedy_fill(v2, weights, rem_cap, order)
            x2[i] = True
            val2 = float(values @ x2)
            if val2 > best.value and np.all(weights @ x2 <= capacity + 1e-9):
                best = _make_result(x2, values, weights, capacity, "mdkp-forced")

    # 1-swap local search on the value frontier
    x = best.x.copy()
    used = weights @ x
    out_idx = np.flatnonzero(~x)
    in_idx = np.flatnonzero(x)
    if out_idx.size and in_idx.size:
        out_order = out_idx[np.argsort(-values[out_idx])][:swap_budget]
        in_order = in_idx[np.argsort(values[in_idx])][:swap_budget]
        for o in out_order:
            fit = np.all(used + weights[:, o] <= capacity + 1e-9)
            if fit:
                x[o] = True
                used = used + weights[:, o]
                continue
            for i in in_order:
                if not x[i] or values[i] >= values[o]:
                    continue
                trial = used - weights[:, i] + weights[:, o]
                if np.all(trial <= capacity + 1e-9):
                    x[i] = False
                    x[o] = True
                    used = trial
                    break
    if float(values @ x) < best.value:
        x = best.x
    return _make_result(x, values, weights, capacity, "mdkp")
