"""repro.core — FPGA resource-aware structured pruning, TPU-native.

The paper's contribution as a composable JAX library:

* structures       resource-aware tensor structures (RF/C -> MXU tiles)
* resource_model   vector resource estimation R(w) (DSP/BRAM -> MXU/HBM)
* knapsack         MDKP solvers (Eq. 5-8)
* masks            mask pytrees + sparsity accounting
* regularizer      resource-aware group lasso
* schedule         sparsity schedules f(s)
* pruner           Algorithm 2 iterative loop
* packing          BSR packing for the zero-skipping serving path (§III-C)
"""
from .knapsack import KnapsackResult, solve_brute, solve_dp, solve_greedy, solve_mdkp
from .masks import (
    apply_masks,
    build_structures,
    count_zero_structures,
    init_masks,
    masks_from_knapsack,
    sparsity_report,
)
from .packing import BSRWeight, bsr_to_dense, pack_bsr
from .pruner import IterativePruner, PruneConfig, PruneIterationLog
from .regularizer import group_lasso, make_regularizer
from .resource_model import TPU_V5E, HardwareSpec, TPUResourceModel, consecutive_groups
from .schedule import SparsitySchedule, constant_step, cubic
from .structures import (
    BlockingSpec,
    LayerStructures,
    StructureInfo,
    block_partition,
    iter_prunable,
    mask_from_selection,
    structure_norms_dense,
)

__all__ = [
    "KnapsackResult", "solve_brute", "solve_dp", "solve_greedy", "solve_mdkp",
    "apply_masks", "build_structures", "count_zero_structures", "init_masks",
    "masks_from_knapsack", "sparsity_report",
    "BSRWeight", "bsr_to_dense", "pack_bsr",
    "IterativePruner", "PruneConfig", "PruneIterationLog",
    "group_lasso", "make_regularizer",
    "TPU_V5E", "HardwareSpec", "TPUResourceModel", "consecutive_groups",
    "SparsitySchedule", "constant_step", "cubic",
    "BlockingSpec", "LayerStructures", "StructureInfo", "block_partition",
    "iter_prunable", "mask_from_selection", "structure_norms_dense",
]
