"""Iterative resource-aware pruning — the paper's Algorithm 2.

    identify structures W = {w_1..w_n}
    R_B <- sum R(w_i);  b <- evaluate(N; W, D_val)
    while s <= s_T and p >= (1 - tol) * b:
        v_i  <- ||w_i|| / max_{w_j in layer} ||w_j||
        solve MDKP(v, U, (1-s) ⊙ R_B)  ->  selected set Ŵ
        fine-tune N(Ŵ) with group regularization
        p <- evaluate;  s <- f(s)

The loop is host-side (numpy + knapsack); the value computation, masking
and fine-tuning are jitted JAX.  ``finetune_fn`` and ``eval_fn`` are
injected so the same pruner drives the paper's Keras-scale benchmarks and
the assigned LM architectures.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Union

import jax
import numpy as np

from .knapsack import KnapsackResult, solve_mdkp
from .masks import (
    _get_path,
    count_zero_structures,
    init_masks,
    masks_from_knapsack,
    sparsity_report,
)
from .resource_model import TPUResourceModel
from .schedule import SparsitySchedule
from .structures import LayerStructures, structure_norms_dense

logger = logging.getLogger("repro.pruner")

__all__ = ["PruneConfig", "PruneIterationLog", "IterativePruner"]

ResourceModels = Union[TPUResourceModel, Mapping[str, TPUResourceModel]]


@dataclasses.dataclass
class PruneConfig:
    schedule: SparsitySchedule
    tolerance: float = 0.02          # paper: stop when acc drops > 2% relative
    exclude_zero: bool = True        # never re-select dead structures
    max_iters: int = 100
    higher_is_better: bool = True    # eval metric direction (accuracy vs loss)


@dataclasses.dataclass
class PruneIterationLog:
    iteration: int
    sparsity: np.ndarray
    metric: float
    knapsack_value: float
    knapsack_method: str
    resources_used: np.ndarray
    resources_baseline: np.ndarray
    structure_sparsity: float
    weight_sparsity: float
    seconds: float

    def reduction(self) -> np.ndarray:
        """Paper-style 'X x' reduction factors per resource."""
        with np.errstate(divide="ignore"):
            return np.where(
                self.resources_used > 0,
                self.resources_baseline / np.maximum(self.resources_used, 1e-300),
                np.inf,
            )


class IterativePruner:
    """Drives Algorithm 2 over a params pytree."""

    def __init__(
        self,
        structures: LayerStructures,
        resource_models: ResourceModels,
        config: PruneConfig,
    ):
        self.structures = structures
        self.config = config
        self._models = resource_models
        self._weights = self._build_weight_matrix()
        self._baseline = self._weights.sum(axis=1)

    # -- resource side ------------------------------------------------------

    def model_for(self, path: str) -> TPUResourceModel:
        if isinstance(self._models, TPUResourceModel):
            return self._models
        return self._models.get(path, self._models.get("default"))

    def _build_weight_matrix(self) -> np.ndarray:
        """U: (m, n) resource consumption per structure (static)."""
        cols: List[np.ndarray] = []
        for info in self.structures.infos:
            rm = self.model_for(info.path)
            cost = rm.structure_cost(info.blocking)  # (m,)
            cols.append(np.tile(cost[:, None], (1, info.num_structures)))
        if not cols:
            return np.zeros((2, 0))
        return np.concatenate(cols, axis=1)

    @property
    def baseline_resources(self) -> np.ndarray:
        return self._baseline

    # -- value side -----------------------------------------------------------

    def values(self, params: Mapping[str, Any]) -> np.ndarray:
        """Layer-normalized structure magnitudes (paper Eq. 4)."""
        vals: List[np.ndarray] = []
        for info in self.structures.infos:
            w = _get_path(params, info.path)
            norms = np.asarray(structure_norms_dense(w, info)).reshape(-1)
            denom = float(norms.max()) if norms.size else 1.0
            vals.append(norms / max(denom, 1e-12))
        return np.concatenate(vals) if vals else np.zeros(0)

    # -- one knapsack step ----------------------------------------------------

    def prune_step(
        self, params: Mapping[str, Any], sparsity: np.ndarray
    ) -> tuple[Dict[str, Any], KnapsackResult]:
        values = self.values(params)
        capacity = (1.0 - np.asarray(sparsity)) * self._baseline
        weights = self._weights
        if self.config.exclude_zero:
            dead = values <= 1e-12
            values = np.where(dead, 0.0, values)
            weights = np.where(dead[None, :], np.inf, weights)
            # structures with inf weight can never be selected by any solver
            # path (they never fit) — enforce cheaply by zeroing instead:
            weights = np.where(np.isinf(weights), capacity.max() * 2 + 1.0, weights)
        result = solve_mdkp(values, weights, capacity)
        masks = masks_from_knapsack(params, self.structures, result.x.astype(np.float32))
        # report true resource usage (without the exclusion inflation)
        result.used = self._weights @ result.x
        return masks, result

    # -- full loop --------------------------------------------------------------

    def run(
        self,
        params: Mapping[str, Any],
        finetune_fn: Callable[[Mapping[str, Any], Mapping[str, Any]], Mapping[str, Any]],
        eval_fn: Callable[[Mapping[str, Any], Mapping[str, Any]], float],
    ) -> tuple[Mapping[str, Any], Dict[str, Any], List[PruneIterationLog]]:
        """Returns (params, masks, logs). Rolls back to the last state within
        tolerance if the final iteration broke the accuracy budget."""
        cfg = self.config
        masks = init_masks(params, self.structures)
        baseline_metric = float(eval_fn(params, masks))
        sign = 1.0 if cfg.higher_is_better else -1.0
        bound = baseline_metric - sign * cfg.tolerance * abs(baseline_metric)

        logs: List[PruneIterationLog] = []
        s = np.zeros_like(np.asarray(cfg.schedule.target, dtype=np.float64))
        best = (params, masks)
        for it in range(cfg.max_iters):
            if cfg.schedule.reached(s):
                break
            s = cfg.schedule(s, it)
            t0 = time.time()
            masks, result = self.prune_step(params, s)
            params = finetune_fn(params, masks)
            metric = float(eval_fn(params, masks))
            rep = sparsity_report(params, masks, self.structures)
            logs.append(
                PruneIterationLog(
                    iteration=it,
                    sparsity=s.copy(),
                    metric=metric,
                    knapsack_value=result.value,
                    knapsack_method=result.method,
                    resources_used=result.used,
                    resources_baseline=self._baseline,
                    structure_sparsity=rep["structure_sparsity"],
                    weight_sparsity=rep["weight_sparsity"],
                    seconds=time.time() - t0,
                )
            )
            ok = (metric >= bound) if cfg.higher_is_better else (metric <= bound)
            logger.info(
                "prune it=%d s=%s metric=%.4f (baseline %.4f) structs=%.1f%% %s",
                it, np.array2string(s, precision=2), metric, baseline_metric,
                100 * rep["structure_sparsity"], "ok" if ok else "TOLERANCE BREAK",
            )
            if not ok:
                params, masks = best  # roll back
                break
            best = (params, masks)
        return params, masks, logs
