"""TPU resource-estimation function R(w) (paper §III-B, Eq. 1 adapted).

The paper's ``R: R^k -> R^m`` maps a resource-aware structure to its vector
of hardware costs — there, (DSP blocks, BRAM blocks).  Here the two modeled
resources are:

* ``mxu``  — MXU tile-passes per activation row-block: how many 128x128
  systolic passes the structure's weights occupy.  A (bk, bn) tile costs
  ``(bk/128)·(bn/128)`` passes (fractional for sub-tile blocks — they still
  occupy a full lane/sublane slot, so we ceil at the *register* granularity
  (8, 128), mirroring how a half-used DSP is still a DSP).
* ``hbm``  — HBM streaming pages: bytes the structure occupies on the
  HBM->VMEM path, in units of ``dma_page_bytes``.  Shared pages mean a
  structure only frees a page when all ``C`` tiles of the super-block are
  pruned — the paper's Eq. 1 consecutive-group condition.

Eq. 1 analogue::

    C = page/Bt           if page ≡ 0 (mod Bt)
        ceil(2·page/Bt)   otherwise

with ``Bt = bk·bn·bytes_per_weight`` the tile footprint — identical logic to
the paper's 36-bit BRAM word with precision P.

Like the paper's LUT case (P < 10 bits → multiplications in LUTs → zero DSP
cost), precisions at or below ``int8`` on TPU halve / quarter MXU passes;
``int4`` packs 4x.  The table below mirrors the paper's case analysis.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import numpy as np

from .structures import BlockingSpec, StructureInfo

__all__ = [
    "TPUResourceModel",
    "ResourceVector",
    "consecutive_groups",
    "HardwareSpec",
    "TPU_V5E",
]


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Roofline constants for the target chip."""

    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12     # per chip
    hbm_bw: float = 819e9               # bytes/s
    ici_bw: float = 50e9                # bytes/s/link
    vmem_bytes: int = 128 * 1024 * 1024
    mxu_dim: int = 128                  # systolic array side
    sublane: int = 8
    dma_page_bytes: int = 512           # HBM burst granule (BRAM-word analogue)


TPU_V5E = HardwareSpec()

# bytes per weight by precision name; < 1.0 entries pack multiple weights
_BYTES = {"fp32": 4.0, "bf16": 2.0, "fp16": 2.0, "int8": 1.0, "fp8": 1.0, "int4": 0.5}
# MXU pass multiplier: int8 runs 2 weights/lane-pass on v5e-class MXUs,
# int4 packs 4 (the paper's "LUT multiplication" analogue is the cheaper
# compute path unlocked by low precision).
_MXU_SCALE = {"fp32": 2.0, "bf16": 1.0, "fp16": 1.0, "int8": 0.5, "fp8": 0.5, "int4": 0.25}

ResourceVector = np.ndarray  # shape (m,) float64


def consecutive_groups(page_bytes: int, tile_bytes: float) -> int:
    """Paper Eq. 1: tiles per memory super-block.

    If the tile footprint divides the page, C = page/tile; otherwise pruning
    must capture a window of twice the page to guarantee at least one page
    is freed: C = ceil(2·page/tile).  (Degenerate big tiles: C = 1.)
    """
    if tile_bytes >= page_bytes:
        return 1
    ratio = page_bytes / tile_bytes
    if abs(ratio - round(ratio)) < 1e-9:
        return int(round(ratio))
    return int(math.ceil(2.0 * page_bytes / tile_bytes))


@dataclasses.dataclass(frozen=True)
class TPUResourceModel:
    """Vector-valued resource estimator for one layer's structures.

    resources modeled (m = 2): [mxu_passes, hbm_pages]

    strategy:
      "stream"   weights streamed HBM->VMEM every step (paper Resource
                 strategy: BRAM-resident) — pays both mxu and hbm.
      "resident" weights pinned in VMEM (paper Latency strategy:
                 register-resident) — pays mxu only; hbm component 0,
                 like the paper's CONV layers where BRAM is not used.
    """

    precision: str = "bf16"
    strategy: str = "stream"
    hw: HardwareSpec = TPU_V5E

    @property
    def bytes_per_weight(self) -> float:
        return _BYTES[self.precision]

    def tile_bytes(self, blocking: BlockingSpec) -> float:
        return blocking.bk * blocking.bn * self.bytes_per_weight

    def consecutive(self, blocking: BlockingSpec) -> int:
        """Effective C for BRAM-aware (multi-dimensional) pruning."""
        return consecutive_groups(self.hw.dma_page_bytes * 1024, self.tile_bytes(blocking))

    def mxu_passes(self, blocking: BlockingSpec) -> float:
        """MXU tile-passes occupied by one (bk, bn) structure.

        Register granularity is (sublane=8, lane=128): a partially-filled
        tile still occupies whole lanes, like a partially-used DSP.
        """
        lanes_k = math.ceil(blocking.bk / self.hw.sublane) * self.hw.sublane
        lanes_n = math.ceil(blocking.bn / self.hw.mxu_dim) * self.hw.mxu_dim
        passes = (lanes_k / self.hw.mxu_dim) * (lanes_n / self.hw.mxu_dim)
        return passes * _MXU_SCALE[self.precision]

    def hbm_pages(self, blocking: BlockingSpec) -> float:
        if self.strategy == "resident":
            return 0.0
        return self.tile_bytes(blocking) / (self.hw.dma_page_bytes * 1024)

    def structure_cost(self, blocking: BlockingSpec) -> ResourceVector:
        """R(w_i) for one structure of this layer: [mxu, hbm]."""
        return np.array(
            [self.mxu_passes(blocking), self.hbm_pages(blocking)], dtype=np.float64
        )

    def layer_cost(self, info: StructureInfo) -> ResourceVector:
        return self.structure_cost(info.blocking) * info.num_structures

    # -- FPGA-mode: reproduces the paper's own DSP/BRAM numbers ------------

    @staticmethod
    def fpga_dsp_bram(precision_bits: int, rf: int, strategy: str = "resource") -> Tuple[float, float]:
        """The paper's literal resource vector for one structure.

        DSP-aware structure (length RF): 1 DSP, RF·P bits of BRAM
        (as a fraction of a 36-bit x 1024 BRAM block) in Resource strategy.
        Precisions < 10 bits map multiplications to LUTs => 0 DSPs
        (paper footnote 3).
        """
        dsp = 0.0 if precision_bits < 10 else 1.0
        if strategy == "latency":
            return dsp, 0.0
        bram_bits_per_block = 36.0 * 1024.0
        bram = (rf * precision_bits) / bram_bits_per_block
        return dsp, bram
