"""Resource-aware group regularization (paper §III-C, after Wen et al.).

The paper adds a group-lasso penalty where each group is a *hardware
resource structure* (not a filter): sum over structures of the structure's
L2 norm, so SGD shrinks whole DSP/BRAM groups toward zero together and the
knapsack's next selection finds near-zero groups cheap to drop.

Here groups are the MXU-tile structures from ``core/structures``.  The
penalty is fully jit-able (pure jnp) and scales with the resource cost of
each structure — structures occupying more hardware are pushed harder,
which is the resource-aware twist over plain group lasso.
"""
from __future__ import annotations

from typing import Any, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .masks import _get_path
from .resource_model import TPUResourceModel
from .structures import LayerStructures, structure_norms_dense

__all__ = ["group_lasso", "make_regularizer"]


def group_lasso(
    params: Mapping[str, Any],
    structures: LayerStructures,
    *,
    resource_model: Optional[TPUResourceModel] = None,
    strength: float = 1e-4,
) -> jnp.ndarray:
    """sum_i  lambda * cost_i * ||w_i||_2  over resource-aware structures."""
    total = jnp.zeros((), dtype=jnp.float32)
    for info in structures.infos:
        w = _get_path(params, info.path)
        norms = structure_norms_dense(w, info)  # (planes, gk, gn) fp32
        if resource_model is not None:
            cost = float(np.sum(resource_model.structure_cost(info.blocking)))
        else:
            cost = 1.0
        # normalize by sqrt(group size) (standard group-lasso scaling) so
        # the penalty is comparable across heterogeneous blockings
        scale = cost / np.sqrt(info.block_elems)
        total = total + scale * jnp.sum(norms)
    return strength * total


def make_regularizer(structures: LayerStructures, resource_model=None, strength: float = 1e-4):
    """Closure usable inside a jitted loss: params -> scalar penalty."""

    def reg(params):
        return group_lasso(
            params, structures, resource_model=resource_model, strength=strength
        )

    return reg
