"""Resource-aware tensor structures (paper §III-A, adapted to TPU).

The paper groups weights by the hardware resource that processes them:

* FPGA DSP group  = ``RF`` consecutive weights time-multiplexed onto one
  multiplier (transpose + flatten + split into length-``RF`` sub-vectors).
* FPGA BRAM group = ``C`` consecutive DSP groups sharing a 36-bit BRAM word.

On TPU the atomic compute resource is an MXU *tile*: a ``(bk, bn)`` block of
the weight matrix that occupies one systolic pass.  The memory resource is a
*super-block* of ``C`` consecutive tiles along the HBM streaming order (the
DMA-page analogue of a BRAM word).  This module maps weight pytrees to and
from those structures.

A "structure" here is always a *block partition of the last two dims* of a
weight tensor; leading dims (e.g. the expert dim of an MoE weight) become
independent planes so that pruning an entire plane's blocks removes the
expert — the coarse structure the paper exploits per-layer on LeNet.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "BlockingSpec",
    "StructureInfo",
    "LayerStructures",
    "block_partition",
    "structure_norms_dense",
    "mask_from_selection",
    "iter_prunable",
    "PRUNABLE_MIN_SIZE",
]

# Tensors smaller than this (in elements) are never pruned — the paper keeps
# tiny layers dense (LeNet fc_3 stays in Latency strategy with RF=1).
PRUNABLE_MIN_SIZE = 1024


@dataclasses.dataclass(frozen=True)
class BlockingSpec:
    """TPU analogue of the paper's (RF, C) grouping knobs.

    bk, bn        block (tile) shape over the (in, out) dims of a matmul
                  weight.  MXU-aligned defaults: multiples of (8, 128).
    consecutive   ``C``: how many consecutive tiles form one memory
                  super-block (Eq. 1 analogue, see resource_model).
    """

    bk: int = 128
    bn: int = 128
    consecutive: int = 1

    def __post_init__(self):
        if self.bk <= 0 or self.bn <= 0 or self.consecutive <= 0:
            raise ValueError(f"invalid blocking {self}")


@dataclasses.dataclass(frozen=True)
class StructureInfo:
    """Static description of the structures of one weight tensor."""

    path: str                    # pytree key-path, '/'-joined
    shape: Tuple[int, ...]       # full weight shape
    planes: int                  # product of leading dims (experts etc.)
    grid_k: int                  # number of blocks along the in dim
    grid_n: int                  # number of blocks along the out dim
    blocking: BlockingSpec

    @property
    def num_structures(self) -> int:
        return self.planes * self.grid_k * self.grid_n

    @property
    def block_elems(self) -> int:
        return self.blocking.bk * self.blocking.bn

    def structure_index(self, plane: int, ik: int, in_: int) -> int:
        return (plane * self.grid_k + ik) * self.grid_n + in_


@dataclasses.dataclass
class LayerStructures:
    """All structures of a model: flat arrays aligned across layers.

    ``infos`` is ordered; structure ids are contiguous per layer in that
    order, which lets knapsack results map back to masks without a dict of
    per-item metadata (important at the 1e5..1e6-structure scale of the
    assigned LMs).
    """

    infos: List[StructureInfo]

    def layer_offsets(self) -> np.ndarray:
        sizes = np.array([i.num_structures for i in self.infos], dtype=np.int64)
        return np.concatenate([[0], np.cumsum(sizes)])

    @property
    def total_structures(self) -> int:
        return int(sum(i.num_structures for i in self.infos))


def _split_leading(shape: Sequence[int]) -> Tuple[int, int, int]:
    """(planes, K, N) from an arbitrary-rank weight shape.

    The last two dims are the matmul (in, out) dims; everything in front is
    folded into independent planes.  1-D tensors are treated as (1, 1, N)
    so biases group with single tiles along the out dim.
    """
    if len(shape) == 0:
        return 1, 1, 1
    if len(shape) == 1:
        return 1, 1, shape[0]
    planes = int(np.prod(shape[:-2], dtype=np.int64)) if len(shape) > 2 else 1
    return planes, shape[-2], shape[-1]


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def block_partition(path: str, shape: Sequence[int], blocking: BlockingSpec) -> StructureInfo:
    planes, k, n = _split_leading(shape)
    bk = min(blocking.bk, k)
    bn = min(blocking.bn, n)
    eff = BlockingSpec(bk=bk, bn=bn, consecutive=blocking.consecutive)
    return StructureInfo(
        path=path,
        shape=tuple(int(s) for s in shape),
        planes=planes,
        grid_k=_ceil_div(k, bk),
        grid_n=_ceil_div(n, bn),
        blocking=eff,
    )


def _pad_to_grid(w2d: jnp.ndarray, info: StructureInfo) -> jnp.ndarray:
    """Zero-pad the (K, N) trailing dims up to whole blocks."""
    bk, bn = info.blocking.bk, info.blocking.bn
    k, n = w2d.shape[-2], w2d.shape[-1]
    pk = info.grid_k * bk - k
    pn = info.grid_n * bn - n
    if pk or pn:
        pad = [(0, 0)] * (w2d.ndim - 2) + [(0, pk), (0, pn)]
        w2d = jnp.pad(w2d, pad)
    return w2d


def structure_norms_dense(w: jnp.ndarray, info: StructureInfo) -> jnp.ndarray:
    """Per-structure L2 norms, shape (planes, grid_k, grid_n). Pure jnp.

    This is the reference path; ``kernels/structure_norms.py`` is the Pallas
    fast path used on TPU for the very large assigned archs.
    """
    planes, k, n = _split_leading(w.shape)
    w2 = w.reshape(planes, k, n)
    w2 = _pad_to_grid(w2, info)
    bk, bn = info.blocking.bk, info.blocking.bn
    w4 = w2.reshape(planes, info.grid_k, bk, info.grid_n, bn)
    sq = jnp.sum(jnp.square(w4.astype(jnp.float32)), axis=(2, 4))
    return jnp.sqrt(sq)


def mask_from_selection(selected: np.ndarray, info: StructureInfo) -> np.ndarray:
    """Expand a per-structure {0,1} selection into a full weight mask.

    ``selected`` has ``info.num_structures`` entries ordered
    (plane, ik, in); the returned mask has ``info.shape`` (cropped from the
    padded grid).
    """
    sel = np.asarray(selected, dtype=np.float32).reshape(
        info.planes, info.grid_k, info.grid_n
    )
    bk, bn = info.blocking.bk, info.blocking.bn
    big = np.repeat(np.repeat(sel, bk, axis=1), bn, axis=2)
    planes, k, n = _split_leading(info.shape)
    big = big[:, :k, :n]
    return big.reshape(info.shape)


def iter_prunable(
    params: Mapping[str, Any],
    *,
    include: Optional[Sequence[str]] = None,
    exclude: Sequence[str] = ("norm", "scale", "bias_only", "embed_norm", "a_log", "dt", "gate_vec"),
    min_size: int = PRUNABLE_MIN_SIZE,
) -> Iterable[Tuple[str, jnp.ndarray]]:
    """Yield (path, weight) for prunable tensors in a params pytree.

    Matmul weights only: ndim >= 2 and size >= min_size, path not matching
    the exclusion list (norm scales, SSM scalars, gate vectors ... the
    non-matmul parameters the paper also excludes from DSP mapping).
    """
    flat = jax.tree_util.tree_flatten_with_path(dict(params))[0]
    for keypath, leaf in flat:
        path = "/".join(_key_str(k) for k in keypath)
        if leaf is None or not hasattr(leaf, "shape"):
            continue
        if leaf.ndim < 2 or int(np.prod(leaf.shape)) < min_size:
            continue
        lowered = path.lower()
        if any(e in lowered for e in exclude):
            continue
        if include is not None and not any(i in lowered for i in include):
            continue
        yield path, leaf


def _key_str(k) -> str:
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    if isinstance(k, jax.tree_util.GetAttrKey):
        return str(k.name)
    return str(k)
