"""Sparsity schedules f(s) for the iterative pruning loop (paper Alg. 2).

The paper increments sparsity by a constant step.  We provide that plus the
cubic schedule of Zhu & Gupta (common in the pruning literature) — both are
vectors over the modeled resources, matching the paper's
``s_T ∈ R^m_+`` target.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

__all__ = ["SparsitySchedule", "constant_step", "cubic"]

ScheduleFn = Callable[[np.ndarray, int], np.ndarray]


@dataclasses.dataclass(frozen=True)
class SparsitySchedule:
    """s_{t+1} = f(s_t, t), clipped to the target."""

    target: np.ndarray  # (m,)
    fn: ScheduleFn

    def __call__(self, s: np.ndarray, t: int) -> np.ndarray:
        s = np.asarray(s, dtype=np.float64)
        nxt = self.fn(s, t)
        return np.minimum(nxt, self.target)

    def reached(self, s: np.ndarray) -> bool:
        return bool(np.all(s >= self.target - 1e-12))


def constant_step(target: Sequence[float], step: float = 0.05) -> SparsitySchedule:
    target = np.asarray(target, dtype=np.float64)

    def fn(s, t):
        return s + step

    return SparsitySchedule(target=target, fn=fn)


def cubic(target: Sequence[float], total_iters: int) -> SparsitySchedule:
    """Zhu-Gupta: s_t = s_T * (1 - (1 - t/T)^3)."""
    target = np.asarray(target, dtype=np.float64)

    def fn(s, t):
        frac = min((t + 1) / max(total_iters, 1), 1.0)
        return target * (1.0 - (1.0 - frac) ** 3)

    return SparsitySchedule(target=target, fn=fn)
