"""Dense+mask -> BSR packing (the paper's §III-C codegen, TPU edition).

The paper emits HLS that skips multiplications by pruned structures — the
compiler alone will not.  The TPU equivalent: pack surviving (bk, bn) tiles
into a block-compressed (BSR-like) layout and run the Pallas kernel in
``kernels/block_sparse_matmul.py``, which iterates only over surviving
tiles (scalar-prefetched indices choose the HBM->VMEM DMAs).

Two coordinated views of the same live-tile set (DESIGN.md §8):

* **flat store** — the single copy of the weights, live tiles only,
  column-major over (block-col, slot):

      blocks:    (nnz, bk, bn)  weight dtype (>=1 slot, zeros if empty)
      flat_rows: (nnz,) int32   K-block index per live tile
      flat_cols: (nnz,) int32   N-block index per live tile (sorted)

  The ref kernel contracts this directly — ONE batched (nnz, M, bk) @
  (nnz, bk, bn) GEMM + a sorted segment-sum over output block-columns —
  so work scales with the *true* live count, not ``grid_n * max_nnz``.

* **per-column map** — the Pallas grid's view, padded to the column max:

      indices: (grid_n, max_nnz) int32  K-block per slot, -1 = padding
      slots:   (grid_n, max_nnz) int32  index into the flat store (0 pad)

  Output tile (i, j) accumulates over its own column's slots; padding
  slots are `pl.when`-skipped (their flat-store fetch is a benign
  redundant DMA bounded by the per-column padding).

Column-major-by-output grouping matches the matmul loop either way: no
scatter is ever needed because BSR columns partition the output.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .structures import BlockingSpec

__all__ = ["BSRWeight", "BSRPlanes", "pack_bsr", "bsr_to_dense"]


@dataclasses.dataclass
class BSRWeight:
    """Block-sparse weight for a (K, N) matmul, tiles of (bk, bn)."""

    indices: jnp.ndarray      # (grid_n, max_nnz) int32, -1 padded
    slots: jnp.ndarray        # (grid_n, max_nnz) int32 into blocks, 0 padded
    blocks: jnp.ndarray       # (nnz, bk, bn) flat store, column-major
    flat_rows: jnp.ndarray    # (nnz,) int32 K-block per live tile
    flat_cols: jnp.ndarray    # (nnz,) int32 N-block per live tile, sorted
    shape: Tuple[int, int]    # dense (K, N)
    blocking: BlockingSpec
    nnz_blocks: int           # true live count (blocks may pad to >= 1)

    @property
    def grid_k(self) -> int:
        return -(-self.shape[0] // self.blocking.bk)

    @property
    def grid_n(self) -> int:
        return self.indices.shape[0]

    @property
    def max_nnz(self) -> int:
        return self.indices.shape[1]

    def density(self) -> float:
        return self.nnz_blocks / max(self.grid_k * self.grid_n, 1)

    def tree_flatten(self):
        children = (self.indices, self.slots, self.blocks,
                    self.flat_rows, self.flat_cols)
        return children, (self.shape, self.blocking, self.nnz_blocks)

    @classmethod
    def tree_unflatten(cls, aux, children):
        indices, slots, blocks, flat_rows, flat_cols = children
        shape, blocking, nnz_blocks = aux
        return cls(indices=indices, slots=slots, blocks=blocks,
                   flat_rows=flat_rows, flat_cols=flat_cols,
                   shape=shape, blocking=blocking, nnz_blocks=nnz_blocks)


jax.tree_util.register_pytree_node(
    BSRWeight, BSRWeight.tree_flatten, BSRWeight.tree_unflatten
)


@dataclasses.dataclass
class BSRPlanes:
    """Flattened per-plane BSR stack for a >2-D weight (MoE (E, D, F)).

    The per-plane ``BSRWeight`` views are concatenated into ONE rectangular
    stack: the per-column slot dim pads to the stack-wide ``max_nnz`` and
    the flat store pads to the largest plane's live count, so
    ``expert_matmul`` issues a single fused kernel call
    (``kernels.ops.bsr_planes_matmul``) instead of a python loop + stack
    over planes.  Pruning every tile of a plane removes the whole expert —
    the paper's coarse structure; a dead plane contributes only
    `pl.when`-skipped padding slots (zero blocks in the flat store).
    """

    indices: jnp.ndarray            # (E, grid_n, max_nnz) int32, -1 padded
    slots: jnp.ndarray              # (E, grid_n, max_nnz) int32, 0 padded
    blocks: jnp.ndarray             # (E, nnz_pad, bk, bn) flat stores
    flat_rows: jnp.ndarray          # (E, nnz_pad) int32, 0 padded
    flat_cols: jnp.ndarray          # (E, nnz_pad) int32 sorted per plane
                                    # (grid_n-1 padded, keeps monotonic)
    shape: Tuple[int, ...]          # full dense shape, leading dims included
    blocking: BlockingSpec          # effective (clamped) tile shape
    plane_nnz: Tuple[int, ...]      # true live count per plane

    @classmethod
    def from_planes(cls, planes: Tuple[BSRWeight, ...],
                    shape: Tuple[int, ...]) -> "BSRPlanes":
        """Concatenate independent per-plane BSRWeights (same (K, N) and
        blocking) into the fused layout, padding both the per-column slot
        dim and the flat store to the stack-wide max."""
        max_nnz = max(p.max_nnz for p in planes)
        nnz_pad = max(p.blocks.shape[0] for p in planes)
        gn = planes[0].grid_n
        idx, slt, blk, fr, fc = [], [], [], [], []
        for p in planes:
            spad = max_nnz - p.max_nnz
            zpad = nnz_pad - p.blocks.shape[0]
            idx.append(jnp.pad(p.indices, ((0, 0), (0, spad)),
                               constant_values=-1))
            slt.append(jnp.pad(p.slots, ((0, 0), (0, spad))))
            blk.append(jnp.pad(p.blocks, ((0, zpad), (0, 0), (0, 0))))
            fr.append(jnp.pad(p.flat_rows, (0, zpad)))
            # pad flat_cols with the LAST column id, not 0: the ref's
            # sorted segment-sum requires the per-plane ids to stay
            # monotonic through the padding (zero blocks contribute zero
            # wherever they point, so any valid column works)
            fc.append(jnp.pad(p.flat_cols, (0, zpad),
                              constant_values=gn - 1))
        return cls(
            indices=jnp.stack(idx), slots=jnp.stack(slt),
            blocks=jnp.stack(blk), flat_rows=jnp.stack(fr),
            flat_cols=jnp.stack(fc),
            shape=tuple(int(s) for s in shape),
            blocking=planes[0].blocking,
            plane_nnz=tuple(p.nnz_blocks for p in planes),
        )

    @property
    def num_planes(self) -> int:
        return self.indices.shape[0]

    @property
    def grid_k(self) -> int:
        return -(-self.shape[-2] // self.blocking.bk)

    @property
    def grid_n(self) -> int:
        return self.indices.shape[1]

    @property
    def max_nnz(self) -> int:
        return self.indices.shape[2]

    @property
    def nnz_blocks(self) -> int:
        return sum(self.plane_nnz)

    @property
    def planes(self) -> Tuple[BSRWeight, ...]:
        """Per-plane BSRWeight views into the fused arrays (oracles/tests)."""
        kn = (int(self.shape[-2]), int(self.shape[-1]))
        return tuple(
            BSRWeight(indices=self.indices[e], slots=self.slots[e],
                      blocks=self.blocks[e], flat_rows=self.flat_rows[e],
                      flat_cols=self.flat_cols[e], shape=kn,
                      blocking=self.blocking, nnz_blocks=self.plane_nnz[e])
            for e in range(self.num_planes)
        )

    def density(self) -> float:
        return self.nnz_blocks / max(
            self.num_planes * self.grid_k * self.grid_n, 1)

    def tree_flatten(self):
        children = (self.indices, self.slots, self.blocks,
                    self.flat_rows, self.flat_cols)
        return children, (self.shape, self.blocking, self.plane_nnz)

    @classmethod
    def tree_unflatten(cls, aux, children):
        indices, slots, blocks, flat_rows, flat_cols = children
        shape, blocking, plane_nnz = aux
        return cls(indices=indices, slots=slots, blocks=blocks,
                   flat_rows=flat_rows, flat_cols=flat_cols, shape=shape,
                   blocking=blocking, plane_nnz=plane_nnz)


jax.tree_util.register_pytree_node(
    BSRPlanes, BSRPlanes.tree_flatten, BSRPlanes.tree_unflatten
)


def pack_bsr(
    weight: np.ndarray,
    blocking: BlockingSpec,
    mask: Optional[np.ndarray] = None,
    *,
    min_slots: int = 1,
) -> BSRWeight:
    """Pack a masked dense (K, N) weight into BSR. Host-side (numpy)."""
    w = np.asarray(weight)
    if w.ndim != 2:
        raise ValueError(f"pack_bsr expects 2-D weights, got {w.shape}")
    if mask is not None:
        w = w * np.asarray(mask, dtype=w.dtype)
    k, n = w.shape
    bk, bn = min(blocking.bk, k), min(blocking.bn, n)
    gk, gn = -(-k // bk), -(-n // bn)
    wp = np.zeros((gk * bk, gn * bn), dtype=w.dtype)
    wp[:k, :n] = w
    tiles = wp.reshape(gk, bk, gn, bn).transpose(0, 2, 1, 3)  # (gk, gn, bk, bn)
    alive = np.abs(tiles).sum(axis=(2, 3)) > 0                # (gk, gn)

    max_nnz = max(int(alive.sum(axis=0).max(initial=0)), min_slots)
    nnz = int(alive.sum())
    nnz_pad = max(nnz, 1)
    indices = np.full((gn, max_nnz), -1, dtype=np.int32)
    slots = np.zeros((gn, max_nnz), dtype=np.int32)
    blocks = np.zeros((nnz_pad, bk, bn), dtype=w.dtype)
    flat_rows = np.zeros((nnz_pad,), dtype=np.int32)
    flat_cols = np.zeros((nnz_pad,), dtype=np.int32)
    z = 0
    for j in range(gn):
        rows = np.flatnonzero(alive[:, j])
        indices[j, : rows.size] = rows
        slots[j, : rows.size] = np.arange(z, z + rows.size)
        blocks[z : z + rows.size] = tiles[rows, j]
        flat_rows[z : z + rows.size] = rows
        flat_cols[z : z + rows.size] = j
        z += rows.size

    eff = BlockingSpec(bk=bk, bn=bn, consecutive=blocking.consecutive)
    return BSRWeight(
        indices=jnp.asarray(indices),
        slots=jnp.asarray(slots),
        blocks=jnp.asarray(blocks),
        flat_rows=jnp.asarray(flat_rows),
        flat_cols=jnp.asarray(flat_cols),
        shape=(k, n),
        blocking=eff,
        nnz_blocks=nnz,
    )


def bsr_to_dense(bsr: BSRWeight) -> jnp.ndarray:
    """Reconstruct the dense (K, N) weight — oracle for tests (traceable)."""
    bk, bn = bsr.blocking.bk, bsr.blocking.bn
    gk, gn = bsr.grid_k, bsr.grid_n
    dense = jnp.zeros((gk * bk, gn * bn), dtype=bsr.blocks.dtype)
    for z in range(bsr.nnz_blocks):
        dense = jax.lax.dynamic_update_slice(
            dense, bsr.blocks[z].astype(dense.dtype),
            (bsr.flat_rows[z] * bk, bsr.flat_cols[z] * bn))
    return dense[: bsr.shape[0], : bsr.shape[1]]
