"""Dense+mask -> BSR packing (the paper's §III-C codegen, TPU edition).

The paper emits HLS that skips multiplications by pruned structures — the
compiler alone will not.  The TPU equivalent: pack surviving (bk, bn) tiles
into a block-compressed (BSR-like) layout and run the Pallas kernel in
``kernels/block_sparse_matmul.py``, which iterates only over surviving
tiles (scalar-prefetched indices choose the HBM->VMEM DMAs).

Layout: for each block-column j (output tile), the K-block indices of its
surviving tiles, padded to the column max with -1:

    indices: (grid_n, max_nnz) int32   (-1 = padding slot)
    blocks:  (grid_n, max_nnz, bk, bn) weight dtype  (zeros in padding)

Column-major-by-output grouping matches the matmul loop: an output tile
accumulates over its own column's surviving tiles only.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .structures import BlockingSpec

__all__ = ["BSRWeight", "pack_bsr", "bsr_to_dense"]


@dataclasses.dataclass
class BSRWeight:
    """Block-sparse weight for a (K, N) matmul, tiles of (bk, bn)."""

    indices: jnp.ndarray      # (grid_n, max_nnz) int32, -1 padded
    blocks: jnp.ndarray       # (grid_n, max_nnz, bk, bn)
    shape: Tuple[int, int]    # dense (K, N)
    blocking: BlockingSpec

    @property
    def grid_k(self) -> int:
        return -(-self.shape[0] // self.blocking.bk)

    @property
    def grid_n(self) -> int:
        return self.indices.shape[0]

    @property
    def max_nnz(self) -> int:
        return self.indices.shape[1]

    @property
    def nnz_blocks(self) -> int:
        return int(jnp.sum(self.indices >= 0))

    def density(self) -> float:
        return self.nnz_blocks / max(self.grid_k * self.grid_n, 1)

    def tree_flatten(self):
        return (self.indices, self.blocks), (self.shape, self.blocking)

    @classmethod
    def tree_unflatten(cls, aux, children):
        indices, blocks = children
        shape, blocking = aux
        return cls(indices=indices, blocks=blocks, shape=shape, blocking=blocking)


jax.tree_util.register_pytree_node(
    BSRWeight, BSRWeight.tree_flatten, BSRWeight.tree_unflatten
)


def pack_bsr(
    weight: np.ndarray,
    blocking: BlockingSpec,
    mask: Optional[np.ndarray] = None,
    *,
    min_slots: int = 1,
) -> BSRWeight:
    """Pack a masked dense (K, N) weight into BSR. Host-side (numpy)."""
    w = np.asarray(weight)
    if w.ndim != 2:
        raise ValueError(f"pack_bsr expects 2-D weights, got {w.shape}")
    if mask is not None:
        w = w * np.asarray(mask, dtype=w.dtype)
    k, n = w.shape
    bk, bn = min(blocking.bk, k), min(blocking.bn, n)
    gk, gn = -(-k // bk), -(-n // bn)
    wp = np.zeros((gk * bk, gn * bn), dtype=w.dtype)
    wp[:k, :n] = w
    tiles = wp.reshape(gk, bk, gn, bn).transpose(0, 2, 1, 3)  # (gk, gn, bk, bn)
    alive = np.abs(tiles).sum(axis=(2, 3)) > 0                # (gk, gn)

    max_nnz = max(int(alive.sum(axis=0).max(initial=0)), min_slots)
    indices = np.full((gn, max_nnz), -1, dtype=np.int32)
    blocks = np.zeros((gn, max_nnz, bk, bn), dtype=w.dtype)
    for j in range(gn):
        rows = np.flatnonzero(alive[:, j])
        indices[j, : rows.size] = rows
        blocks[j, : rows.size] = tiles[rows, j]

    eff = BlockingSpec(bk=bk, bn=bn, consecutive=blocking.consecutive)
    return BSRWeight(
        indices=jnp.asarray(indices),
        blocks=jnp.asarray(blocks),
        shape=(k, n),
        blocking=eff,
    )


def bsr_to_dense(bsr: BSRWeight) -> jnp.ndarray:
    """Reconstruct the dense (K, N) weight — oracle for tests (traceable)."""
    bk, bn = bsr.blocking.bk, bsr.blocking.bn
    gk, gn = bsr.grid_k, bsr.grid_n
    dense = jnp.zeros((gk * bk, gn * bn), dtype=bsr.blocks.dtype)
    for j in range(gn):
        for s in range(bsr.max_nnz):
            i = bsr.indices[j, s]
            safe = jnp.maximum(i, 0)
            cur = jax.lax.dynamic_slice(dense, (safe * bk, j * bn), (bk, bn))
            new = jnp.where(i >= 0, bsr.blocks[j, s], cur)
            dense = jax.lax.dynamic_update_slice(
                dense, new.astype(dense.dtype), (safe * bk, j * bn))
    return dense[: bsr.shape[0], : bsr.shape[1]]
