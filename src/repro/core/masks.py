"""Mask pytrees: creation, application, and sparsity accounting.

Masks mirror the params pytree: prunable leaves get a {0,1} float mask of
the same shape, non-prunable leaves get ``None``.  Applying a mask is a
pure element-wise multiply so it is free to fuse into the matmul producer
under jit; the serving path instead *packs* masked weights to BSR
(``core/packing.py``) so pruned tiles are skipped outright.
"""
from __future__ import annotations

from typing import Any, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .structures import (
    BlockingSpec,
    LayerStructures,
    StructureInfo,
    block_partition,
    iter_prunable,
    mask_from_selection,
)

__all__ = [
    "build_structures",
    "init_masks",
    "apply_masks",
    "masks_from_knapsack",
    "sparsity_report",
    "count_zero_structures",
]


def build_structures(
    params: Mapping[str, Any],
    blocking: BlockingSpec | Mapping[str, BlockingSpec],
    **iter_kwargs,
) -> LayerStructures:
    """Partition every prunable weight into resource-aware structures.

    ``blocking`` may be a single spec or a per-path override mapping with a
    ``"default"`` entry (the paper's heterogeneous per-layer RF/strategy,
    Table IV).
    """
    infos = []
    for path, w in iter_prunable(params, **iter_kwargs):
        if isinstance(blocking, BlockingSpec):
            spec = blocking
        else:
            spec = blocking.get(path, blocking.get("default"))
            if spec is None:
                raise KeyError(f"no blocking spec for {path} and no default")
        infos.append(block_partition(path, w.shape, spec))
    return LayerStructures(infos=infos)


def _get_path(tree: Mapping[str, Any], path: str):
    node = tree
    for part in path.split("/"):
        node = node[int(part)] if isinstance(node, (list, tuple)) else node[part]
    return node


def _set_path(tree: Dict[str, Any], path: str, value) -> None:
    parts = path.split("/")
    node = tree
    for part in parts[:-1]:
        node = node[int(part)] if isinstance(node, (list, tuple)) else node[part]
    last = parts[-1]
    if isinstance(node, list):
        node[int(last)] = value
    else:
        node[last] = value


def init_masks(params: Mapping[str, Any], structures: LayerStructures) -> Dict[str, Any]:
    """All-ones masks (sparsity 0) shaped like the prunable leaves."""
    masks = jax.tree.map(lambda _: None, dict(params))
    for info in structures.infos:
        w = _get_path(params, info.path)
        _set_path(masks, info.path, jnp.ones(w.shape, dtype=w.dtype))
    return masks


def apply_masks(params: Mapping[str, Any], masks: Optional[Mapping[str, Any]]):
    """Elementwise params * mask where a mask exists."""
    if masks is None:
        return params
    return jax.tree.map(
        lambda p, m: p if m is None else p * m.astype(p.dtype),
        dict(params),
        dict(masks),
        is_leaf=lambda x: x is None,
    )


def masks_from_knapsack(
    params: Mapping[str, Any],
    structures: LayerStructures,
    selection: np.ndarray,
) -> Dict[str, Any]:
    """Expand a global knapsack selection vector into a mask pytree."""
    offsets = structures.layer_offsets()
    masks = jax.tree.map(lambda _: None, dict(params))
    for li, info in enumerate(structures.infos):
        sel = selection[offsets[li]: offsets[li + 1]]
        w = _get_path(params, info.path)
        m = mask_from_selection(sel, info)
        _set_path(masks, info.path, jnp.asarray(m, dtype=w.dtype))
    return masks


def count_zero_structures(masks: Mapping[str, Any], structures: LayerStructures) -> Tuple[int, int]:
    """(pruned, total) structure counts implied by a mask pytree."""
    pruned = 0
    total = structures.total_structures
    for info in structures.infos:
        m = np.asarray(_get_path(masks, info.path))
        sel = _selection_from_mask(m, info)
        pruned += int(np.sum(sel == 0))
    return pruned, total


def _selection_from_mask(mask: np.ndarray, info: StructureInfo) -> np.ndarray:
    planes = info.planes
    k = info.shape[-2] if len(info.shape) >= 2 else 1
    n = info.shape[-1]
    m2 = mask.reshape(planes, k, n)
    bk, bn = info.blocking.bk, info.blocking.bn
    pk, pn = info.grid_k * bk - k, info.grid_n * bn - n
    if pk or pn:
        m2 = np.pad(m2, [(0, 0), (0, pk), (0, pn)])
    m4 = m2.reshape(planes, info.grid_k, bk, info.grid_n, bn)
    return (np.abs(m4).sum(axis=(2, 4)) > 0).astype(np.int8).reshape(-1)


def sparsity_report(
    params: Mapping[str, Any],
    masks: Mapping[str, Any],
    structures: LayerStructures,
) -> Dict[str, float]:
    """Weight- and structure-level sparsity, global and per-layer."""
    report: Dict[str, float] = {}
    zeros = 0
    total = 0
    for info in structures.infos:
        m = np.asarray(_get_path(masks, info.path))
        z = int(np.sum(m == 0))
        t = int(m.size)
        report[f"layer/{info.path}"] = z / max(t, 1)
        zeros += z
        total += t
    report["weight_sparsity"] = zeros / max(total, 1)
    p, t = count_zero_structures(masks, structures)
    report["structure_sparsity"] = p / max(t, 1)
    return report
