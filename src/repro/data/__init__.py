"""Deterministic synthetic data substrate."""
from .pipeline import LMPipeline
from .synthetic import ImageTask, JetsTask, TokenTask

__all__ = ["LMPipeline", "ImageTask", "JetsTask", "TokenTask"]
