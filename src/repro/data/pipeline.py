"""Host data pipeline: step-indexed deterministic batches, device
placement with global sharding, and background prefetch.

Fault-tolerance properties:
* batches are a pure function of (seed, global step) — restart-safe and
  elastic-safe (a rescaled job regenerates exactly the same global batch,
  just sliced differently across hosts);
* on a multi-process runtime each process materializes only its addressable
  shard of the batch (``process_slice``) and assembles the global array
  with ``jax.make_array_from_process_local_data`` — single-process falls
  back to plain device_put.
* prefetch runs one step ahead on a worker thread (overlaps host synth
  with device compute).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .synthetic import TokenTask

__all__ = ["LMPipeline"]


class LMPipeline:
    def __init__(
        self,
        task: TokenTask,
        batch: int,
        seq: int,
        *,
        mesh: Optional[Mesh] = None,
        batch_axes=("data",),
        prefetch: int = 2,
    ):
        self.task = task
        self.batch = batch
        self.seq = seq
        self.mesh = mesh
        self.batch_axes = batch_axes
        self._prefetch = prefetch
        self._queue: "queue.Queue" = queue.Queue(maxsize=max(prefetch, 1))
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- deterministic access ------------------------------------------------

    def batch_at(self, step: int) -> Dict[str, Any]:
        host = self.task.batch(step, self.batch, self.seq)
        if self.mesh is None:
            return host
        axes = tuple(a for a in self.batch_axes if a in self.mesh.axis_names)
        sharding = NamedSharding(self.mesh, P(axes if axes else None))
        return {
            k: jax.device_put(np.asarray(v), sharding) for k, v in host.items()
        }

    # -- prefetching iterator --------------------------------------------------

    def run(self, start_step: int, num_steps: int) -> Iterator[Dict[str, Any]]:
        if self._prefetch <= 0:
            for s in range(start_step, start_step + num_steps):
                yield self.batch_at(s)
            return

        def worker():
            for s in range(start_step, start_step + num_steps):
                if self._stop.is_set():
                    return
                self._queue.put(self.batch_at(s))

        self._stop.clear()
        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()
        for _ in range(num_steps):
            yield self._queue.get()
        self._thread.join(timeout=5)

    def close(self):
        self._stop.set()
        if self._thread is not None:
            while not self._queue.empty():
                self._queue.get_nowait()
