"""Deterministic synthetic datasets.

Everything is a pure function of (seed, step) — the fault-tolerance
cornerstone: any host can regenerate any batch after a restart or an
elastic resize, so the data pipeline never needs coordinated state.

* LM tokens: an order-2 random automaton over the vocab with noise — has
  real learnable structure (loss decreases under training) while needing
  zero files on disk.
* jets: 5-class gaussian mixtures over 16 features (the paper's jet
  tagging task, synthesized).
* images: class-template images + noise (SVHN/F-MNIST stand-ins).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["TokenTask", "JetsTask", "ImageTask"]


@dataclasses.dataclass(frozen=True)
class TokenTask:
    vocab: int
    seed: int = 0
    noise: float = 0.05

    def _auto(self) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        return rng.integers(0, self.vocab, size=(min(self.vocab, 4096),), dtype=np.int32)

    def batch(self, step: int, batch: int, seq: int) -> Dict[str, jnp.ndarray]:
        """tokens/labels (B, S) int32; labels are next-token."""
        table = self._auto()
        m = table.shape[0]
        rng = np.random.default_rng((self.seed, step))
        x = np.empty((batch, seq + 1), dtype=np.int32)
        x[:, 0] = rng.integers(0, self.vocab, size=batch)
        cur = x[:, 0] % m
        for t in range(1, seq + 1):
            nxt = table[cur % m] % self.vocab
            flip = rng.uniform(size=batch) < self.noise
            nxt = np.where(flip, rng.integers(0, self.vocab, size=batch), nxt)
            x[:, t] = nxt
            cur = (cur * 31 + nxt) % m
        return {
            "tokens": jnp.asarray(x[:, :-1]),
            "labels": jnp.asarray(x[:, 1:]),
        }


@dataclasses.dataclass(frozen=True)
class JetsTask:
    """Paper benchmark: 16 features -> 5 classes (W/Z/t/q/g)."""

    features: int = 16
    classes: int = 5
    seed: int = 7
    scale: float = 0.8   # tuned: ~92% baseline acc (paper jets task: 76.6%)

    def _centers(self) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        return rng.normal(size=(self.classes, self.features)) * self.scale

    def batch(self, step: int, batch: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
        centers = self._centers()
        rng = np.random.default_rng((self.seed, step))
        y = rng.integers(0, self.classes, size=batch)
        x = centers[y] + rng.normal(size=(batch, self.features))
        return jnp.asarray(x.astype(np.float32)), jnp.asarray(y.astype(np.int32))


@dataclasses.dataclass(frozen=True)
class ImageTask:
    """Template-plus-noise image classification (SVHN / F-MNIST scale)."""

    height: int = 28
    width: int = 28
    channels: int = 1
    classes: int = 10
    seed: int = 11
    noise: float = 0.6

    def _templates(self) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        t = rng.normal(size=(self.classes, self.height, self.width, self.channels))
        # low-pass: classes differ in coarse structure, like digits
        from numpy.fft import irfft2, rfft2

        f = rfft2(t, axes=(1, 2))
        f[:, 6:, :, :] = 0
        f[:, :, 6:, :] = 0
        return irfft2(f, s=(self.height, self.width), axes=(1, 2)).real * 3.0

    def batch(self, step: int, batch: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
        tem = self._templates()
        rng = np.random.default_rng((self.seed, step))
        y = rng.integers(0, self.classes, size=batch)
        x = tem[y] + rng.normal(size=(batch, self.height, self.width, self.channels)) * self.noise
        return jnp.asarray(x.astype(np.float32)), jnp.asarray(y.astype(np.int32))
