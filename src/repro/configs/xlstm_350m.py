"""xlstm-350m [ssm] — 24L d_model=1024 4H d_ff=0 vocab=50304,
sLSTM + mLSTM blocks (xLSTM[7:1]).  [arXiv:2405.04517]

d_ff=0: xLSTM blocks carry their own projections (mLSTM pf=2 up/down,
sLSTM pf=4/3 post-MLP); there is no separate transformer FFN.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    vocab=50304,
    d_model=1024,
    n_layers=24,
    n_heads=4,
    kv_heads=4,
    d_ff=0,
    mixer_pattern=("mlstm", "mlstm", "mlstm", "mlstm",
                   "mlstm", "mlstm", "mlstm", "slstm"),
    mlp_pattern=("none",),
    mlstm_proj_factor=2.0,
    ssm_chunk=512,
    norm_type="layernorm",
    activation="gelu",
    gated_mlp=False,
    tie_embeddings=True,
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    remat="none",
    sub_quadratic=True,            # recurrent state: long_500k runs
    notes="sLSTM layers are sequential (recurrent gate dependence); their "
          "scan trip counts are fed to the roofline supplements.",
)
