"""command-r-plus-104b [dense] — 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000, GQA, no biases.  [hf:CohereForAI/c4ai-command-r-v01]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="lm",
    vocab=256000,
    d_model=12288,
    n_layers=64,
    n_heads=96,
    kv_heads=8,
    d_ff=33792,
    norm_type="layernorm",
    activation="silu",
    gated_mlp=True,
    tie_embeddings=True,           # cohere ties input/output embeddings
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    remat="full",                  # largest dense model: full remat
    sub_quadratic=False,
)
