"""whisper-tiny [audio] — 4L(+4L enc) d_model=384 6H (kv=6) d_ff=1536
vocab=51865, encoder-decoder, conv frontend (STUB).  [arXiv:2212.04356]

The conv/mel frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings (B, 1500, d_model).  decode cells
exercise the decoder (self-attn KV cache + precomputed cross-attn K/V);
the assigned 32k cache far exceeds Whisper's real 448 positions — honored
as a dry-run stress shape (DESIGN.md §5).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    vocab=51865,
    d_model=384,
    n_layers=4,                    # decoder layers
    enc_layers=4,                  # encoder layers
    enc_frames=1500,
    n_heads=6,
    kv_heads=6,
    d_ff=1536,
    use_rope=False,                # whisper: sinusoidal/learned abs positions
    norm_type="layernorm",
    activation="gelu",
    gated_mlp=False,
    tie_embeddings=True,
    param_dtype="float32",         # tiny model: fp32 everywhere
    activ_dtype="bfloat16",
    remat="none",
    sub_quadratic=False,
)
