"""Config registry: the 10 assigned architectures + the paper's own
benchmark models, selectable via ``--arch <id>``."""
from __future__ import annotations

from typing import Dict, List

from .base import (
    SHAPES,
    ModelConfig,
    ShapeCell,
    cell_applicable,
    input_specs,
    make_smoke,
)
from .command_r_plus_104b import CONFIG as command_r_plus_104b
from .deepseek_7b import CONFIG as deepseek_7b
from .deepseek_67b import CONFIG as deepseek_67b
from .granite_moe_1b_a400m import CONFIG as granite_moe_1b_a400m
from .jamba_v0_1_52b import CONFIG as jamba_v0_1_52b
from .mixtral_8x7b import CONFIG as mixtral_8x7b
from .qwen1_5_0_5b import CONFIG as qwen1_5_0_5b
from .qwen2_vl_2b import CONFIG as qwen2_vl_2b
from .whisper_tiny import CONFIG as whisper_tiny
from .xlstm_350m import CONFIG as xlstm_350m

ARCHS: Dict[str, ModelConfig] = {
    "granite-moe-1b-a400m": granite_moe_1b_a400m,
    "mixtral-8x7b": mixtral_8x7b,
    "deepseek-7b": deepseek_7b,
    "deepseek-67b": deepseek_67b,
    "command-r-plus-104b": command_r_plus_104b,
    "qwen1.5-0.5b": qwen1_5_0_5b,
    "qwen2-vl-2b": qwen2_vl_2b,
    "jamba-v0.1-52b": jamba_v0_1_52b,
    "whisper-tiny": whisper_tiny,
    "xlstm-350m": xlstm_350m,
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choose from {sorted(ARCHS)}")
    return ARCHS[arch]


def list_archs() -> List[str]:
    return list(ARCHS)


__all__ = [
    "ARCHS", "get_config", "list_archs", "ModelConfig", "ShapeCell",
    "SHAPES", "input_specs", "make_smoke", "cell_applicable",
]
