"""qwen1.5-0.5b [dense] — 24L d_model=1024 16H (kv=16) d_ff=2816
vocab=151936, QKV bias.  [hf:Qwen/Qwen1.5-0.5B]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="lm",
    vocab=151936,
    d_model=1024,
    n_layers=24,
    n_heads=16,
    kv_heads=16,
    d_ff=2816,
    qkv_bias=True,
    rope_theta=1e6,
    norm_type="rmsnorm",
    activation="silu",
    gated_mlp=True,
    tie_embeddings=True,
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    remat="dots",
    sub_quadratic=False,
)
