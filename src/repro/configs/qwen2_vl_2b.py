"""qwen2-vl-2b [vlm] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, M-RoPE, dynamic resolution.  [arXiv:2409.12191]

The vision frontend is a STUB per the assignment: ``input_specs`` provides
precomputed patch embeddings (B, num_patches, d_model) that replace the
first ``num_patches`` token positions.  M-RoPE uses (temporal, height,
width) position ids with frequency sections (16, 24, 24) over head_dim 128.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    vocab=151936,
    d_model=1536,
    n_layers=28,
    n_heads=12,
    kv_heads=2,
    d_ff=8960,
    head_dim=128,
    qkv_bias=True,
    mrope_sections=(16, 24, 24),
    num_patches=1024,              # stub visual context length
    rope_theta=1e6,
    norm_type="rmsnorm",
    activation="silu",
    gated_mlp=True,
    tie_embeddings=True,
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    remat="dots",
    sub_quadratic=False,
)
