"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    vocab=49155,
    d_model=1024,
    n_layers=24,
    n_heads=16,
    kv_heads=8,
    d_ff=512,                      # per-expert FFN hidden
    moe_experts=32,
    moe_top_k=8,
    mlp_pattern=("moe",),
    norm_type="rmsnorm",
    activation="silu",
    gated_mlp=True,
    tie_embeddings=True,
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    remat="dots",
    sub_quadratic=False,
    notes="vocab 49155 is not divisible by the 16-way TP axis -> embedding "
          "falls back to replication (table is only ~100MB in bf16).",
)
