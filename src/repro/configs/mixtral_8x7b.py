"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    vocab=32000,
    d_model=4096,
    n_layers=32,
    n_heads=32,
    kv_heads=8,
    d_ff=14336,
    moe_experts=8,
    moe_top_k=2,
    mlp_pattern=("moe",),
    window=4096,                    # SWA => ring-buffer cache, long_500k ok
    norm_type="rmsnorm",
    activation="silu",
    gated_mlp=True,
    tie_embeddings=False,
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    remat="dots",
    sub_quadratic=True,
    notes="E=8 experts on a 16-way model axis: EP falls back to "
          "intra-expert TP (DESIGN.md §4). SWA window 4096 bounds the "
          "decode cache, so long_500k runs with a ring buffer.",
)
