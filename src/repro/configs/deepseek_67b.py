"""deepseek-67b [dense] — 95L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=102400, llama-arch.  [arXiv:2401.02954]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="lm",
    vocab=102400,
    d_model=8192,
    n_layers=95,
    n_heads=64,
    kv_heads=8,
    d_ff=22016,
    norm_type="rmsnorm",
    activation="silu",
    gated_mlp=True,
    tie_embeddings=False,
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    remat="dots",
    sub_quadratic=False,
)
