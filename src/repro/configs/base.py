"""Model configuration + assigned shape cells + input specs.

``ModelConfig`` drives the composable stack in ``models/transformer.py``.
``ShapeCell`` encodes the four assigned input shapes; ``input_specs``
produces ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no
device allocation) for the dry-run and roofline passes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ModelConfig", "ShapeCell", "SHAPES", "input_specs", "make_smoke"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str = "lm"              # lm | moe | vlm | hybrid | audio | ssm
    vocab: int = 32000
    d_model: int = 512
    n_layers: int = 4
    n_heads: int = 8
    kv_heads: int = 8
    d_ff: int = 2048
    head_dim: Optional[int] = None

    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25

    # attention
    window: Optional[int] = None            # SWA
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True
    mrope_sections: Optional[Tuple[int, int, int]] = None
    attn_chunk: int = 512
    logits_softcap: Optional[float] = None

    # layer patterns (cycled over n_layers)
    mixer_pattern: Optional[Tuple[str, ...]] = None
    mlp_pattern: Optional[Tuple[str, ...]] = None

    # SSM / xLSTM
    d_state: int = 16
    d_conv: int = 4
    ssm_chunk: int = 512
    mlstm_proj_factor: float = 2.0

    # encoder-decoder (whisper) / VLM stubs
    enc_layers: int = 0
    enc_frames: int = 1500
    num_patches: int = 0

    # norms / activations / embeddings
    norm_type: str = "rmsnorm"
    activation: str = "silu"
    gated_mlp: bool = True
    tie_embeddings: bool = True

    # numerics / memory
    param_dtype: str = "float32"
    activ_dtype: str = "float32"
    remat: str = "none"                     # none | dots | full

    # beyond-paper perf levers (EXPERIMENTS.md §Perf)
    seq_sharded_acts: bool = False          # Megatron-SP residual stream
    row_accum_dtype: str = "float32"        # row-parallel matmul psum dtype
    moe_impl: str = "gspmd"                 # gspmd | alltoall (shard_map EP)
    paged_attn_impl: str = "fused"          # fused (page walk) | gather (view)

    # capability flags
    sub_quadratic: bool = False             # may run long_500k
    notes: str = ""

    # ------------------------------------------------------------------

    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def adtype(self):
        return jnp.dtype(self.activ_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + layers)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.head_dim_()
        total = v * d * (1 if self.tie_embeddings else 2)
        from repro.models.transformer import layer_specs  # lazy: avoid cycle

        for spec in layer_specs(self):
            if spec.mixer == "attn":
                total += d * self.n_heads * hd * 2 + d * self.kv_heads * hd * 2
            elif spec.mixer == "mamba":
                di = 2 * d
                dtr = max(d // 16, 1)
                total += d * 2 * di + di * (dtr + 2 * self.d_state) + dtr * di + di * d
            elif spec.mixer == "mlstm":
                di = int(self.mlstm_proj_factor * d)
                total += 2 * d * di + 3 * di * di + di * d
            elif spec.mixer == "slstm":
                total += 4 * d * d + 4 * d * (d // self.n_heads) + 2 * d * int(4 / 3 * d)
            if spec.mlp == "dense":
                total += d * f * (3 if self.gated_mlp else 2)
            elif spec.mlp == "moe":
                total += self.moe_experts * d * f * (3 if self.gated_mlp else 2) + d * self.moe_experts
        if self.enc_layers:
            total += self.enc_layers * (4 * d * self.n_heads * hd + 2 * d * f)
            total += self.n_layers * 4 * d * self.n_heads * hd  # cross attn
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k of experts)."""
        if not self.moe_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        per_expert = d * f * (3 if self.gated_mlp else 2)
        from repro.models.transformer import layer_specs

        moe_layers = sum(1 for s in layer_specs(self) if s.mlp == "moe")
        inactive = moe_layers * (self.moe_experts - self.moe_top_k) * per_expert
        return int(self.param_count() - inactive)


# ---------------------------------------------------------------------------
# Shape cells
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str                   # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) per the assignment's skip rules."""
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k dense KV decode skipped (DESIGN.md §5)"
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of the cell.

    train:   {"tokens","labels"(,"positions","patch_embeds","frames")}
    prefill: same minus labels
    decode:  {"tokens" (B,1)} + cache specs + cache_len
    """
    b, s = cell.global_batch, cell.seq_len
    batch: Dict[str, Any] = {}
    if cell.kind in ("train", "prefill"):
        batch["tokens"] = _sds((b, s), jnp.int32)
        if cell.kind == "train":
            batch["labels"] = _sds((b, s), jnp.int32)
        if cfg.mrope_sections is not None:
            batch["positions"] = _sds((b, s, 3), jnp.int32)
        if cfg.num_patches > 0:
            batch["patch_embeds"] = _sds((b, cfg.num_patches, cfg.d_model), cfg.adtype)
        if cfg.enc_layers > 0:
            batch["frames"] = _sds((b, cfg.enc_frames, cfg.d_model), cfg.adtype)
        return {"batch": batch}

    # decode
    batch["tokens"] = _sds((b, 1), jnp.int32)
    from repro.models.transformer import init_caches  # lazy

    caches = jax.eval_shape(
        lambda: init_caches(cfg, b, s, jnp.dtype(cfg.activ_dtype)
                            if cfg.activ_dtype != "float32" else jnp.bfloat16)
    )
    return {
        "batch": batch,
        "caches": caches,
        "cache_len": _sds((), jnp.int32),
    }


def make_smoke(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        name=cfg.name + "-smoke",
        vocab=min(cfg.vocab, 256),
        d_model=128,
        n_layers=min(cfg.n_layers, 4),
        n_heads=4,
        kv_heads=min(cfg.kv_heads, 4) if cfg.kv_heads < cfg.n_heads else 4,
        d_ff=0 if cfg.d_ff == 0 else 128,
        head_dim=32,
        moe_experts=min(cfg.moe_experts, 4) if cfg.moe_experts else 0,
        moe_top_k=min(cfg.moe_top_k, 2) if cfg.moe_top_k else 0,
        window=min(cfg.window, 32) if cfg.window else None,
        enc_layers=min(cfg.enc_layers, 2) if cfg.enc_layers else 0,
        enc_frames=16 if cfg.enc_layers else cfg.enc_frames,
        num_patches=8 if cfg.num_patches else 0,
        mrope_sections=(4, 6, 6) if cfg.mrope_sections else None,
        attn_chunk=16,
        ssm_chunk=16,
        d_state=8,
        param_dtype="float32",
        activ_dtype="float32",
        remat="none",
    )
    kw.update(overrides)
    return cfg.replace(**kw)
