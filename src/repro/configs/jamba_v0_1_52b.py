"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2, Mamba:attention 7:1 interleave, MoE on
every other layer.  [arXiv:2403.19887]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    vocab=65536,
    d_model=4096,
    n_layers=32,
    n_heads=32,
    kv_heads=8,
    d_ff=14336,
    moe_experts=16,
    moe_top_k=2,
    # jamba period-8 block: attention at index 4, mamba elsewhere (1:7)
    mixer_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    # MoE every other layer, dense MLP otherwise
    mlp_pattern=("dense", "moe"),
    d_state=16,
    d_conv=4,
    ssm_chunk=512,    # chunked scan bounds live memory (whole-seq assoc scan
                      # needs ~970GB/dev for backward — measured in §Dry-run)
    norm_type="rmsnorm",
    activation="silu",
    gated_mlp=True,
    tie_embeddings=True,
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    remat="dots",
    sub_quadratic=True,            # hybrid: SSM state + few attn layers
    notes="long_500k decode: mamba layers carry O(1) state; the 4 "
          "attention layers keep a full 512k KV cache sharded on kv_seq.",
)
