"""deepseek-7b [dense] — 30L d_model=4096 32H (MHA kv=32) d_ff=11008
vocab=102400, llama-arch.  [arXiv:2401.02954]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="lm",
    vocab=102400,
    d_model=4096,
    n_layers=30,
    n_heads=32,
    kv_heads=32,
    d_ff=11008,
    norm_type="rmsnorm",
    activation="silu",
    gated_mlp=True,
    tie_embeddings=False,
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    remat="dots",
    sub_quadratic=False,
)
