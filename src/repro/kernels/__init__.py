"""Pallas TPU kernels for the paper's compute hot spots.

* block_sparse_matmul — the §III-C zero-skipping codegen analogue (BSR)
* structure_norms     — Algorithm 2's per-structure value sweep
* paged_attention     — fused page-table walk with online softmax
  (decode + prefill), O(cache_len) not O(max_len)

Each kernel ships with a jit wrapper (ops.py) and a pure-jnp oracle
(ref.py / a non-gathering ref in paged_attention.py); tests sweep
shapes/dtypes with assert_allclose in interpret mode.
"""
from .epilogue import Epilogue, apply_epilogue, make_epilogue
from .ops import (
    bsr_matmul,
    bsr_planes_matmul,
    paged_attention_decode,
    paged_attention_prefill,
    structure_norms,
)

__all__ = [
    "Epilogue", "apply_epilogue", "make_epilogue",
    "bsr_matmul", "bsr_planes_matmul", "structure_norms",
    "paged_attention_decode", "paged_attention_prefill",
]
