"""Epilogue spec for the fused BSR matmul kernels (DESIGN.md §8).

A matmul epilogue is everything the layer applies to the accumulator
before the next GEMM: bias add, activation, the SwiGLU gate multiply,
and the residual add.  Materializing those as separate ops costs a full
(M, N) round-trip each — on the prefill path that is three extra
(B, T, d_ff) tensors per MLP.  ``Epilogue`` names the fused tail once so
every execution path (Pallas kernel, interpret mode, jnp ref, dense
einsum fallback) applies the identical fp32 math:

    y = accum                      # fp32 out of the MXU / einsum
    y = y + bias                   # (N,) broadcast
    y = act(y)                     # jax.nn.<activation>
    y = y * multiplier             # SwiGLU: y is the gate, mult the up
    y = y + residual               # skip connection
    return y.astype(out_dtype)

The array operands ride the pytree (so the spec jits like any other
argument); the activation name is static aux data — presence/absence of
an operand changes the treedef and therefore retraces, exactly like a
changed kernel configuration should.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["Epilogue", "apply_epilogue", "make_epilogue"]


@dataclasses.dataclass
class Epilogue:
    """Fused matmul tail: ``act(y + bias) * multiplier + residual``."""

    bias: Optional[jnp.ndarray] = None          # (N,)
    multiplier: Optional[jnp.ndarray] = None    # (..., N) — SwiGLU "up"
    residual: Optional[jnp.ndarray] = None      # (..., N) skip input
    activation: Optional[str] = None            # jax.nn name (static)

    def tree_flatten(self):
        return (self.bias, self.multiplier, self.residual), (self.activation,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        bias, multiplier, residual = children
        return cls(bias=bias, multiplier=multiplier, residual=residual,
                   activation=aux[0])

    def map_operands(self, fn) -> "Epilogue":
        """New spec with ``fn`` applied to the (M, N)-shaped operands —
        used by wrappers that reshape/transpose x around the kernel."""
        return Epilogue(
            bias=self.bias,
            multiplier=None if self.multiplier is None else fn(self.multiplier),
            residual=None if self.residual is None else fn(self.residual),
            activation=self.activation,
        )


jax.tree_util.register_pytree_node(
    Epilogue, Epilogue.tree_flatten, Epilogue.tree_unflatten
)


def make_epilogue(
    bias=None, activation: Optional[str] = None, multiplier=None, residual=None
) -> Optional[Epilogue]:
    """Epilogue or None when there is nothing to fuse (keeps the treedef
    of plain matmul calls unchanged)."""
    if bias is None and activation is None and multiplier is None \
            and residual is None:
        return None
    return Epilogue(bias=bias, multiplier=multiplier, residual=residual,
                    activation=activation)


def apply_epilogue(y: jnp.ndarray, epi: Optional[Epilogue]) -> jnp.ndarray:
    """The epilogue contract on a plain array (ref kernels and the dense
    einsum fallback) — fp32 in, fp32 out, same op order as the kernel."""
    if epi is None:
        return y
    if epi.bias is not None:
        y = y + epi.bias.astype(y.dtype)
    if epi.activation is not None:
        y = getattr(jax.nn, epi.activation)(y)
    if epi.multiplier is not None:
        y = y * epi.multiplier
    if epi.residual is not None:
        # natural promotion: a bf16 accumulator must not downcast the
        # (possibly wider) residual stream
        y = y + epi.residual
    return y
