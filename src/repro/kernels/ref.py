"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.packing import BSRWeight, bsr_to_dense

__all__ = ["bsr_matmul_ref", "structure_norms_ref"]


def bsr_matmul_ref(x: jnp.ndarray, bsr: BSRWeight) -> jnp.ndarray:
    """y = x @ dense(bsr), fp32 accumulation."""
    dense = bsr_to_dense(bsr)
    y = jnp.dot(x, dense.astype(x.dtype), preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def structure_norms_ref(w: jnp.ndarray, bk: int, bn: int) -> jnp.ndarray:
    k, n = w.shape
    bk, bn = min(bk, k), min(bn, n)
    gk, gn = -(-k // bk), -(-n // bn)
    wp = jnp.pad(w, ((0, gk * bk - k), (0, gn * bn - n)))
    t = wp.reshape(gk, bk, gn, bn)
    return jnp.sqrt(jnp.sum(jnp.square(t.astype(jnp.float32)), axis=(1, 3)))
