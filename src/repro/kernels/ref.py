"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests).

``bsr_matmul_ref`` is also the CPU *serving* path (kernels/ops.py routes
here off-TPU), so it must honour the zero-skipping contract: it never
reconstructs the dense weight.  It contracts the packed *flat store*
directly — ONE batched ``(nnz, M, bk) @ (nnz, bk, bn)`` GEMM over the
live tiles, then a sorted segment-sum over output block-columns (BSR
columns partition the output, so no scatter is needed).  Work scales
with the *true* ``nnz_blocks`` — not ``grid_n * max_nnz`` like the old
per-column padded contraction, which at 75% sparsity did ~3x the live
work because every column paid the worst column's slot count.  This is
what makes prefill-shaped (large-M) packed GEMMs beat dense on CPU.

Flat-store padding slots carry exact-zero blocks (pack time), so they
contribute nothing wherever their (row 0, col 0) coordinates point — no
re-masking pass over the weights per call.

The fused ``Epilogue`` (bias / activation / SwiGLU gate / residual) is
applied on the fp32 accumulator before the final cast, matching the
Pallas kernel's in-VMEM epilogue bit-for-bit on the ref path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.packing import BSRPlanes, BSRWeight
from .epilogue import Epilogue, apply_epilogue

__all__ = ["bsr_matmul_ref", "bsr_planes_matmul_ref", "structure_norms_ref"]


def _pad_k(x: jnp.ndarray, bk: int) -> jnp.ndarray:
    k = x.shape[-1]
    pad = (-k) % bk
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x


def bsr_matmul_ref(
    x: jnp.ndarray,                  # (M, K)
    bsr: BSRWeight,
    *,
    epilogue: Optional[Epilogue] = None,
) -> jnp.ndarray:
    """y = epilogue(x @ W_bsr) contracting the flat live-tile store only."""
    bk, bn = bsr.blocking.bk, bsr.blocking.bn
    gn = bsr.grid_n
    xp = _pad_k(x, bk)
    m = xp.shape[0]
    # transpose x to block-row-major ONCE, then the per-tile gather is a
    # cheap leading-axis take (xg rows are contiguous (M, bk) panels)
    xt = jnp.swapaxes(xp.reshape(m, -1, bk), 0, 1)           # (gk, M, bk)
    xg = jnp.take(xt, bsr.flat_rows, axis=0)                 # (Z, M, bk)
    contrib = jnp.einsum("zmb,zbn->zmn", xg, bsr.blocks,
                         preferred_element_type=jnp.float32)  # (Z, M, bn)
    y = jax.ops.segment_sum(contrib, bsr.flat_cols, num_segments=gn,
                            indices_are_sorted=True)          # (gn, M, bn)
    y = jnp.moveaxis(y, 0, 1).reshape(m, gn * bn)[:, : bsr.shape[1]]
    return apply_epilogue(y, epilogue).astype(x.dtype)


def bsr_planes_matmul_ref(
    x: jnp.ndarray,                  # (E, M, K)
    planes: BSRPlanes,
    *,
    epilogue: Optional[Epilogue] = None,
) -> jnp.ndarray:
    """Fused per-plane BSR matmul -> (E, M, n) in x.dtype.

    One batched GEMM over every plane's flat store at once; the segment
    ids get a per-plane ``e * grid_n`` offset so a single sorted
    segment-sum produces all planes' output columns.  A fully-pruned
    plane costs only its zero-block padding slots."""
    e, m, _ = x.shape
    bk, bn = planes.blocking.bk, planes.blocking.bn
    gn = planes.grid_n
    n = planes.shape[-1]
    z = planes.blocks.shape[1]
    xp = _pad_k(x, bk)
    xt = jnp.swapaxes(xp.reshape(e, m, -1, bk), 1, 2)        # (E, gk, M, bk)
    xg = jnp.take_along_axis(
        xt, planes.flat_rows[:, :, None, None], axis=1)      # (E, Z, M, bk)
    contrib = jnp.einsum("ezmb,ezbn->ezmn", xg, planes.blocks,
                         preferred_element_type=jnp.float32)  # (E, Z, M, bn)
    segs = (planes.flat_cols
            + jnp.arange(e, dtype=jnp.int32)[:, None] * gn).reshape(-1)
    y = jax.ops.segment_sum(contrib.reshape(e * z, m, bn), segs,
                            num_segments=e * gn, indices_are_sorted=True)
    y = jnp.moveaxis(y.reshape(e, gn, m, bn), 1, 2)          # (E, M, gn, bn)
    y = y.reshape(e, m, gn * bn)[:, :, :n]
    return apply_epilogue(y, epilogue).astype(x.dtype)


def structure_norms_ref(w: jnp.ndarray, bk: int, bn: int) -> jnp.ndarray:
    k, n = w.shape
    bk, bn = min(bk, k), min(bn, n)
    gk, gn = -(-k // bk), -(-n // bn)
    wp = jnp.pad(w, ((0, gk * bk - k), (0, gn * bn - n)))
    t = wp.reshape(gk, bk, gn, bn)
    return jnp.sqrt(jnp.sum(jnp.square(t.astype(jnp.float32)), axis=(1, 3)))
