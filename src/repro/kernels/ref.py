"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests).

``bsr_matmul_ref`` is also the CPU *serving* path (kernels/ops.py routes
here off-TPU), so it must honour the zero-skipping contract: it never
reconstructs the dense weight.  Instead it gathers exactly the live
block-rows of ``x`` named by the BSR indices, contracts them against the
packed blocks with one batched einsum, and sums per output block-column
— BSR columns partition the output, so no scatter is needed.  Padding
slots (index -1) contribute zero (their blocks are zeroed at pack time
and re-masked here for safety).  Work scales with ``nnz_blocks``, not
``grid_k * grid_n`` — the same roofline scaling as the TPU kernel.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.packing import BSRWeight

__all__ = ["bsr_matmul_ref", "bsr_planes_matmul_ref", "structure_norms_ref"]


def _bsr_cols(
    x: jnp.ndarray,          # (M, gk * bk) — K already padded to the block grid
    indices: jnp.ndarray,    # (grid_n, max_nnz) int32, -1 padded
    blocks: jnp.ndarray,     # (grid_n, max_nnz, bk, bn)
) -> jnp.ndarray:
    """Per-column live-block contraction -> (M, grid_n * bn) fp32.

    The slot dim folds into the contraction: each output block-column is
    ONE (M, s*bk) @ (s*bk, bn) GEMM over its live tiles — batched over
    grid_n only, so XLA lowers to a few big dots instead of grid_n*s tiny
    ones (2x dense at 25% density on CPU, vs ~par for the naive
    (gn, s)-batched form)."""
    gn, s, bk, bn = blocks.shape
    m = x.shape[0]
    xb = x.reshape(m, x.shape[1] // bk, bk)                  # (M, gk, bk)
    live = indices >= 0
    # gather only the block-rows the live slots name (padding fetches row 0,
    # then gets masked — the jnp analogue of the kernel's benign pad DMA)
    xg = jnp.take(xb, jnp.maximum(indices, 0), axis=1)       # (M, gn, s, bk)
    xg = jnp.moveaxis(xg, 0, 1).reshape(gn, m, s * bk)
    wb = jnp.where(live[..., None, None], blocks, 0).astype(x.dtype)
    y = jnp.einsum("jmk,jkn->jmn", xg, wb.reshape(gn, s * bk, bn),
                   preferred_element_type=jnp.float32)       # (gn, M, bn)
    return jnp.moveaxis(y, 0, 1).reshape(m, gn * bn)


def _pad_k(x: jnp.ndarray, bk: int) -> jnp.ndarray:
    k = x.shape[-1]
    pad = (-k) % bk
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x


def bsr_matmul_ref(x: jnp.ndarray, bsr: BSRWeight) -> jnp.ndarray:
    """y = x @ W_bsr for x (M, K), contracting live blocks only."""
    bk = bsr.blocking.bk
    y = _bsr_cols(_pad_k(x, bk), bsr.indices, bsr.blocks)
    return y[:, : bsr.shape[1]].astype(x.dtype)


def bsr_planes_matmul_ref(
    x: jnp.ndarray,          # (E, M, K)
    indices: jnp.ndarray,    # (E, grid_n, max_nnz) int32, -1 padded
    blocks: jnp.ndarray,     # (E, grid_n, max_nnz, bk, bn)
    *,
    n: int,
) -> jnp.ndarray:
    """Fused per-plane BSR matmul -> (E, M, n) in x.dtype.

    One segment-wise einsum over every plane's live blocks at once; a
    fully-pruned plane costs only its padding slots."""
    e, gn, s, bk, bn = blocks.shape
    m = x.shape[1]
    xp = _pad_k(x, bk)
    xb = xp.reshape(e, m, xp.shape[-1] // bk, bk)            # (E, M, gk, bk)
    live = indices >= 0
    xg = jnp.take_along_axis(
        xb, jnp.maximum(indices, 0).reshape(e, 1, gn * s, 1), axis=2,
    ).reshape(e, m, gn, s, bk)
    # fold slots into the contraction (see _bsr_cols): one GEMM per
    # (plane, block-column) pair, batched over (E, grid_n)
    xg = jnp.moveaxis(xg, 1, 2).reshape(e, gn, m, s * bk)
    wb = jnp.where(live[..., None, None], blocks, 0).astype(x.dtype)
    y = jnp.einsum("ejmk,ejkn->ejmn", xg, wb.reshape(e, gn, s * bk, bn),
                   preferred_element_type=jnp.float32)       # (E, gn, M, bn)
    return jnp.moveaxis(y, 1, 2).reshape(e, m, gn * bn)[:, :, :n].astype(x.dtype)


def structure_norms_ref(w: jnp.ndarray, bk: int, bn: int) -> jnp.ndarray:
    k, n = w.shape
    bk, bn = min(bk, k), min(bn, n)
    gk, gn = -(-k // bk), -(-n // bn)
    wp = jnp.pad(w, ((0, gk * bk - k), (0, gn * bn - n)))
    t = wp.reshape(gk, bk, gn, bn)
    return jnp.sqrt(jnp.sum(jnp.square(t.astype(jnp.float32)), axis=(1, 3)))
