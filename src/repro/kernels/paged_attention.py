"""Fused paged-attention kernels: walk the page table, never gather.

The serving pool stores every sequence's KV in fixed-size pages
``(num_pages, page_size, K, dh)`` with a per-row table of page ids
(serving/pages.py, DESIGN.md §9).  The naive decode path materializes the
logical view first — ``pool[page_table].reshape(b, max_len, K, dh)`` —
so every token pays O(max_pages · page_size) memory traffic no matter
how short the row's real context is.  These kernels instead *walk* the
table: grid over (batch, kv_head), inner loop over pages, an
online-softmax accumulator carried across pages, and the just-computed
current token's K/V kept in-register (it seeds the accumulator and never
round-trips through the pool).  Work and traffic scale with the live
``cache_len``, not the allocation — the same locality argument the
paper makes for structured pruning: compression only pays when the
kernel respects the memory layout.

Online-softmax recurrence per page (all fp32):

    m2  = max(m, max_s(scores))          # running max
    r   = exp(m - m2)                    # rescale factor for old state
    p   = where(valid, exp(s - m2), 0)   # page probabilities (unnormed)
    l   = l·r + Σ_s p                    # running normalizer
    acc = acc·r + p @ V_page             # running weighted values
    out = acc / l                        # after the last page

Decode seeds the state with the in-register current token — ``m = s_new,
l = 1, acc = v_new`` — so every row has a non-empty softmax even at
``cache_len == 0`` (a free slot parked on the null page).

Two backends behind ``ops.paged_attention_decode`` / ``_prefill``:

* ``*_ref``    — pure-jnp, but still **non-gathering**: a
  ``fori_loop`` over page *segments* bounded by ``max(cache_len)``, so
  CPU serving gets the same work-scales-with-context contract as the
  TPU kernel (and stays bit-comparable to it at ``pages_per_step=1`` —
  the ref mirrors the kernel's op sequence exactly).
* ``*_pallas`` — the TPU kernel; ``interpret=True`` runs the same body
  on CPU for CI.  Page ids are scalar-prefetched (SMEM) and the pool
  BlockSpec index map clamps dead steps to the last live page, so a
  revisited block index skips the DMA — traffic is O(cache_len) even
  though the grid is statically sized by the table width.

Masked positions never touch values: scores get the finite ``NEG_INF``
sentinel *and* the value contribution is zeroed (``p`` is where-masked),
so NaN poison in unallocated pages (the null page, freed pages) cannot
leak through a ``0 · NaN`` in the value contraction.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "paged_attention_decode_ref",
    "paged_attention_decode_pallas",
    "paged_attention_prefill_ref",
    "paged_attention_prefill_pallas",
]

NEG_INF = -1e30  # finite mask sentinel (matches models/attention.py)


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# Decode: one query token per row over [0, cache_len) pool positions
# ---------------------------------------------------------------------------

def paged_attention_decode_ref(
    q: jnp.ndarray,            # (B, H, dh) — rotated query for the new token
    k_new: jnp.ndarray,        # (B, K, dh) — rotated K of the new token
    v_new: jnp.ndarray,        # (B, K, dh)
    k_pool: jnp.ndarray,       # (P, page_size, K, dh) physical pages
    v_pool: jnp.ndarray,       # (P, page_size, K, dh)
    page_table: jnp.ndarray,   # (B, max_pages) int32 pool ids
    cache_len: jnp.ndarray,    # (B,) int32 — #prior tokens (new token excluded)
    *,
    pages_per_step: int = 8,
) -> jnp.ndarray:
    """Non-gathering reference: page-segment ``fori_loop`` bounded by
    ``max(cache_len)``, online softmax across segments.  Returns
    (B, H, dh) fp32.  ``pages_per_step=1`` is bit-comparable to the
    Pallas kernel (same op order per page); larger segments amortize the
    loop on CPU and stay within float rounding of it."""
    b, h, dh = q.shape
    kvh = k_new.shape[1]
    g = h // kvh
    ps = k_pool.shape[1]
    max_pages = page_table.shape[1]
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(b, kvh, g, dh).astype(jnp.float32)
    kn = k_new.astype(jnp.float32)
    vn = v_new.astype(jnp.float32)
    clen = jnp.broadcast_to(
        jnp.asarray(cache_len, jnp.int32).reshape(-1), (b,))

    # the in-register current token seeds the state (its own score is the
    # first max, so exp(s_new - m) = 1): m = s_new, l = 1, acc = v_new —
    # the same seed the Pallas kernel uses, keeping the two bit-comparable
    s_new = jnp.sum(qg * kn[:, :, None, :], axis=-1, keepdims=True) * scale
    m0 = s_new                                              # (B,K,G,1)
    l0 = jnp.ones_like(s_new)
    acc0 = jnp.broadcast_to(vn[:, :, None, :], (b, kvh, g, dh)).astype(
        jnp.float32)

    seg = pages_per_step * ps                               # positions / step
    offs = jnp.arange(ps, dtype=jnp.int32)
    page_idx = jnp.arange(pages_per_step, dtype=jnp.int32)

    def body(j, carry):
        m, l, acc = carry
        idx = j * pages_per_step + page_idx                 # logical pages
        # clip the *lookup* (labels stay logical): positions past the
        # table are masked below, never mislabeled
        pid = jnp.take(page_table, jnp.minimum(idx, max_pages - 1), axis=1)
        kp = k_pool[pid].reshape(b, seg, kvh, dh).astype(jnp.float32)
        vp = v_pool[pid].reshape(b, seg, kvh, dh).astype(jnp.float32)
        pos = (idx[:, None] * ps + offs[None, :]).reshape(seg)
        valid = (pos[None, :] < clen[:, None]) & (pos[None, :] < max_pages * ps)
        s = jnp.einsum("bkgd,bskd->bkgs", qg, kp,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        # zero masked values too: unallocated pages may hold anything
        # (NaN-poisoned in tests) and 0 · NaN = NaN in the contraction
        vp = jnp.where(valid[:, :, None, None], vp, 0.0)
        m2 = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        r = jnp.exp(m - m2)
        p = jnp.where(valid[:, None, None, :], jnp.exp(s - m2), 0.0)
        l = l * r + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * r + jnp.einsum(
            "bkgs,bskd->bkgd", p, vp, preferred_element_type=jnp.float32)
        return m2, l, acc

    n_steps = (jnp.max(clen) + seg - 1) // seg
    m, l, acc = jax.lax.fori_loop(0, n_steps, body, (m0, l0, acc0))
    return (acc / l).reshape(b, h, dh)


def _decode_kernel(tbl_ref, clen_ref, q_ref, kn_ref, vn_ref, kp_ref, vp_ref,
                   o_ref, m_ref, l_ref, acc_ref, *, page_size: int,
                   scale: float):
    """Grid (B, K, max_pages); scratch m/l/acc persists across the
    innermost page dimension.  j == 0 seeds from the in-register current
    token; dead pages (j·ps >= cache_len) are skipped; the last step
    normalizes into the output block."""
    bb = pl.program_id(0)
    j = pl.program_id(2)
    clen = clen_ref[bb]
    qg = q_ref[0, 0].astype(jnp.float32)                    # (G, dh)

    @pl.when(j == 0)
    def _seed():
        kn = kn_ref[0, 0].astype(jnp.float32)               # (dh,)
        s_new = jnp.sum(qg * kn[None, :], axis=-1, keepdims=True) * scale
        m_ref[...] = s_new                                  # (G, 1)
        l_ref[...] = jnp.ones_like(s_new)
        acc_ref[...] = jnp.broadcast_to(
            vn_ref[0, 0].astype(jnp.float32)[None, :], acc_ref.shape)

    @pl.when(j * page_size < clen)
    def _page():
        kp = kp_ref[0, :, 0, :].astype(jnp.float32)         # (ps, dh)
        vp = vp_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            qg, kp, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale     # (G, ps)
        pos = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, page_size), 1)
        valid = pos < clen                                  # (1, ps)
        s = jnp.where(valid, s, NEG_INF)
        vp = jnp.where(valid.reshape(page_size, 1), vp, 0.0)
        m = m_ref[...]
        m2 = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        r = jnp.exp(m - m2)
        p = jnp.where(valid, jnp.exp(s - m2), 0.0)
        l_ref[...] = l_ref[...] * r + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * r + jnp.dot(
            p, vp, preferred_element_type=jnp.float32)
        m_ref[...] = m2

    @pl.when(j == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0, 0] = acc_ref[...] / l_ref[...]


def paged_attention_decode_pallas(
    q: jnp.ndarray,            # (B, H, dh)
    k_new: jnp.ndarray,        # (B, K, dh)
    v_new: jnp.ndarray,        # (B, K, dh)
    k_pool: jnp.ndarray,       # (P, page_size, K, dh)
    v_pool: jnp.ndarray,       # (P, page_size, K, dh)
    page_table: jnp.ndarray,   # (B, max_pages) int32
    cache_len: jnp.ndarray,    # (B,) int32
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, dh = q.shape
    kvh = k_new.shape[1]
    g = h // kvh
    ps = k_pool.shape[1]
    max_pages = page_table.shape[1]
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(b, kvh, g, dh)
    clen = jnp.broadcast_to(
        jnp.asarray(cache_len, jnp.int32).reshape(-1), (b,))

    def pool_map(bb, k, j, tbl, cl):
        # clamp dead steps to the last live page: a repeated block index
        # skips the DMA, so traffic is O(cache_len) not O(max_pages)
        live = (cl[bb] + ps - 1) // ps
        jj = jnp.minimum(j, jnp.maximum(live - 1, 0))
        return (tbl[bb, jj], 0, k, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda bb, k, j, tbl, cl: (bb, k, 0, 0)),
            pl.BlockSpec((1, 1, dh), lambda bb, k, j, tbl, cl: (bb, k, 0)),
            pl.BlockSpec((1, 1, dh), lambda bb, k, j, tbl, cl: (bb, k, 0)),
            pl.BlockSpec((1, ps, 1, dh), pool_map),
            pl.BlockSpec((1, ps, 1, dh), pool_map),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, g, dh), lambda bb, k, j, tbl, cl: (bb, k, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),     # running max m
            pltpu.VMEM((g, 1), jnp.float32),     # running normalizer l
            pltpu.VMEM((g, dh), jnp.float32),    # fp32 output accumulator
        ],
    )
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        )
    out = pl.pallas_call(
        functools.partial(_decode_kernel, page_size=ps, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, dh), jnp.float32),
        interpret=interpret,
        **kwargs,
    )(page_table, clen, qg, k_new, v_new, k_pool, v_pool)
    return out.reshape(b, h, dh)


# ---------------------------------------------------------------------------
# Prefill: bm-tiled query blocks over the same page walk, causal mask
# ---------------------------------------------------------------------------

def paged_attention_prefill_ref(
    q: jnp.ndarray,            # (B, S, H, dh) — rotated, pos [q_offset, q_offset+S)
    k_pool: jnp.ndarray,       # (P, page_size, K, dh) — prompt K/V scattered in
    v_pool: jnp.ndarray,
    page_table: jnp.ndarray,   # (B, max_pages) int32
    lengths: jnp.ndarray,      # (B,) int32 — per-row TOTAL length (<= q_offset+S)
    *,
    pages_per_step: int = 8,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Causal paged prefill reference: same page-segment walk as decode,
    vectorized over all S query rows.  With ``q_offset`` (static) the
    queries sit at logical positions ``[q_offset, q_offset+S)`` and the
    walk covers every page from logical position 0 — the tail-only
    prefill of a request whose first ``q_offset`` tokens are already
    cached in shared prefix pages (DESIGN.md §12).  ``lengths`` is the
    per-row *total* context (prefix + tail); rows at/past their length
    get zero output.  Returns (B, S, H, dh) fp32."""
    b, s, h, dh = q.shape
    kvh = k_pool.shape[2]
    g = h // kvh
    ps = k_pool.shape[1]
    max_pages = page_table.shape[1]
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(b, s, kvh, g, dh).transpose(0, 2, 3, 1, 4).astype(
        jnp.float32)                                        # (B,K,G,S,dh)
    ln = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32).reshape(-1), (b,))

    m0 = jnp.full((b, kvh, g, s, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, s, 1), jnp.float32)
    acc0 = jnp.zeros((b, kvh, g, s, dh), jnp.float32)
    qpos = q_offset + jnp.arange(s, dtype=jnp.int32)
    seg = pages_per_step * ps
    offs = jnp.arange(ps, dtype=jnp.int32)
    page_idx = jnp.arange(pages_per_step, dtype=jnp.int32)

    def body(j, carry):
        m, l, acc = carry
        idx = j * pages_per_step + page_idx
        pid = jnp.take(page_table, jnp.minimum(idx, max_pages - 1), axis=1)
        kp = k_pool[pid].reshape(b, seg, kvh, dh).astype(jnp.float32)
        vp = v_pool[pid].reshape(b, seg, kvh, dh).astype(jnp.float32)
        kvpos = (idx[:, None] * ps + offs[None, :]).reshape(seg)
        # (B, S, seg): causal x per-row length, labels stay logical
        valid = ((kvpos[None, None, :] <= qpos[None, :, None])
                 & (kvpos[None, None, :] < ln[:, None, None])
                 & (qpos[None, :, None] < ln[:, None, None]))
        kv_live = kvpos[None, :] < ln[:, None]              # (B, seg)
        sb = jnp.einsum("bkgqd,bskd->bkgqs", qg, kp,
                        preferred_element_type=jnp.float32) * scale
        sb = jnp.where(valid[:, None, None], sb, NEG_INF)
        vp = jnp.where(kv_live[:, :, None, None], vp, 0.0)
        m2 = jnp.maximum(m, jnp.max(sb, axis=-1, keepdims=True))
        r = jnp.exp(m - m2)
        p = jnp.where(valid[:, None, None], jnp.exp(sb - m2), 0.0)
        l = l * r + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * r + jnp.einsum("bkgqs,bskd->bkgqd", p, vp,
                                   preferred_element_type=jnp.float32)
        return m2, l, acc

    n_steps = _cdiv(_cdiv(q_offset + s, ps), pages_per_step)
    m, l, acc = jax.lax.fori_loop(0, n_steps, body, (m0, l0, acc0))
    out = acc / jnp.where(l == 0.0, 1.0, l)                 # dead rows -> 0
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, dh)


def _prefill_kernel(tbl_ref, len_ref, q_ref, kp_ref, vp_ref, o_ref,
                    m_ref, l_ref, acc_ref, *, page_size: int, block_q: int,
                    group: int, scale: float, q_offset: int):
    """Grid (B, K, q_tiles, pages), pages innermost.  Query rows are laid
    out (bm·G, dh) so one dot covers the whole GQA group; the causal mask
    is built from 2D iotas (qpos = q_offset + row // G, kvpos = page
    offset) — ``q_offset`` shifts every query to its logical position for
    tail-only prefill over shared prefix pages (DESIGN.md §12)."""
    bb = pl.program_id(0)
    i = pl.program_id(2)
    j = pl.program_id(3)
    ln = len_ref[bb]

    @pl.when(j == 0)
    def _seed():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # pages needed by this q tile: kvpos <= qpos < min(len, q_offset+(i+1)·bm)
    qhi = jnp.minimum(ln, q_offset + (i + 1) * block_q)

    @pl.when(j * page_size < qhi)
    def _page():
        dh = acc_ref.shape[-1]
        qg = q_ref[0, 0].astype(jnp.float32).reshape(block_q * group, dh)
        kp = kp_ref[0, :, 0, :].astype(jnp.float32)         # (ps, dh)
        vp = vp_ref[0, :, 0, :].astype(jnp.float32)
        sb = jax.lax.dot_general(
            qg, kp, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale     # (bm·G, ps)
        shp = (block_q * group, page_size)
        qpos = (q_offset + i * block_q
                + jax.lax.broadcasted_iota(jnp.int32, shp, 0) // group)
        kvpos = j * page_size + jax.lax.broadcasted_iota(jnp.int32, shp, 1)
        valid = (kvpos <= qpos) & (kvpos < ln) & (qpos < ln)
        sb = jnp.where(valid, sb, NEG_INF)
        kv_live = (j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (page_size, 1), 0)) < ln
        vp = jnp.where(kv_live, vp, 0.0)
        m = m_ref[...]
        m2 = jnp.maximum(m, jnp.max(sb, axis=-1, keepdims=True))
        r = jnp.exp(m - m2)
        p = jnp.where(valid, jnp.exp(sb - m2), 0.0)
        l_ref[...] = l_ref[...] * r + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * r + jnp.dot(
            p, vp, preferred_element_type=jnp.float32)
        m_ref[...] = m2

    @pl.when(j == pl.num_programs(3) - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).reshape(
            o_ref.shape[2:])


def paged_attention_prefill_pallas(
    q: jnp.ndarray,            # (B, S, H, dh)
    k_pool: jnp.ndarray,       # (P, page_size, K, dh)
    v_pool: jnp.ndarray,
    page_table: jnp.ndarray,   # (B, max_pages) int32
    lengths: jnp.ndarray,      # (B,) int32
    *,
    bm: int = 64,
    interpret: bool = False,
    q_offset: int = 0,
) -> jnp.ndarray:
    b, s, h, dh = q.shape
    kvh = k_pool.shape[2]
    g = h // kvh
    ps = k_pool.shape[1]
    max_pages = page_table.shape[1]
    scale = 1.0 / math.sqrt(dh)
    bm = min(bm, s)
    s_pad = _cdiv(s, bm) * bm
    n_qt = s_pad // bm
    n_pg = _cdiv(q_offset + s, ps)                          # context pages only
    ln = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32).reshape(-1), (b,))

    qt = q.reshape(b, s, kvh, g, dh).transpose(0, 2, 1, 3, 4)  # (B,K,S,G,dh)
    if s_pad != s:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, s_pad - s), (0, 0), (0, 0)))

    def pool_map(bb, k, i, j, tbl, cl):
        live = (jnp.minimum(cl[bb], q_offset + (i + 1) * bm) + ps - 1) // ps
        jj = jnp.minimum(j, jnp.maximum(live - 1, 0))
        return (tbl[bb, jj], 0, k, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, n_qt, n_pg),
        in_specs=[
            pl.BlockSpec((1, 1, bm, g, dh),
                         lambda bb, k, i, j, tbl, cl: (bb, k, i, 0, 0)),
            pl.BlockSpec((1, ps, 1, dh), pool_map),
            pl.BlockSpec((1, ps, 1, dh), pool_map),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, bm, g, dh), lambda bb, k, i, j, tbl, cl: (bb, k, i, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((bm * g, 1), jnp.float32),
            pltpu.VMEM((bm * g, 1), jnp.float32),
            pltpu.VMEM((bm * g, dh), jnp.float32),
        ],
    )
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        )
    out = pl.pallas_call(
        functools.partial(_prefill_kernel, page_size=ps, block_q=bm,
                          group=g, scale=scale, q_offset=q_offset),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, s_pad, g, dh), jnp.float32),
        interpret=interpret,
        **kwargs,
    )(page_table, ln, qt, k_pool, v_pool)
    return out[:, :, :s].transpose(0, 2, 1, 3, 4).reshape(b, s, h, dh)
