"""Per-structure L2 norms Pallas kernel — the pruning-step hot spot.

Every pruning iteration computes ||w_i|| for every resource-aware structure
(Algorithm 2's value update).  At the 100B-param scale of the assigned
archs that is a full sweep over all weights; this kernel tiles the weight
matrix through VMEM once, emitting one fp32 norm per (bk, bn) tile.

Grid: (grid_k, grid_n); each step reduces one tile.  Reference oracle:
``core.structures.structure_norms_dense``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["structure_norms_kernel", "structure_norms_pallas"]


def structure_norms_kernel(w_ref, o_ref):
    sq = jnp.sum(jnp.square(w_ref[...].astype(jnp.float32)))
    o_ref[0, 0] = jnp.sqrt(sq)


def structure_norms_pallas(
    w: jnp.ndarray,          # (K, N)
    *,
    bk: int = 128,
    bn: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns (grid_k, grid_n) fp32 tile norms (zero-padded tail tiles)."""
    k, n = w.shape
    bk, bn = min(bk, k), min(bn, n)
    gk, gn = -(-k // bk), -(-n // bn)
    pk, pn = gk * bk - k, gn * bn - n
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    return pl.pallas_call(
        structure_norms_kernel,
        grid=(gk, gn),
        in_specs=[pl.BlockSpec((bk, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gk, gn), jnp.float32),
        interpret=interpret,
    )(w)
