"""Jit'd public wrappers around the Pallas kernels.

``use_pallas`` defaults to interpret-mode on CPU hosts (this container) and
compiled mode on real TPU backends; the pure-jnp fallbacks are what the
dry-run lowers (Pallas TPU kernels cannot target the CPU SPMD dry-run —
see DESIGN.md §3).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.packing import BSRWeight
from .block_sparse_matmul import bsr_matmul_pallas
from .structure_norms import structure_norms_pallas
from . import ref as _ref

__all__ = ["bsr_matmul", "structure_norms", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("bm", "mode"))
def bsr_matmul(
    x: jnp.ndarray,
    bsr: BSRWeight,
    *,
    bm: int = 128,
    mode: str = "auto",          # auto | pallas | interpret | ref
) -> jnp.ndarray:
    """y = x @ W_bsr for x (..., K); skips pruned tiles on TPU."""
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    if mode == "ref" or (mode == "auto" and not on_tpu()):
        y = _ref.bsr_matmul_ref(x2, bsr)
    else:
        interpret = (mode == "interpret") or (mode == "auto" and not on_tpu())
        y = bsr_matmul_pallas(
            x2, bsr.indices, bsr.blocks, n=bsr.shape[1], bm=bm, interpret=interpret
        )
    return y.reshape(*lead, bsr.shape[1])


@functools.partial(jax.jit, static_argnames=("bk", "bn", "mode"))
def structure_norms(
    w: jnp.ndarray, *, bk: int = 128, bn: int = 128, mode: str = "auto"
) -> jnp.ndarray:
    """Tile L2 norms (grid_k, grid_n) fp32 for a (K, N) weight."""
    if mode == "ref" or (mode == "auto" and not on_tpu()):
        return _ref.structure_norms_ref(w, bk, bn)
    interpret = (mode == "interpret") or (mode == "auto" and not on_tpu())
    return structure_norms_pallas(w, bk=bk, bn=bn, interpret=interpret)
