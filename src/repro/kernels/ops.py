"""Jit'd public wrappers around the Pallas kernels.

Mode dispatch (``mode=``):
* ``auto``      — ref path off-TPU, compiled Pallas on TPU (serving default)
* ``ref``       — pure-jnp zero-skipping oracle (kernels/ref.py)
* ``pallas``    — compiled Pallas (TPU only)
* ``interpret`` — the Pallas kernel under the interpreter, any backend —
  this is how CI exercises the real kernel body on CPU hosts

The ref path is itself zero-skipping (it contracts live blocks only, no
densify — see kernels/ref.py), so CPU serving gets the same
work-scales-with-density contract as the TPU kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.packing import BSRWeight
from .block_sparse_matmul import bsr_matmul_pallas, bsr_planes_matmul_pallas
from .structure_norms import structure_norms_pallas
from . import ref as _ref

__all__ = ["bsr_matmul", "bsr_planes_matmul", "structure_norms", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _use_ref(mode: str) -> bool:
    if mode not in ("auto", "ref", "pallas", "interpret"):
        raise ValueError(f"unknown kernel mode {mode!r}")
    return mode == "ref" or (mode == "auto" and not on_tpu())


@functools.partial(jax.jit, static_argnames=("bm", "mode"))
def bsr_matmul(
    x: jnp.ndarray,
    bsr: BSRWeight,
    *,
    bm: int = 128,
    mode: str = "auto",          # auto | pallas | interpret | ref
) -> jnp.ndarray:
    """y = x @ W_bsr for x (..., K); skips pruned tiles on every path."""
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    if _use_ref(mode):
        y = _ref.bsr_matmul_ref(x2, bsr)
    else:
        y = bsr_matmul_pallas(
            x2, bsr.indices, bsr.blocks, n=bsr.shape[1], bm=bm,
            interpret=(mode == "interpret"),
        )
    return y.reshape(*lead, bsr.shape[1])


@functools.partial(jax.jit, static_argnames=("n", "bm", "mode"))
def bsr_planes_matmul(
    x: jnp.ndarray,              # (E, ..., K)
    indices: jnp.ndarray,        # (E, grid_n, max_nnz)
    blocks: jnp.ndarray,         # (E, grid_n, max_nnz, bk, bn)
    *,
    n: int,
    bm: int = 128,
    mode: str = "auto",
) -> jnp.ndarray:
    """Fused gather-free per-plane matmul: y[e] = x[e] @ W_bsr[e].

    One call for the whole plane stack (the MoE expert dimension) —
    no python loop over planes, no per-expert stack."""
    e = x.shape[0]
    lead = x.shape[1:-1]
    k = x.shape[-1]
    x3 = x.reshape(e, -1, k)
    if _use_ref(mode):
        y = _ref.bsr_planes_matmul_ref(x3, indices, blocks, n=n)
    else:
        y = bsr_planes_matmul_pallas(
            x3, indices, blocks, n=n, bm=bm, interpret=(mode == "interpret")
        )
    return y.reshape(e, *lead, n)


@functools.partial(jax.jit, static_argnames=("bk", "bn", "mode"))
def structure_norms(
    w: jnp.ndarray, *, bk: int = 128, bn: int = 128, mode: str = "auto"
) -> jnp.ndarray:
    """Tile L2 norms (grid_k, grid_n) fp32 for a (K, N) weight."""
    if _use_ref(mode):
        return _ref.structure_norms_ref(w, bk, bn)
    return structure_norms_pallas(w, bk=bk, bn=bn, interpret=(mode == "interpret"))
