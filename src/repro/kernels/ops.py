"""Jit'd public wrappers around the Pallas kernels.

Mode dispatch (``mode=``):
* ``auto``      — ref path off-TPU, compiled Pallas on TPU (serving default)
* ``ref``       — pure-jnp zero-skipping oracle (kernels/ref.py)
* ``pallas``    — compiled Pallas (TPU only)
* ``interpret`` — the Pallas kernel under the interpreter, any backend —
  this is how CI exercises the real kernel body on CPU hosts

The ref path is itself zero-skipping (it contracts the flat live-tile
store only, no densify — see kernels/ref.py), so CPU serving gets the
same work-scales-with-density contract as the TPU kernel.

Both wrappers accept a fused ``Epilogue`` (kernels/epilogue.py): bias,
activation, SwiGLU gate multiply and residual are applied to the fp32
accumulator inside the kernel (or on the ref accumulator before the
final cast) — identical math on every path, no (M, N) intermediate
round-trips.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.packing import BSRPlanes, BSRWeight
from .block_sparse_matmul import bsr_matmul_pallas, bsr_planes_matmul_pallas
from .epilogue import Epilogue, apply_epilogue, make_epilogue
from .paged_attention import (
    paged_attention_decode_pallas,
    paged_attention_decode_ref,
    paged_attention_prefill_pallas,
    paged_attention_prefill_ref,
)
from .structure_norms import structure_norms_pallas
from . import ref as _ref

__all__ = [
    "Epilogue", "apply_epilogue", "make_epilogue",
    "bsr_matmul", "bsr_planes_matmul", "structure_norms", "on_tpu",
    "paged_attention_decode", "paged_attention_prefill",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _use_ref(mode: str) -> bool:
    if mode not in ("auto", "ref", "pallas", "interpret"):
        raise ValueError(f"unknown kernel mode {mode!r}")
    return mode == "ref" or (mode == "auto" and not on_tpu())


@functools.partial(jax.jit, static_argnames=("bm", "mode"))
def bsr_matmul(
    x: jnp.ndarray,
    bsr: BSRWeight,
    *,
    bm: int = 128,
    mode: str = "auto",          # auto | pallas | interpret | ref
    epilogue: Optional[Epilogue] = None,
) -> jnp.ndarray:
    """y = epilogue(x @ W_bsr) for x (..., K); skips pruned tiles on
    every path.  Epilogue operands broadcast over the leading dims of x
    (i.e. multiplier/residual are shaped (..., N) like the output)."""
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    epi = None if epilogue is None else epilogue.map_operands(
        lambda a: a.reshape(-1, a.shape[-1]))
    if _use_ref(mode):
        y = _ref.bsr_matmul_ref(x2, bsr, epilogue=epi)
    else:
        y = bsr_matmul_pallas(
            x2, bsr, bm=bm, epilogue=epi, interpret=(mode == "interpret"),
        )
    return y.reshape(*lead, bsr.shape[1])


@functools.partial(jax.jit, static_argnames=("bm", "mode"))
def bsr_planes_matmul(
    x: jnp.ndarray,              # (E, ..., K)
    planes: BSRPlanes,
    *,
    bm: int = 128,
    mode: str = "auto",
    epilogue: Optional[Epilogue] = None,
) -> jnp.ndarray:
    """Fused gather-free per-plane matmul: y[e] = epilogue(x[e] @ W_bsr[e]).

    One call for the whole plane stack (the MoE expert dimension) —
    no python loop over planes, no per-expert stack.  Epilogue
    multiplier/residual are shaped (E, ..., n) like the output."""
    e = x.shape[0]
    lead = x.shape[1:-1]
    k = x.shape[-1]
    n = planes.shape[-1]
    x3 = x.reshape(e, -1, k)
    epi = None if epilogue is None else epilogue.map_operands(
        lambda a: a.reshape(e, -1, a.shape[-1]))
    if _use_ref(mode):
        y = _ref.bsr_planes_matmul_ref(x3, planes, epilogue=epi)
    else:
        y = bsr_planes_matmul_pallas(
            x3, planes, bm=bm, epilogue=epi, interpret=(mode == "interpret")
        )
    return y.reshape(e, *lead, n)


@functools.partial(jax.jit, static_argnames=("mode", "pages_per_step"))
def paged_attention_decode(
    q: jnp.ndarray,            # (B, H, dh) — rotated query, new token
    k_new: jnp.ndarray,        # (B, K, dh) — rotated K, new token (in-register)
    v_new: jnp.ndarray,        # (B, K, dh)
    k_pool: jnp.ndarray,       # (P, page_size, K, dh) physical pages
    v_pool: jnp.ndarray,
    page_table: jnp.ndarray,   # (B, max_pages) int32 pool ids
    cache_len: jnp.ndarray,    # (B,) int32 — #prior tokens
    *,
    mode: str = "auto",
    pages_per_step: int = 8,   # ref-path segment width (perf only)
) -> jnp.ndarray:
    """Fused paged decode attention: walks ``page_table`` with an online
    softmax, O(cache_len) work/traffic, no logical-view gather.  The new
    token's K/V never round-trips through the pool — it seeds the
    accumulator in-register.  Returns (B, H, dh) fp32."""
    if _use_ref(mode):
        return paged_attention_decode_ref(
            q, k_new, v_new, k_pool, v_pool, page_table, cache_len,
            pages_per_step=pages_per_step)
    return paged_attention_decode_pallas(
        q, k_new, v_new, k_pool, v_pool, page_table, cache_len,
        interpret=(mode == "interpret"))


@functools.partial(
    jax.jit, static_argnames=("bm", "mode", "pages_per_step", "q_offset"))
def paged_attention_prefill(
    q: jnp.ndarray,            # (B, S, H, dh) — rotated, pos [q_offset, q_offset+S)
    k_pool: jnp.ndarray,       # (P, page_size, K, dh) — context K/V already
    v_pool: jnp.ndarray,       #   scattered into the rows' pages
    page_table: jnp.ndarray,   # (B, max_pages) int32
    lengths: jnp.ndarray,      # (B,) int32 per-row TOTAL length (<= q_offset+S)
    *,
    bm: int = 64,              # Pallas query-tile rows
    mode: str = "auto",
    pages_per_step: int = 8,
    q_offset: int = 0,         # static logical position of q row 0
) -> jnp.ndarray:
    """Causal paged prefill attention over the same page walk (bm-tiled
    query blocks in the Pallas kernel).  ``q_offset > 0`` is the
    tail-only prefill of a prefix-cache hit: queries sit at logical
    positions ``[q_offset, q_offset+S)`` and attend over every earlier
    page in the table, including shared prefix pages this request never
    computed (DESIGN.md §12).  Rows past ``lengths`` produce zeros.
    Returns (B, S, H, dh) fp32."""
    if _use_ref(mode):
        return paged_attention_prefill_ref(
            q, k_pool, v_pool, page_table, lengths,
            pages_per_step=pages_per_step, q_offset=q_offset)
    return paged_attention_prefill_pallas(
        q, k_pool, v_pool, page_table, lengths, bm=bm,
        interpret=(mode == "interpret"), q_offset=q_offset)


@functools.partial(jax.jit, static_argnames=("bk", "bn", "mode"))
def structure_norms(
    w: jnp.ndarray, *, bk: int = 128, bn: int = 128, mode: str = "auto"
) -> jnp.ndarray:
    """Tile L2 norms (grid_k, grid_n) fp32 for a (K, N) weight."""
    if _use_ref(mode):
        return _ref.structure_norms_ref(w, bk, bn)
    return structure_norms_pallas(w, bk=bk, bn=bn, interpret=(mode == "interpret"))
