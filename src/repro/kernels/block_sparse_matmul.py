"""Block-sparse (BSR) matmul Pallas TPU kernel — the paper's §III-C codegen.

The paper's HLS generator emits RTL that skips multiplications by pruned
structures.  The TPU equivalent: the grid iterates only over *surviving*
weight tiles; the block-row indices are scalar-prefetched (SMEM) so each
grid step DMAs exactly one live (bk, bn) weight tile and the matching
(bm, bk) activation tile HBM->VMEM.  Pruned tiles cost neither MXU passes
nor HBM traffic — the "DSP and BRAM removal" of the paper, in roofline
terms: compute term x (1 - structure sparsity), memory term likewise.

Layout (from core/packing.py):
    indices (grid_n, max_nnz) int32, -1-padded per block-column
    blocks  (grid_n, max_nnz, bk, bn)

Grid: (m_tiles, grid_n, max_nnz) — output tile (i, j) accumulates over its
column's live tiles; padding slots are skipped with ``pl.when`` (they fetch
block-row 0, a benign redundant DMA bounded by the per-column padding).

MXU alignment: bm, bk, bn should be multiples of (8, 128) sublane/lane
tiles; fp32 accumulation in an output-resident VMEM tile.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "bsr_matmul_kernel", "bsr_matmul_pallas",
    "bsr_planes_matmul_kernel", "bsr_planes_matmul_pallas",
]


def bsr_matmul_kernel(idx_ref, x_ref, w_ref, o_ref):
    """One grid step: o[i, j] += x[i, idx[j, s]] @ w[j, s]."""
    j = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    live = idx_ref[j, s] >= 0

    @pl.when(live)
    def _accum():
        o_ref[...] += jnp.dot(
            x_ref[...], w_ref[0, 0], preferred_element_type=jnp.float32
        )


def bsr_matmul_pallas(
    x: jnp.ndarray,             # (M, K)
    indices: jnp.ndarray,       # (grid_n, max_nnz) int32
    blocks: jnp.ndarray,        # (grid_n, max_nnz, bk, bn)
    *,
    n: int,                     # logical N (<= grid_n * bn)
    bm: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """y = x @ W_bsr, fp32 accumulation, returns (M, n) in x.dtype."""
    m, k = x.shape
    grid_n, max_nnz, bk, bn = blocks.shape
    if k % bk:
        x = jnp.pad(x, ((0, 0), (0, bk * ((k + bk - 1) // bk) - k)))
    bm = min(bm, m)
    pad_m = (-m) % bm
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    m_tiles = x.shape[0] // bm

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m_tiles, grid_n, max_nnz),
        in_specs=[
            pl.BlockSpec(
                (bm, bk), lambda i, j, s, idx: (i, jnp.maximum(idx[j, s], 0))
            ),
            pl.BlockSpec((1, 1, bk, bn), lambda i, j, s, idx: (j, s, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s, idx: (i, j)),
    )
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        )
    out = pl.pallas_call(
        bsr_matmul_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m_tiles * bm, grid_n * bn), jnp.float32),
        interpret=interpret,
        **kwargs,
    )(indices, x, blocks)
    return out[:m, :n].astype(x.dtype)


# ---------------------------------------------------------------------------
# Fused per-plane (expert) BSR matmul
# ---------------------------------------------------------------------------

def bsr_planes_matmul_kernel(idx_ref, x_ref, w_ref, o_ref):
    """One grid step: o[e, i, j] += x[e, i, idx[e, j, s]] @ w[e, j, s].

    Identical math to ``bsr_matmul_kernel`` with a *plane-offset* grid
    dimension in front: plane ``e`` selects which expert's activations,
    indices and blocks the step touches, so the whole per-plane stack is
    one kernel launch instead of a python loop of E launches."""
    e = pl.program_id(1)
    j = pl.program_id(2)
    s = pl.program_id(3)

    @pl.when(s == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    live = idx_ref[e, j, s] >= 0

    @pl.when(live)
    def _accum():
        o_ref[...] += jnp.dot(
            x_ref[0], w_ref[0, 0, 0], preferred_element_type=jnp.float32
        )[None]


def bsr_planes_matmul_pallas(
    x: jnp.ndarray,             # (E, M, K)
    indices: jnp.ndarray,       # (E, grid_n, max_nnz) int32, -1 padded
    blocks: jnp.ndarray,        # (E, grid_n, max_nnz, bk, bn)
    *,
    n: int,                     # logical N (<= grid_n * bn)
    bm: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """y[e] = x[e] @ W_bsr[e] in one fused launch, returns (E, M, n).

    The flattened-planes layout (sparse/transform.BSRPlanes) pads every
    plane's slot dim to the stack-wide ``max_nnz``; the per-plane offset
    into the concatenated (E*grid_n) block-columns is implicit in the
    (e, j) grid coordinates.  Padding slots are skipped with ``pl.when``
    exactly like single-plane padding."""
    e, m, k = x.shape
    _, grid_n, max_nnz, bk, bn = blocks.shape
    if k % bk:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, bk * ((k + bk - 1) // bk) - k)))
    bm = min(bm, m)
    pad_m = (-m) % bm
    if pad_m:
        x = jnp.pad(x, ((0, 0), (0, pad_m), (0, 0)))
    m_tiles = x.shape[1] // bm

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m_tiles, e, grid_n, max_nnz),
        in_specs=[
            pl.BlockSpec(
                (1, bm, bk),
                lambda i, p, j, s, idx: (p, i, jnp.maximum(idx[p, j, s], 0)),
            ),
            pl.BlockSpec(
                (1, 1, 1, bk, bn), lambda i, p, j, s, idx: (p, j, s, 0, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda i, p, j, s, idx: (p, i, j)),
    )
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        )
    out = pl.pallas_call(
        bsr_planes_matmul_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(
            (e, m_tiles * bm, grid_n * bn), jnp.float32),
        interpret=interpret,
        **kwargs,
    )(indices, x, blocks)
    return out[:, :m, :n].astype(x.dtype)
