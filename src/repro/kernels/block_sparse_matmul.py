"""Block-sparse (BSR) matmul Pallas TPU kernels — the paper's §III-C codegen.

The paper's HLS generator emits RTL that skips multiplications by pruned
structures.  The TPU equivalent: the grid iterates only over *surviving*
weight tiles; the per-column block-row indices and flat-store slots are
scalar-prefetched (SMEM) so each grid step DMAs exactly one live (bk, bn)
weight tile and the matching (bm, bk) activation tile HBM->VMEM.  Pruned
tiles cost neither MXU passes nor HBM traffic — the "DSP and BRAM
removal" of the paper, in roofline terms: compute term x (1 - structure
sparsity), memory term likewise.

Layout (from core/packing.py — the flat store + per-column map):
    indices (grid_n, max_nnz) int32, -1-padded per block-column
    slots   (grid_n, max_nnz) int32 into the flat store, 0-padded
    blocks  (nnz, bk, bn) flat store, column-major, single weight copy

Grid: (m_tiles, grid_n, max_nnz) — the ``bm``-tiled leading dimension
covers prefill-shaped (large-M) GEMMs; output tile (i, j) accumulates
over its column's live tiles with the Pallas pipeline double-buffering
the flat-store block DMAs across the innermost nnz loop (each step's
tile prefetches while the previous one multiplies).  Padding slots are
skipped with ``pl.when`` (they fetch flat slot 0, a benign redundant DMA
bounded by the per-column padding).

Epilogue fusion (DESIGN.md §8): bias add, activation, SwiGLU gate
multiply and residual add run on the fp32 accumulator in VMEM at the
last slot step of every output tile — the (M, N) intermediate never
round-trips to HBM.

MXU alignment: bm, bk, bn should be multiples of (8, 128) sublane/lane
tiles; fp32 accumulation in an output-resident VMEM tile.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import BSRPlanes, BSRWeight
from .epilogue import Epilogue

__all__ = [
    "bsr_matmul_kernel", "bsr_matmul_pallas",
    "bsr_planes_matmul_kernel", "bsr_planes_matmul_pallas",
]


def _epi_flags(epi: Optional[Epilogue]):
    if epi is None:
        return False, None, False, False
    return (epi.bias is not None, epi.activation,
            epi.multiplier is not None, epi.residual is not None)


def _fused_tail(y, epi_refs, has_bias, act, has_mult, has_res):
    """The in-VMEM epilogue on the fp32 accumulator tile — static python
    branches, same op order as kernels/epilogue.apply_epilogue."""
    k = 0
    if has_bias:
        y = y + epi_refs[k][...].astype(jnp.float32)
        k += 1
    if act is not None:
        y = getattr(jax.nn, act)(y)
    if has_mult:
        y = y * epi_refs[k][...].astype(jnp.float32)
        k += 1
    if has_res:
        y = y + epi_refs[k][...].astype(jnp.float32)
        k += 1
    return y


def bsr_matmul_kernel(idx_ref, slot_ref, x_ref, w_ref, *rest,
                      nnz_steps, has_bias, act, has_mult, has_res):
    """One grid step: o[i, j] += x[i, idx[j, s]] @ blocks[slot[j, s]],
    with the fused epilogue applied at the column's last slot step."""
    j = pl.program_id(1)
    s = pl.program_id(2)
    o_ref = rest[-1]

    @pl.when(s == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(idx_ref[j, s] >= 0)
    def _accum():
        o_ref[...] += jnp.dot(
            x_ref[...], w_ref[0], preferred_element_type=jnp.float32
        )

    if has_bias or act is not None or has_mult or has_res:
        @pl.when(s == nnz_steps - 1)
        def _epilogue():
            o_ref[...] = _fused_tail(
                o_ref[...], rest[:-1], has_bias, act, has_mult, has_res)


def _pad_mn(a: jnp.ndarray, m_pad: int, n_pad: int) -> jnp.ndarray:
    pm, pn = m_pad - a.shape[0], n_pad - a.shape[1]
    if pm or pn:
        a = jnp.pad(a, ((0, pm), (0, pn)))
    return a


def bsr_matmul_pallas(
    x: jnp.ndarray,             # (M, K)
    bsr: BSRWeight,
    *,
    bm: int = 128,
    epilogue: Optional[Epilogue] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """y = epilogue(x @ W_bsr), fp32 accumulation, returns (M, n) in
    x.dtype.  Epilogue operands (multiplier/residual) are (M, n)."""
    m, k = x.shape
    n = bsr.shape[1]
    grid_n, max_nnz = bsr.indices.shape
    bk, bn = bsr.blocking.bk, bsr.blocking.bn
    if k % bk:
        x = jnp.pad(x, ((0, 0), (0, bk * ((k + bk - 1) // bk) - k)))
    bm = min(bm, m)
    pad_m = (-m) % bm
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    m_tiles = x.shape[0] // bm

    has_bias, act, has_mult, has_res = _epi_flags(epilogue)
    operands = [x, bsr.blocks]
    in_specs = [
        pl.BlockSpec(
            (bm, bk), lambda i, j, s, idx, slt: (i, jnp.maximum(idx[j, s], 0))
        ),
        pl.BlockSpec((1, bk, bn), lambda i, j, s, idx, slt: (slt[j, s], 0, 0)),
    ]
    if has_bias:
        operands.append(_pad_mn(
            epilogue.bias.astype(jnp.float32)[None], 1, grid_n * bn))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, s, idx, slt: (0, j)))
    for operand in (epilogue.multiplier if has_mult else None,
                    epilogue.residual if has_res else None):
        if operand is not None:
            operands.append(_pad_mn(operand, m_tiles * bm, grid_n * bn))
            in_specs.append(
                pl.BlockSpec((bm, bn), lambda i, j, s, idx, slt: (i, j)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(m_tiles, grid_n, max_nnz),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s, idx, slt: (i, j)),
    )
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        )
    kernel = functools.partial(
        bsr_matmul_kernel, nnz_steps=max_nnz, has_bias=has_bias, act=act,
        has_mult=has_mult, has_res=has_res)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m_tiles * bm, grid_n * bn), jnp.float32),
        interpret=interpret,
        **kwargs,
    )(bsr.indices, bsr.slots, *operands)
    return out[:m, :n].astype(x.dtype)


# ---------------------------------------------------------------------------
# Fused per-plane (expert) BSR matmul
# ---------------------------------------------------------------------------

def bsr_planes_matmul_kernel(idx_ref, slot_ref, x_ref, w_ref, *rest,
                             nnz_steps, has_bias, act, has_mult, has_res):
    """One grid step: o[e, i, j] += x[e, i, idx[e, j, s]] @
    blocks[e, slot[e, j, s]].

    Identical math to ``bsr_matmul_kernel`` with a *plane-offset* grid
    dimension in front: plane ``e`` selects which expert's activations,
    index map and flat store the step touches, so the whole per-plane
    stack is one kernel launch instead of a python loop of E launches."""
    e = pl.program_id(1)
    j = pl.program_id(2)
    s = pl.program_id(3)
    o_ref = rest[-1]

    @pl.when(s == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(idx_ref[e, j, s] >= 0)
    def _accum():
        o_ref[...] += jnp.dot(
            x_ref[0], w_ref[0, 0], preferred_element_type=jnp.float32
        )[None]

    if has_bias or act is not None or has_mult or has_res:
        @pl.when(s == nnz_steps - 1)
        def _epilogue():
            o_ref[...] = _fused_tail(
                o_ref[...], rest[:-1], has_bias, act, has_mult, has_res)


def _pad_emn(a: jnp.ndarray, m_pad: int, n_pad: int) -> jnp.ndarray:
    pm, pn = m_pad - a.shape[1], n_pad - a.shape[2]
    if pm or pn:
        a = jnp.pad(a, ((0, 0), (0, pm), (0, pn)))
    return a


def bsr_planes_matmul_pallas(
    x: jnp.ndarray,             # (E, M, K)
    planes: BSRPlanes,
    *,
    bm: int = 128,
    epilogue: Optional[Epilogue] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """y[e] = epilogue(x[e] @ W_bsr[e]) in one fused launch -> (E, M, n).

    Epilogue operands (multiplier/residual) are (E, M, n); bias (n,) is
    broadcast across planes."""
    e, m, k = x.shape
    n = planes.shape[-1]
    _, grid_n, max_nnz = planes.indices.shape
    bk, bn = planes.blocking.bk, planes.blocking.bn
    if k % bk:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, bk * ((k + bk - 1) // bk) - k)))
    bm = min(bm, m)
    pad_m = (-m) % bm
    if pad_m:
        x = jnp.pad(x, ((0, 0), (0, pad_m), (0, 0)))
    m_tiles = x.shape[1] // bm

    has_bias, act, has_mult, has_res = _epi_flags(epilogue)
    operands = [x, planes.blocks]
    in_specs = [
        pl.BlockSpec(
            (1, bm, bk),
            lambda i, p, j, s, idx, slt: (p, i, jnp.maximum(idx[p, j, s], 0)),
        ),
        pl.BlockSpec(
            (1, 1, bk, bn), lambda i, p, j, s, idx, slt: (p, slt[p, j, s], 0, 0)
        ),
    ]
    if has_bias:
        operands.append(_pad_mn(
            epilogue.bias.astype(jnp.float32)[None], 1, grid_n * bn))
        in_specs.append(
            pl.BlockSpec((1, bn), lambda i, p, j, s, idx, slt: (0, j)))
    for operand in (epilogue.multiplier if has_mult else None,
                    epilogue.residual if has_res else None):
        if operand is not None:
            operands.append(_pad_emn(operand, m_tiles * bm, grid_n * bn))
            in_specs.append(pl.BlockSpec(
                (1, bm, bn), lambda i, p, j, s, idx, slt: (p, i, j)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(m_tiles, e, grid_n, max_nnz),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, bm, bn), lambda i, p, j, s, idx, slt: (p, i, j)),
    )
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        )
    kernel = functools.partial(
        bsr_planes_matmul_kernel, nnz_steps=max_nnz, has_bias=has_bias,
        act=act, has_mult=has_mult, has_res=has_res)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(
            (e, m_tiles * bm, grid_n * bn), jnp.float32),
        interpret=interpret,
        **kwargs,
    )(planes.indices, planes.slots, *operands)
    return out[:, :m, :n].astype(x.dtype)
