"""Continuous-batching serving engine over paged KV caches.

The engine owns a fixed number of *decode slots* (rows of the jitted
decode step) and one page pool per attention layer (DESIGN.md §9/§10).
Its host loop interleaves three things per scheduler event:

1. **admission** — the FIFO scheduler hands over requests whose whole
   token budget fits in the pool; each gets a free slot, freshly
   allocated pages, and a *paged prefill-on-join*: one jitted
   ``lm_prefill`` over its (unpadded) prompt whose attention K/V is
   scattered straight into the pages the request owns (no contiguous
   intermediate cache) and whose recurrent states (mamba/xLSTM) are
   written into the slot row.  The first token is the prefill argmax —
   identical to the static hot path in ``launch/serve.py``.

   With **prefix caching** (default on for attention-only stacks,
   DESIGN.md §12) admission first matches the prompt's longest
   page-aligned cached prefix in the ``PrefixIndex``: hit pages are
   *mapped* into the new table (refcount bump, zero prefill compute for
   the hit region) and ``lm_prefill`` runs only on the uncached tail at
   its logical ``start_pos``.  After prefill the request's own full
   prompt blocks are indexed, so identical or prefix-sharing later
   arrivals — including re-admissions after the original retired — skip
   that compute too.  The match is capped one token short of the prompt
   (the tail is never empty), so every position a request ever writes
   (tail prefill + decode) lands in privately allocated pages — COW is
   unreachable on this path, but a refcount guard before every decode
   chunk enforces it (``pool.cow``) as a backstop.
2. **decode** — ONE jitted ``_decode_chunk`` call scans
   ``ticks_per_sync`` decode steps for all slots on device: per-row
   ``cache_len`` masks, per-row page-table reads/writes, per-slot
   *traced* sampling params, per-slot PRNG keys advancing in-scan, and
   per-slot ``done`` masks that freeze EOS'd / budget-exhausted rows
   mid-chunk.  One device->host transfer returns the whole token block
   plus per-row emitted counts — the per-token host sync of PR 4 is
   amortized over the chunk.
3. **retirement** — rows that hit EOS or their budget give their pages
   back to the pool, freeing the slot for the next admission.  Admission
   and retirement only ever happen at chunk boundaries.

**Fault tolerance (DESIGN.md §13).**  Every request walks an explicit
lifecycle (``RequestStatus``) and ends in exactly one terminal state.
The layer adds, at each chunk boundary:

* *backpressure* — the waiting queue is bounded (``max_queue``);
  over-capacity submits are REJECTED instead of queued, with
  queue-depth/reject counters in :attr:`fault_stats`;
* *cancellation* — :meth:`cancel` removes a waiting request immediately
  and aborts an active one at the next chunk boundary, releasing its
  pages refcount-correctly (prefix-index entries survive, active tables
  never leak);
* *deadlines* — ``submit(..., deadline_ticks=N)`` expires a request
  that has not finished by ``arrival + N`` ticks, waiting or active;
* *fault isolation* — a non-finite guard inside the decode chunk
  freezes any row whose logits go NaN/inf at that very tick; the host
  quarantines only that row (FAILED, pages freed and purged from the
  prefix index) while co-batched rows keep streaming bit-identically;
  a ``PrefixIndex.verify()`` self-check each step drops a corrupted
  cache (by its reference ledger — no leaks) and keeps serving;
* *crash consistency* — the host-mirrored slot state is snapshotted
  before each chunk; an exception mid-``step()`` restores the snapshot,
  counts the failure, and degrades to ``ticks_per_sync=1`` so the
  engine stays usable (after ``max_chunk_failures`` consecutive
  failures it gives up loudly).

A seeded :class:`~repro.serving.faults.FaultInjector` can be attached to
drive all of these deterministically (chaos tests, ``serve.py --chaos``).

Because every row's attention is masked to its own ``[0, cache_len)``
and its pages are exclusively owned, a sequence that joins mid-stream
computes exactly what it would compute decoded alone — the token-identity
property ``tests/test_serving_engine.py`` pins down for dense and
packed-BSR params at every ``ticks_per_sync``.  Sampling params are
per-request (``submit(..., temperature=, top_k=, top_p=)`` overriding
the engine defaults) and ride the scan as ``(B,)`` vectors with a
*per-slot* PRNG key seeded from the request id, so sampled streams are
also independent of co-batching.  MoE archs run but route tokens jointly
across the batch, so only dense/attention stacks carry the bit-identity
guarantee.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence, Set

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import runtime as analysis_runtime
from repro.configs.base import ModelConfig
from repro.models import init_caches, layer_specs, lm_decode, lm_prefill
from repro.models.transformer import _select_token_rows

from .pages import NULL_PAGE, PagePool, PrefixIndex
from .scheduler import Request, RequestStatus, Scheduler
from .slo import AdaptiveChunkPolicy, ChunkSignals, percentiles

__all__ = ["ServingEngine"]


@dataclasses.dataclass
class _Slot:
    req: Request
    pages: List[int]
    emitted: List[int]


# Module-level jitted steps with a *static* cfg (ModelConfig is a frozen,
# hashable dataclass): every ServingEngine instance in the process shares
# one compilation cache per (cfg, shapes) — a warm-up engine really warms
# the engine being measured.

@functools.partial(jax.jit, static_argnames=("cfg", "start", "guard"),
                   donate_argnames=("caches",))
def _paged_prefill_step(params, tokens, caches, table, slot, *, cfg,
                        start=0, guard=True):
    """Paged prefill-on-join: one cache-filling pass over a (1, L) prompt
    that writes attention K/V *directly* into the pool pages named by
    ``table`` (1, max_pages) — no contiguous intermediate cache, no
    page-wise copy afterwards.  Recurrent (SSM/xLSTM) layers prefill into
    a scratch single-row cache whose final state lands in row ``slot``
    of the per-slot pool.  ``start > 0`` (static) is the prefix-cache
    tail-only variant: ``tokens`` is the uncached suffix at logical
    positions ``[start, start+L)``, attending over the shared prefix
    pages already mapped into ``table`` (attention-only stacks; the
    engine gates this).  ``guard`` additionally reduces the first-token
    logits to an all-finite flag so admission can quarantine a poisoned
    prefill before it ever occupies a slot.  Returns
    (first_token (1,), ok scalar bool, new caches)."""
    specs = layer_specs(cfg)
    row_caches = init_caches(cfg, 1, tokens.shape[1], jnp.float32)
    pre = [pool if spec.mixer == "attn" else rc
           for spec, pool, rc in zip(specs, caches, row_caches)]
    logits, new = lm_prefill(
        params, pre, {"tokens": tokens, "page_tables": table}, cfg,
        start_pos=start)
    first = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    ok = (jnp.all(jnp.isfinite(logits[:, -1])) if guard
          else jnp.asarray(True))
    out = []
    for spec, pool, nc in zip(specs, caches, new):
        if spec.mixer == "attn":
            out.append(nc)          # pool already holds the prompt pages
        elif nc:
            out.append(jax.tree_util.tree_map(
                lambda P, r: P.at[slot].set(r[0].astype(P.dtype)),
                pool, nc))
        else:
            out.append(pool)
    return first, ok, out


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "ticks", "eos_id", "sampled", "guard"),
    donate_argnames=("caches",))
def _decode_chunk(params, caches, tok, cache_len, tables, rngs,
                  temperature, top_k, top_p, budget_left, *,
                  cfg, ticks, eos_id, sampled, guard):
    """``ticks`` batched decode steps in ONE ``lax.scan`` — the chunk
    between two scheduler events (DESIGN.md §10).

    Per-row ``done`` masks freeze rows mid-chunk the moment they emit
    ``eos_id`` or exhaust ``budget_left``: a frozen row keeps its token,
    ``cache_len`` and rng untouched for the rest of the chunk (its
    lockstep decode output is discarded), so the tokens it *did* emit are
    bit-identical to its solo decode no matter where in a chunk it
    finished.  Sampling params are traced ``(B,)`` vectors — co-batched
    requests keep independent temperature/top-k/top-p — and per-row rngs
    advance in-scan only on live sampled rows.  ``sampled=False`` (a
    static host decision: no live slot has temperature > 0) compiles the
    pure-argmax variant with none of the per-row filter argsorts.  Once
    every row is done the remaining steps skip the decode body via
    ``lax.cond``.

    ``guard=True`` (static) adds the non-finite fault gate (DESIGN.md
    §13): a row whose logits contain NaN/inf at some tick is frozen AT
    that tick exactly like a done row — its poisoned token is never
    emitted, its state stops advancing — and flagged in the returned
    ``bad`` vector so the host can quarantine it.  Other rows are
    untouched: their attention never reads the bad row's pages, so their
    streams stay bit-identical.

    Returns (token block (ticks, B), per-row emitted counts (B,),
    per-row bad flags (B,), last tok (B, 1), cache_len (B,),
    rngs (B, 2), caches) in a single host transfer."""
    b = tok.shape[0]
    done0 = budget_left <= 0          # free slots ride along frozen
    bad0 = jnp.zeros((b,), bool)

    def live_step(operand):
        tok, clen, rngs, done, bad, left, cs = operand
        logits, cs = lm_decode(
            params, cs, {"tokens": tok, "page_tables": tables}, clen, cfg)
        last = logits[:, -1]
        if sampled:
            nxt, rngs2 = _select_token_rows(
                last, rngs, temperature, top_k, top_p)
        else:
            nxt, rngs2 = jnp.argmax(last, axis=-1).astype(jnp.int32), rngs
        live = ~done
        if guard:
            # quarantine gate: a poisoned row freezes at THIS tick —
            # nothing it would have emitted leaves the chunk
            finite = jnp.all(jnp.isfinite(last), axis=-1)
            bad = bad | (live & ~finite)
            live = live & finite
            done = done | bad
        # frozen rows: discard the lockstep output, keep all state.
        # (their page writes land at their frozen cache_len inside their
        # own — or the null — page, attended by nobody.)
        emit = jnp.where(live, nxt, tok[:, 0])
        left = jnp.where(live, left - 1, left)
        done = done | (left <= 0)
        if eos_id is not None:
            done = done | (live & (emit == eos_id))
        clen = jnp.where(live, clen + 1, clen)
        rngs = jnp.where(live[:, None], rngs2, rngs)
        tok = jnp.where(live[:, None], nxt[:, None], tok)
        return (tok, clen, rngs, done, bad, left, cs), (emit, live)

    def step(carry, _):
        return jax.lax.cond(
            jnp.all(carry[3]),
            lambda op: (op, (op[0][:, 0], jnp.zeros((b,), bool))),
            live_step, carry)

    carry0 = (tok, cache_len, rngs, done0, bad0, budget_left, caches)
    (tok, cache_len, rngs, _, bad, _, caches), (toks, lives) = jax.lax.scan(
        step, carry0, None, length=ticks)
    counts = jnp.sum(lives.astype(jnp.int32), axis=0)
    return toks, counts, bad, tok, cache_len, rngs, caches


class ServingEngine:
    """Request-level serving: paged KV pool + continuous batching.

    Parameters
    ----------
    params : dense or BSR-packed model pytree (both serve identically
        through the ``layers.matmul`` dispatch).
    cfg : model config.  Paged caches do not support SWA ring windows or
        encoder-decoder (whisper) stacks.
    num_slots : decode-batch rows; the jitted step shape never changes.
    page_size : tokens per physical KV page.
    max_seq_len : longest prompt+generation budget a request may hold;
        fixes the page-table width.
    num_pages : physical pages per layer pool (page 0 is the null page).
        Defaults to every slot holding a full-length sequence.
    ticks_per_sync : decode steps batched into one on-device chunk
        between scheduler events.  1 reproduces the PR-4 tick-per-sync
        loop; larger chunks amortize the host round-trip at the cost of
        admissions/retirements only happening at chunk boundaries.
    chunk_policy : optional :class:`~repro.serving.slo.AdaptiveChunkPolicy`
        making the chunk length adaptive (DESIGN.md §15): each boundary
        picks the next length from the policy's declared level ladder —
        shrinking toward the next slot-free event when arrived waiters
        exist, shrinking under SLO pressure (close hard deadlines, soft
        ttft/tpot targets), growing back to the top level when calm.
        Signals come from host mirrors only (no extra syncs), the
        policy never changes *what* tokens a stream emits (bit-identity
        holds under every policy), and only ``policy.compile_levels``
        chunk variants ever compile.  When set, ``ticks_per_sync``
        serves only as the degraded-fallback baseline.
    aging_ticks : scheduler anti-starvation knob — queue wait promotes
        a request one priority level per this many ticks (None
        disables aging).  See :class:`~repro.serving.scheduler.Scheduler`.
    temperature / top_k / top_p : engine-wide sampling defaults; each
        request may override them at :meth:`submit`.
    prefix_caching : share page-aligned prompt-prefix KV across requests
        through a content-hash :class:`PrefixIndex` (DESIGN.md §12).
        Auto-disabled for stacks with recurrent mixers — their per-slot
        state cannot be resumed from pages alone.
    max_queue : bound on the waiting queue; a :meth:`submit` past it is
        REJECTED (terminal status, counted in :attr:`fault_stats`)
        instead of growing admission latency without limit.  None =
        unbounded (the pre-§13 behavior).
    nan_guard : compile the non-finite logit gate into the decode chunk
        and prefill (DESIGN.md §13), quarantining poisoned rows as
        FAILED.  Off reproduces the unguarded PR-7 hot path —
        ``bench_serving.py`` measures the guard's overhead against it.
    max_chunk_failures : consecutive decode-chunk exceptions tolerated
        (snapshot-restore + degraded single-tick retry) before the
        engine gives up with a RuntimeError.
    fault_injector : optional
        :class:`~repro.serving.faults.FaultInjector` consulted at the
        chunk-boundary hook points (chaos testing).
    """

    def __init__(
        self,
        params,
        cfg: ModelConfig,
        *,
        num_slots: int = 4,
        page_size: int = 8,
        max_seq_len: int = 64,
        num_pages: Optional[int] = None,
        ticks_per_sync: int = 1,
        chunk_policy: Optional[AdaptiveChunkPolicy] = None,
        aging_ticks: Optional[int] = 32,
        temperature: float = 0.0,
        top_k: Optional[int] = None,
        top_p: Optional[float] = None,
        eos_id: Optional[int] = None,
        seed: int = 0,
        prefix_caching: bool = True,
        max_queue: Optional[int] = None,
        nan_guard: bool = True,
        max_chunk_failures: int = 3,
        fault_injector=None,
    ):
        if cfg.window is not None:
            raise ValueError("paged KV caches do not support SWA windows")
        if cfg.enc_layers:
            raise ValueError("encoder-decoder archs are not paged-servable")
        if ticks_per_sync < 1:
            raise ValueError("ticks_per_sync must be >= 1")
        self.params, self.cfg = params, cfg
        self.num_slots = num_slots
        self.ticks_per_sync = ticks_per_sync
        self.configured_ticks_per_sync = ticks_per_sync
        self.chunk_policy = chunk_policy
        self.max_pages = -(-max_seq_len // page_size)
        if num_pages is None:
            num_pages = num_slots * self.max_pages + 1
        self.pool = PagePool(num_pages, page_size)
        self._specs = layer_specs(cfg)
        attn_only = all(spec.mixer == "attn" for spec in self._specs)
        self.prefix_caching = bool(prefix_caching) and attn_only
        self.prefix_index = (PrefixIndex(self.pool)
                             if self.prefix_caching else None)
        self.scheduler = Scheduler(self.pool, self.prefix_index,
                                   max_queue=max_queue,
                                   aging_ticks=aging_ticks)
        self.temperature, self.top_k, self.top_p = temperature, top_k, top_p
        self.eos_id = eos_id
        self.nan_guard = bool(nan_guard)
        self.max_chunk_failures = max_chunk_failures
        self.injector = fault_injector
        self._base_key = jax.random.PRNGKey(seed)
        # prefix-cache observability (see prefix_stats)
        self.prefix_lookups = 0       # admissions that consulted the index
        self.prefix_hit_requests = 0  # admissions with >= 1 block hit
        self.prefix_pages_shared = 0  # hit pages mapped instead of prefilled
        # fault-tolerance observability (see fault_stats)
        self.rejected = 0             # bounded-queue admission rejects
        self.cancelled = 0            # cancel() honored (waiting or active)
        self.expired = 0              # deadline expiries (waiting or active)
        self.failed = 0               # guard quarantines (prefill or decode)
        self.guard_trips = 0          # non-finite detections by the guard
        self.chunk_failures = 0       # decode-chunk exceptions recovered
        self.alloc_failures = 0       # admission allocs that failed + retried
        self.index_drops = 0          # verify() inconsistencies -> cache drop
        self.queue_high_water = 0     # deepest the waiting queue ever got
        self.degraded = False         # fell back to single-tick chunks
        # SLO / adaptive-chunking observability (see slo_stats)
        self.chunks_by_ticks: Dict[int, int] = {}  # committed chunk lengths
        self.chunk_shrinks = 0        # committed chunk shorter than previous
        self.chunk_grows = 0          # committed chunk longer than previous
        self._last_chunk_ticks: Optional[int] = None
        self.last_chunk_error: Optional[str] = None
        self._consec_chunk_failures = 0
        self._cancel_pending: Set[int] = set()
        self._step_progress = False   # terminal/retry event this step

        # device state: page-pool caches per layer; recurrent mixers keep
        # ordinary per-slot rows (their state is O(1) per sequence)
        kvh, hd = cfg.kv_heads, cfg.head_dim_()
        self.caches = []
        for spec, c in zip(self._specs, init_caches(cfg, num_slots, 1,
                                                    jnp.float32)):
            if spec.mixer == "attn":
                c = {"k": jnp.zeros((num_pages, page_size, kvh, hd),
                                    jnp.float32),
                     "v": jnp.zeros((num_pages, page_size, kvh, hd),
                                    jnp.float32)}
            self.caches.append(c)

        # host-mirrored per-slot state, pushed to device every chunk
        self._tok = np.zeros((num_slots, 1), np.int32)
        self._cache_len = np.zeros((num_slots,), np.int32)
        self._tables = np.full((num_slots, self.max_pages), NULL_PAGE,
                               np.int32)
        self._rngs = np.zeros((num_slots, 2), np.uint32)
        # per-slot sampling params, traced into the chunk as (B,) vectors
        self._temp = np.zeros((num_slots,), np.float32)
        self._topk = np.zeros((num_slots,), np.int32)      # 0: disabled
        self._topp = np.ones((num_slots,), np.float32)     # 1: disabled
        self.slots: List[Optional[_Slot]] = [None] * num_slots
        self.requests: Dict[int, Request] = {}
        self.tick = 0
        self._next_rid = 0
        self.active_slot_ticks = 0
        self.decode_ticks = 0
        # declared host round-trips (analysis_stats / DESIGN.md §14):
        # one "decode_chunk" region per chunk, one "admission" region
        # per admitted request — everything else stays on device
        self.sync_regions: Dict[str, int] = {"admission": 0, "decode_chunk": 0}

    # -- request intake ----------------------------------------------------

    def submit(self, prompt, max_new: int, arrival: int = 0, *,
               temperature: Optional[float] = None,
               top_k: Optional[int] = None,
               top_p: Optional[float] = None,
               deadline_ticks: Optional[int] = None,
               priority: int = 0,
               ttft_target_ticks: Optional[int] = None,
               tpot_target_ticks: Optional[int] = None) -> int:
        """Queue a request and return its rid.  Per-request sampling
        params default to the engine-level settings; pass e.g.
        ``temperature=0.0`` to force a greedy stream inside a sampled
        engine (or vice versa).  ``deadline_ticks`` bounds the request's
        lifetime: unfinished by ``arrival + deadline_ticks`` engine
        ticks, it is EXPIRED (waiting or mid-stream).

        ``priority`` (lower = more urgent, default 0) orders admission
        through the scheduler's aging rule; ``ttft_target_ticks`` /
        ``tpot_target_ticks`` are *soft* SLO targets — the adaptive
        chunk policy steers boundaries to land inside them and
        :meth:`slo_stats` counts the misses, but missing one never
        terminates the request (use ``deadline_ticks`` for that).

        If the bounded waiting queue is full the request is REJECTED —
        terminal immediately, visible via ``engine.requests[rid].status``
        and the ``rejected`` counter — instead of queueing unboundedly;
        callers shed the load rather than hiding it in latency."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if max_new < 1 or prompt.size < 1:
            raise ValueError("need a non-empty prompt and max_new >= 1")
        oob = np.nonzero((prompt < 0) | (prompt >= self.cfg.vocab))[0]
        if oob.size:
            pos = int(oob[0])
            raise ValueError(
                f"prompt token id {int(prompt[pos])} at position {pos} is "
                f"outside [0, {self.cfg.vocab}); out-of-range ids would "
                f"silently gather garbage embedding rows")
        if deadline_ticks is not None and deadline_ticks < 1:
            raise ValueError("deadline_ticks must be >= 1 (or None)")
        if ttft_target_ticks is not None and ttft_target_ticks < 1:
            raise ValueError("ttft_target_ticks must be >= 1 (or None)")
        if tpot_target_ticks is not None and tpot_target_ticks < 1:
            raise ValueError("tpot_target_ticks must be >= 1 (or None)")
        req = Request(rid=self._next_rid, prompt=prompt, max_new=max_new,
                      arrival=arrival, temperature=temperature,
                      top_k=top_k, top_p=top_p,
                      deadline_ticks=deadline_ticks, priority=priority,
                      ttft_target_ticks=ttft_target_ticks,
                      tpot_target_ticks=tpot_target_ticks)
        if self.pool.pages_for(req.budget_tokens) > self.max_pages:
            raise ValueError(
                f"request needs {req.budget_tokens} tokens > "
                f"max_seq_len {self.max_pages * self.pool.page_size}")
        self._next_rid += 1
        self.requests[req.rid] = req
        if self.scheduler.submit(req):
            self.queue_high_water = max(self.queue_high_water,
                                        self.scheduler.pending)
        else:
            self.rejected += 1
        return req.rid

    def cancel(self, rid: int) -> RequestStatus:
        """Cancel a request.  Waiting requests leave the queue
        immediately (CANCELLED, no tokens).  Active requests are marked
        and released at the next chunk boundary — their pages return to
        the pool refcount-correctly (prefix-index entries survive on
        their own references) and the tokens emitted so far are kept.
        Cancelling a terminal request is a no-op.  Returns the request's
        status as of this call (CANCELLED once honored; ACTIVE means the
        cancel is pending the boundary)."""
        req = self.requests.get(rid)
        if req is None:
            raise KeyError(f"unknown request id {rid}")
        if req.terminal:
            return req.status
        waiting = self.scheduler.remove(rid)
        if waiting is not None:
            self.scheduler.finish_waiting(
                waiting, self.tick, RequestStatus.CANCELLED,
                reason="cancelled while queued")
            self.cancelled += 1
            return RequestStatus.CANCELLED
        self._cancel_pending.add(rid)
        return req.status

    def sampling_for(self, req: Request):
        """The effective (temperature, top_k, top_p) a request decodes
        with: its own overrides where set, engine defaults elsewhere.
        (Public so solo-decode verifiers can replicate the stream.)"""
        t = req.temperature if req.temperature is not None else self.temperature
        k = req.top_k if req.top_k is not None else self.top_k
        p = req.top_p if req.top_p is not None else self.top_p
        return (float(t or 0.0), k, p)

    # -- engine loop -------------------------------------------------------

    def _admit(self) -> int:
        free = [i for i, s in enumerate(self.slots) if s is None]
        admitted = self.scheduler.admit(self.tick, len(free))
        # pages promised to this batch's admissions: eviction below must
        # never reclaim a page a sibling's reservation counted on.  (A
        # sibling's hits can only *grow* between here and its own turn —
        # earlier admissions insert fresh blocks — so pinning the match
        # as of now is sufficient.)
        pins: Set[int] = set()
        if self.prefix_index is not None:
            for req in admitted:
                pins.update(self.prefix_index.match(req.prompt))
        count = 0
        for j, req in enumerate(admitted):
            slot = free[0]
            hits: List[int] = []
            if self.prefix_index is not None:
                self.prefix_lookups += 1
                hits = self.prefix_index.match(req.prompt)
            n_hit = len(hits)
            total = self.pool.pages_for(req.budget_tokens)
            need = total - n_hit
            if (self.prefix_index is not None
                    and need > self.pool.free_pages):
                self.prefix_index.evict(need - self.pool.free_pages,
                                        exclude=pins | set(hits))
            try:
                if self.injector is not None:
                    self.injector.on_alloc(self, need)
                fresh = self.pool.alloc_pages(need)
            except RuntimeError:
                # allocator failure (injected or real): nothing of this
                # request is committed yet — requeue it and the rest of
                # the batch in order and retry at a later boundary
                self.alloc_failures += 1
                self._step_progress = True
                self.scheduler.requeue(admitted[j:])
                break
            free.pop(0)
            self.pool.share(hits)                 # map, don't recompute
            pages = hits + fresh
            self._tables[slot] = NULL_PAGE
            self._tables[slot, :total] = pages
            # prefill only the uncached tail; the match is capped one
            # token short of the prompt, so the tail is never empty and
            # every write lands past the shared region
            start = n_hit * self.pool.page_size
            first, ok, self.caches = _paged_prefill_step(
                self.params, jnp.asarray(req.prompt[start:][None]),
                self.caches, jnp.asarray(self._tables[slot][None]),
                jnp.asarray(slot, jnp.int32), cfg=self.cfg, start=start,
                guard=self.nan_guard)
            # ONE declared host round-trip per admission: first token,
            # guard verdict, and the request's decode key in a single
            # batched pull (was three separate syncs)
            with analysis_runtime.sync_region("admission"):
                self.sync_regions["admission"] += 1
                first_np, ok_np, rng_np = jax.device_get(
                    (first, ok,
                     jax.random.fold_in(self._base_key, req.rid)))
            if self.nan_guard and not bool(ok_np):
                # poisoned prefill: quarantine before the request ever
                # holds a slot — its pages (and any cached blocks that
                # fed them) must never be mapped again
                self.guard_trips += 1
                self.failed += 1
                self._step_progress = True
                req.tokens = np.zeros((0,), np.int32)
                if self.prefix_index is not None:
                    self.prefix_index.drop_pages(pages)
                self._tables[slot] = NULL_PAGE
                self.scheduler.retire(
                    req, pages, self.tick, status=RequestStatus.FAILED,
                    reason="non-finite prefill logits (quarantined)")
                free.insert(0, slot)
                continue
            self._cache_len[slot] = req.prompt_len
            tok = int(first_np[0])
            req.first_token_time = time.perf_counter()
            req.prefix_hit_pages = n_hit
            if self.prefix_index is not None:
                self.prefix_index.insert(req.prompt, pages)
                if n_hit:
                    self.prefix_hit_requests += 1
                self.prefix_pages_shared += n_hit
            self._tok[slot, 0] = tok
            self._rngs[slot] = np.asarray(rng_np, np.uint32)
            t, k, p = self.sampling_for(req)
            self._temp[slot] = t
            self._topk[slot] = k if k is not None else 0
            self._topp[slot] = p if p is not None else 1.0
            req.admitted_at = self.tick
            req.status = RequestStatus.ACTIVE
            self.slots[slot] = _Slot(req=req, pages=pages, emitted=[tok])
            count += 1
            self._maybe_finish(slot)
        return count

    def _cow_guard(self, active: List[int], ticks: int) -> None:
        """Enforce copy-on-write before a decode chunk: no row may write
        into a page it does not exclusively own.  The standard admission
        path makes this unreachable (decode always writes into a private
        tail page — see _admit), so any trigger means an external holder
        shared a live tail page; the write target is copied to a fresh
        page and the row's table repointed, never the sharer's data."""
        ps = self.pool.page_size
        for i in active:
            s = self.slots[i]
            lo = int(self._cache_len[i])
            hi = lo + ticks                # write positions this chunk
            for idx in range(lo // ps, (hi - 1) // ps + 1):
                if idx >= self.max_pages:
                    break
                pid = int(self._tables[i, idx])
                if pid == NULL_PAGE or self.pool.refcount(pid) == 1:
                    continue
                if (self.pool.free_pages == 0
                        and self.prefix_index is not None):
                    self.prefix_index.evict(1, exclude=set(s.pages))
                new = self.pool.cow(pid)
                for li, spec in enumerate(self._specs):
                    if spec.mixer != "attn":
                        continue
                    c = self.caches[li]
                    self.caches[li] = {
                        **c,
                        "k": c["k"].at[new].set(c["k"][pid]),
                        "v": c["v"].at[new].set(c["v"][pid]),
                    }
                self._tables[i, idx] = new
                s.pages[s.pages.index(pid)] = new

    # -- lifecycle transitions ---------------------------------------------

    def _release_slot(self, i: int, status: RequestStatus,
                      reason: Optional[str] = None) -> None:
        """Terminal transition for an active slot: record the tokens
        emitted so far, clear the slot's host mirrors (table to the null
        page, sampling params to engine-off defaults) and hand the pages
        back through the scheduler (a refcount decrement under sharing —
        prefix-index entries survive on their own references).  FAILED
        rows additionally purge every index entry touching their pages:
        quarantined K/V must never be mapped into a later table."""
        s = self.slots[i]
        s.req.tokens = np.asarray(s.emitted, np.int32)
        s.req.finished_time = time.perf_counter()
        if status is RequestStatus.FAILED and self.prefix_index is not None:
            self.prefix_index.drop_pages(s.pages)
        self.slots[i] = None
        self._tables[i] = NULL_PAGE
        self._cache_len[i] = 0
        self._tok[i, 0] = 0
        self._temp[i], self._topk[i], self._topp[i] = 0.0, 0, 1.0
        self.scheduler.retire(s.req, s.pages, self.tick, status=status,
                              reason=reason)

    def _maybe_finish(self, slot: int) -> None:
        s = self.slots[slot]
        if s is None:
            return
        if (len(s.emitted) >= s.req.max_new
                or (self.eos_id is not None
                    and s.emitted[-1] == self.eos_id)):
            self._release_slot(slot, RequestStatus.FINISHED)

    def _service_cancels(self) -> None:
        """Honor pending cancels at the chunk boundary (the only point
        where slot state is at rest on the host)."""
        if not self._cancel_pending:
            return
        for i, s in enumerate(self.slots):
            if s is not None and s.req.rid in self._cancel_pending:
                self._cancel_pending.discard(s.req.rid)
                self.cancelled += 1
                self._step_progress = True
                self._release_slot(
                    i, RequestStatus.CANCELLED,
                    reason="cancelled mid-stream at chunk boundary")
        # anything left finished on its own before the boundary: drop
        self._cancel_pending = {
            rid for rid in self._cancel_pending
            if not self.requests[rid].terminal}

    def _service_deadlines(self) -> None:
        """Expire overdue requests: waiting ones leave the queue with no
        tokens; active ones are aborted at this boundary keeping their
        partial stream."""
        for _ in self.scheduler.expire(self.tick):
            self.expired += 1
            self._step_progress = True
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            dl = s.req.deadline
            if dl is not None and self.tick >= dl:
                self.expired += 1
                self._step_progress = True
                self._release_slot(
                    i, RequestStatus.EXPIRED,
                    reason=f"deadline (tick {dl}) passed mid-stream")

    def _verify_index(self) -> None:
        """Prefix-index self-check (DESIGN.md §13): on ANY inconsistency
        drop the whole cache — released by the reference ledger, so the
        pool stays exactly conserved even under entry corruption — and
        keep serving uncached.  Active tables are untouched (their pages
        live on the requests' own references), so in-flight streams stay
        bit-identical; only future admissions lose the shared-prefix
        shortcut until the index repopulates."""
        if self.prefix_index is None:
            return
        issues = self.prefix_index.verify()
        if issues:
            self.prefix_index.clear()
            self.index_drops += 1
            self._step_progress = True

    # -- crash-consistent stepping -----------------------------------------

    def _snapshot(self):
        """Copy of every host-mirrored slot vector, taken after the COW
        guard and before the decode chunk: the restore point that keeps
        engine invariants if the chunk raises mid-``step()``."""
        return (self._tok.copy(), self._cache_len.copy(),
                self._tables.copy(), self._rngs.copy(), self._temp.copy(),
                self._topk.copy(), self._topp.copy())

    def _restore(self, snap) -> None:
        (self._tok, self._cache_len, self._tables, self._rngs,
         self._temp, self._topk, self._topp) = (a.copy() for a in snap)

    def _caches_alive(self) -> bool:
        ok = True

        def chk(x):
            nonlocal ok
            if isinstance(x, jax.Array) and x.is_deleted():
                ok = False
        jax.tree_util.tree_map(chk, self.caches)
        return ok

    def _recover_chunk_failure(self, snap, err: Exception) -> None:
        """A decode chunk raised mid-``step()``: restore the snapshot so
        every host mirror matches the last committed chunk boundary,
        fall back to degraded single-tick chunks, and retry on the next
        step.  Page writes the aborted chunk may have landed sit at
        positions >= each row's (restored) cache_len — attended by
        nobody, overwritten by the retry.  If the failure outlived the
        donated cache buffers, or keeps repeating, the engine is
        unrecoverable and says so loudly."""
        self._restore(snap)
        if not self._caches_alive():
            raise RuntimeError(
                "decode chunk failed after its cache donation was "
                "consumed; engine state is unrecoverable") from err
        self.chunk_failures += 1
        self._consec_chunk_failures += 1
        self._step_progress = True
        self.last_chunk_error = repr(err)
        if not self.degraded:
            self.degraded = True
            self.ticks_per_sync = 1       # smallest replayable unit
        if self._consec_chunk_failures > self.max_chunk_failures:
            raise RuntimeError(
                f"{self._consec_chunk_failures} consecutive decode-chunk "
                f"failures (last: {self.last_chunk_error}); giving up: "
                f"{self._state()}") from err

    # -- adaptive chunk length (DESIGN.md §15) -------------------------------

    def _chunk_signals(self, active: List[int]) -> ChunkSignals:
        """Assemble the chunk policy's inputs from host mirrors only —
        scheduler queue, per-slot emitted counts, request targets.
        Nothing here touches the device, so consulting the policy adds
        zero host syncs (the steady-state sync test still counts exactly
        one declared transfer per chunk)."""
        tick = self.tick
        queue_depth = sum(
            1 for r in self.scheduler.waiting if r.arrival <= tick)
        slack = None
        headroom = None
        for i in active:
            s = self.slots[i]
            left = s.req.max_new - len(s.emitted)
            slack = left if slack is None else min(slack, left)
            dl = s.req.deadline
            if dl is not None:
                h = max(1, dl - tick)
                headroom = h if headroom is None else min(headroom, h)
            tp = s.req.tpot_target_ticks
            if tp is not None:
                # the stream flushes only at boundaries: a chunk longer
                # than the per-token target holds tokens past it
                headroom = tp if headroom is None else min(headroom, tp)
        next_arrival = None
        for r in self.scheduler.waiting:
            if r.arrival > tick:
                d = r.arrival - tick
                next_arrival = (d if next_arrival is None
                                else min(next_arrival, d))
                continue
            if r.ttft_target_ticks is not None:
                h = max(1, r.arrival + r.ttft_target_ticks - tick)
                headroom = h if headroom is None else min(headroom, h)
        return ChunkSignals(tick=tick, queue_depth=queue_depth,
                            free_slots=self.num_slots - len(active),
                            min_active_slack=slack, slo_headroom=headroom,
                            next_arrival_in=next_arrival)

    def _next_ticks(self, active: List[int]) -> int:
        """The next chunk's length.  Fixed ``ticks_per_sync`` without a
        policy (and in degraded mode, where recovery already forced the
        single-tick replayable unit); otherwise the policy's pick for
        the current signals — always a member of its declared
        ``compile_levels``, so the jitted ``_decode_chunk`` variants
        stay a small closed set."""
        if self.chunk_policy is None or self.degraded:
            return self.ticks_per_sync
        return self.chunk_policy.next_ticks(self._chunk_signals(active))

    def _count_chunk(self, ticks: int) -> None:
        """Record a COMMITTED chunk length (aborted chunks are restored,
        not counted) and the shrink/grow transition against the previous
        committed chunk — the bench and the check.sh smoke assert the
        adaptive policy actually exercised both directions."""
        self.chunks_by_ticks[ticks] = self.chunks_by_ticks.get(ticks, 0) + 1
        prev = self._last_chunk_ticks
        if prev is not None:
            if ticks < prev:
                self.chunk_shrinks += 1
            elif ticks > prev:
                self.chunk_grows += 1
        self._last_chunk_ticks = ticks

    def step(self) -> int:
        """One scheduler event: fault/lifecycle servicing, admission,
        then ONE on-device chunk of ``ticks_per_sync`` decode steps
        (or the adaptive policy's pick, see ``_next_ticks``).
        Returns the number of requests admitted this event."""
        self._step_progress = False
        if self.injector is not None:
            self.injector.on_step_start(self)
        self._verify_index()
        self._service_cancels()
        self._service_deadlines()
        admitted = self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            self.tick += 1
            return admitted
        ticks = self._next_ticks(active)
        self._cow_guard(active, ticks)
        left = np.zeros((self.num_slots,), np.int32)
        for i in active:
            left[i] = self.slots[i].req.max_new - len(self.slots[i].emitted)
        snap = self._snapshot()
        try:
            if self.injector is not None:
                self.injector.on_chunk_start(self, active, ticks)
            toks, counts, bad, tok, clen, rngs, caches = _decode_chunk(
                self.params, self.caches, jnp.asarray(self._tok),
                jnp.asarray(self._cache_len), jnp.asarray(self._tables),
                jnp.asarray(self._rngs), jnp.asarray(self._temp),
                jnp.asarray(self._topk), jnp.asarray(self._topp),
                jnp.asarray(left), cfg=self.cfg, ticks=ticks,
                eos_id=self.eos_id, sampled=bool(np.any(self._temp > 0.0)),
                guard=self.nan_guard)
        except Exception as err:
            self._recover_chunk_failure(snap, err)
            self.tick += 1
            return admitted
        self._consec_chunk_failures = 0
        self.caches = caches
        # ONE declared host round-trip per decode chunk: every per-slot
        # output in a single batched pull (device_get returns numpy)
        with analysis_runtime.sync_region("decode_chunk"):
            self.sync_regions["decode_chunk"] += 1
            toks, counts, bad, tok, clen, rngs = jax.device_get(
                (toks, counts, bad, tok, clen, rngs))
        self._tok = np.array(tok)
        self._cache_len = np.array(clen)
        self._rngs = np.array(rngs)
        for i in active:
            self.slots[i].emitted.extend(
                int(t) for t in toks[:int(counts[i]), i])
            if bad[i]:
                self.guard_trips += 1
                self.failed += 1
                self._step_progress = True
                self._release_slot(
                    i, RequestStatus.FAILED,
                    reason="non-finite decode logits (quarantined)")
            else:
                self._maybe_finish(i)
        self.active_slot_ticks += int(counts.sum())
        self.decode_ticks += ticks
        self.tick += ticks
        self._count_chunk(ticks)
        return admitted

    @property
    def prefix_stats(self) -> Dict[str, int]:
        """Prefix-cache counters: lookups / hit requests / pages shared
        (mapped instead of prefilled), blocks currently indexed, COW
        copies served, index evictions, and the refcount high-water mark
        (most tables any single page ever appeared in)."""
        idx = self.prefix_index
        return {
            "enabled": int(self.prefix_caching),
            "lookups": self.prefix_lookups,
            "hit_requests": self.prefix_hit_requests,
            "pages_shared": self.prefix_pages_shared,
            "blocks_indexed": len(idx) if idx is not None else 0,
            "evictions": idx.evictions if idx is not None else 0,
            "cow_copies": self.pool.cow_copies,
            "ref_high_water": self.pool.ref_high_water,
        }

    @property
    def fault_stats(self) -> Dict[str, int]:
        """Fault-tolerance counters (DESIGN.md §13), exposed like
        :attr:`prefix_stats`: queue depth/bound/high-water plus one
        counter per lifecycle/fault event.  ``max_queue`` 0 means
        unbounded."""
        return {
            "nan_guard": int(self.nan_guard),
            "queue_depth": self.scheduler.pending,
            "queue_high_water": self.queue_high_water,
            "max_queue": self.scheduler.max_queue or 0,
            "rejected": self.rejected,
            "cancelled": self.cancelled,
            "expired": self.expired,
            "failed": self.failed,
            "guard_trips": self.guard_trips,
            "chunk_failures": self.chunk_failures,
            "alloc_failures": self.alloc_failures,
            "index_drops": self.index_drops,
            "degraded": int(self.degraded),
        }

    def slo_stats(self) -> Dict[str, object]:
        """SLO / adaptive-chunking observability (DESIGN.md §15),
        exposed like :attr:`prefix_stats` / :attr:`fault_stats`.

        Chunk side: whether a policy is attached, the declared compile
        set of chunk lengths, a histogram of committed chunk lengths,
        and shrink/grow transition counts.  Request side: soft-target
        miss counters plus per-priority-class latency aggregates over
        every terminal request that held a slot — TTFT p50/p99 in ticks
        (admission tick minus arrival; the first token lands at
        admission) and mean ticks-per-token after the first.  Computed
        lazily by scanning ``scheduler.finished`` — nothing here is on
        the hot path."""
        policy = self.chunk_policy
        ttft_miss = tpot_miss = 0
        by_prio: Dict[int, Dict[str, List[float]]] = {}
        for r in self.scheduler.finished:
            ttft_miss += int(r.ttft_missed)
            tpot_miss += int(r.tpot_missed)
            if r.admitted_at is None:
                continue
            cls = by_prio.setdefault(r.priority, {"ttft": [], "tpot": []})
            cls["ttft"].append(float(r.ttft_ticks))
            tpot = r.tpot_ticks
            if tpot is not None:
                cls["tpot"].append(float(tpot))
        classes = {}
        for prio in sorted(by_prio):
            cls = by_prio[prio]
            pct = percentiles(cls["ttft"])
            classes[prio] = {
                "requests": len(cls["ttft"]),
                "ttft_ticks_p50": pct["p50"],
                "ttft_ticks_p99": pct["p99"],
                "tpot_ticks_mean": (float(np.mean(cls["tpot"]))
                                    if cls["tpot"] else 0.0),
            }
        return {
            "adaptive": int(policy is not None),
            "chunk_levels": list(policy.compile_levels) if policy is not None
            else [self.configured_ticks_per_sync],
            "chunks_by_ticks": dict(sorted(self.chunks_by_ticks.items())),
            "chunk_shrinks": self.chunk_shrinks,
            "chunk_grows": self.chunk_grows,
            "aging_ticks": self.scheduler.aging_ticks or 0,
            "ttft_target_misses": ttft_miss,
            "tpot_target_misses": tpot_miss,
            "by_priority": classes,
        }

    def analysis_stats(self) -> Dict[str, object]:
        """Runtime counters backing the static analyzer's dynamic claims
        (DESIGN.md §14), exposed like :attr:`prefix_stats` /
        :attr:`fault_stats`: jit cache sizes for the two hot-path entry
        points (steady state must not grow them), the process-wide
        compile-event count, and this engine's declared host sync
        regions — one ``decode_chunk`` region per chunk, one
        ``admission`` region per admitted request.  Tests snapshot this
        before and after traffic to prove "0 recompiles, <=1 transfer
        per chunk"."""
        return {
            "compile_caches": {
                "_decode_chunk": analysis_runtime.cache_size(_decode_chunk),
                "_paged_prefill_step": analysis_runtime.cache_size(_paged_prefill_step),
            },
            "compile_events": analysis_runtime.compile_events(),
            "sync_regions": dict(self.sync_regions),
        }

    def release_prefix_cache(self) -> int:
        """Drop every cached prefix block (e.g. to fully drain the pool);
        pages still mapped by active requests survive through the
        requests' own references.  Returns entries released."""
        if self.prefix_index is None:
            return 0
        return self.prefix_index.clear()

    def _state(self) -> str:
        """One-line engine state for stall diagnostics."""
        waiting = [(r.rid, r.budget_tokens,
                    self.scheduler.pages_needed(r), r.arrival, r.priority)
                   for r in self.scheduler.waiting]
        active = [(s.req.rid, len(s.emitted), s.req.max_new)
                  for s in self.slots if s is not None]
        return (f"tick={self.tick} "
                f"waiting(rid,budget_tok,pages,arrival,prio)={waiting} "
                f"active(rid,emitted,max_new)={active} "
                f"pool={self.pool.free_pages}/{self.pool.num_pages - 1} "
                f"pages free (page_size={self.pool.page_size}, "
                f"max {self.max_pages} pages/request) "
                f"prefix_cache={self.prefix_stats} "
                f"faults={self.fault_stats}")

    def run(self, max_ticks: int = 100_000) -> Dict[int, Request]:
        """Drive chunks until every submitted request is terminal.
        Returns every terminal request by rid — FINISHED streams plus
        any CANCELLED / EXPIRED / FAILED / REJECTED ones (check
        ``.status``; partial tokens are kept where the request ever held
        a slot)."""
        while self.scheduler.pending or any(s is not None for s in self.slots):
            if self.tick >= max_ticks:
                raise RuntimeError(
                    f"engine stalled after {max_ticks} ticks: {self._state()}")
            # a tick that starts fully idle with a due request and admits
            # nothing can never make progress (no pages will ever free) —
            # unless this step made OTHER progress: a terminal transition
            # (cancel/expire/reject), a transient allocator failure being
            # retried, or a recovered chunk fault
            idle = all(s is None for s in self.slots)
            # priority order means the queue head may not be the earliest
            # arrival — "due" is ANY arrived waiter
            due = any(r.arrival <= self.tick
                      for r in self.scheduler.waiting)
            admitted = self.step()
            if idle and due and not admitted and not self._step_progress:
                head = self.scheduler.effective_head(self.tick)
                avail = self.pool.free_pages
                if self.prefix_index is not None:
                    avail += self.prefix_index.evictable_pages()
                raise RuntimeError(
                    "admission stalled: head request "
                    f"rid={head.rid} needs "
                    f"{self.scheduler.pages_needed(head)} pages "
                    f"({head.budget_tokens} tokens) but the drained pool "
                    f"only has {avail} (incl. evictable cache); "
                    f"{self._state()}")
        return {r.rid: r for r in self.scheduler.finished}

    @property
    def slot_utilization(self) -> float:
        if not self.decode_ticks:
            return 0.0
        return self.active_slot_ticks / (self.decode_ticks * self.num_slots)
