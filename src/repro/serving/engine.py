"""Continuous-batching serving engine over paged KV caches.

The engine owns a fixed number of *decode slots* (rows of the jitted
decode step) and one page pool per attention layer (DESIGN.md §9).  Its
host loop interleaves three things per tick:

1. **admission** — the FIFO scheduler hands over requests whose whole
   token budget fits in the pool; each gets a free slot, freshly
   allocated pages, and a *prefill-on-join*: one jitted ``lm_prefill``
   over its (unpadded) prompt, whose KV is copied page-by-page into the
   pool and whose recurrent states (mamba/xLSTM) are written into the
   slot row.  The first token is the prefill argmax — identical to the
   static hot path in ``launch/serve.py``.
2. **decode** — ONE jitted ``lm_decode`` step for all slots: per-row
   ``cache_len`` masks, per-row page-table reads/writes.  Free slots ride
   along pointing at the null page; their outputs are discarded.
3. **retirement** — rows that hit EOS or their budget give their pages
   back to the pool, freeing the slot for the next admission.

Because every row's attention is masked to its own ``[0, cache_len)``
and its pages are exclusively owned, a sequence that joins mid-stream
computes exactly what it would compute decoded alone — the token-identity
property ``tests/test_serving_engine.py`` pins down for dense and
packed-BSR params.  Sampling (temperature/top-k/top-p) uses a *per-slot*
PRNG key seeded from the request id, so sampled streams are also
independent of co-batching.  MoE archs run but route tokens jointly
across the batch, so only greedy dense/attention stacks carry the
bit-identity guarantee.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import init_caches, layer_specs, lm_decode, lm_prefill
from repro.models.transformer import _select_token

from .pages import NULL_PAGE, PagePool
from .scheduler import Request, Scheduler

__all__ = ["ServingEngine"]


@dataclasses.dataclass
class _Slot:
    req: Request
    pages: List[int]
    emitted: List[int]


# Module-level jitted steps with a *static* cfg (ModelConfig is a frozen,
# hashable dataclass): every ServingEngine instance in the process shares
# one compilation cache per (cfg, shapes) — a warm-up engine really warms
# the engine being measured.

@functools.partial(jax.jit, static_argnames=("cfg",))
def _prefill_step(params, tokens, *, cfg):
    """Prefill-on-join: one cache-filling pass over a (1, L) prompt."""
    caches = init_caches(cfg, 1, tokens.shape[1], jnp.float32)
    logits, caches = lm_prefill(params, caches, {"tokens": tokens}, cfg)
    first = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    return first, caches


@functools.partial(jax.jit, static_argnames=("cfg",),
                   donate_argnames=("caches",))
def _insert_step(caches, row_caches, page_ids, slot, *, cfg):
    """Copy a prefilled single-row cache into the pool: whole KV pages
    for attention layers, slot rows for recurrent (SSM/xLSTM) state."""
    n = page_ids.shape[0]
    out = []
    for spec, pool, rc in zip(layer_specs(cfg), caches, row_caches):
        if spec.mixer == "attn":
            ps = pool["k"].shape[1]
            upd = {}
            for key in ("k", "v"):
                kv = rc[key][0]                             # (L, K, dh)
                pad = n * ps - kv.shape[0]
                kv = jnp.pad(kv, ((0, pad), (0, 0), (0, 0)))
                kv = kv.reshape(n, ps, *kv.shape[1:])
                upd[key] = pool[key].at[page_ids].set(
                    kv.astype(pool[key].dtype))
            out.append(upd)
        elif rc:
            out.append(jax.tree_util.tree_map(
                lambda P, r: P.at[slot].set(r[0].astype(P.dtype)),
                pool, rc))
        else:
            out.append(pool)
    return out


@functools.partial(
    jax.jit, static_argnames=("cfg", "temperature", "top_k", "top_p"),
    donate_argnames=("caches",))
def _decode_step(params, caches, tok, cache_len, tables, rngs, *,
                 cfg, temperature, top_k, top_p):
    """One batched decode tick: per-row cache_len + page-table masks."""
    logits, caches = lm_decode(
        params, caches, {"tokens": tok, "page_tables": tables},
        cache_len, cfg)
    lg = logits[:, -1].astype(jnp.float32)
    if temperature and temperature > 0.0:
        # per-slot keys -> each row's sample stream ignores its co-batch
        # (join-invariant sampling)
        def row(l, k):
            t, k = _select_token(l[None], k, temperature=temperature,
                                 top_k=top_k, top_p=top_p)
            return t[0], k
        nxt, rngs = jax.vmap(row)(lg, rngs)
    else:
        nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    return nxt, caches, rngs


class ServingEngine:
    """Request-level serving: paged KV pool + continuous batching.

    Parameters
    ----------
    params : dense or BSR-packed model pytree (both serve identically
        through the ``layers.matmul`` dispatch).
    cfg : model config.  Paged caches do not support SWA ring windows or
        encoder-decoder (whisper) stacks.
    num_slots : decode-batch rows; the jitted step shape never changes.
    page_size : tokens per physical KV page.
    max_seq_len : longest prompt+generation budget a request may hold;
        fixes the page-table width.
    num_pages : physical pages per layer pool (page 0 is the null page).
        Defaults to every slot holding a full-length sequence.
    """

    def __init__(
        self,
        params,
        cfg: ModelConfig,
        *,
        num_slots: int = 4,
        page_size: int = 8,
        max_seq_len: int = 64,
        num_pages: Optional[int] = None,
        temperature: float = 0.0,
        top_k: Optional[int] = None,
        top_p: Optional[float] = None,
        eos_id: Optional[int] = None,
        seed: int = 0,
    ):
        if cfg.window is not None:
            raise ValueError("paged KV caches do not support SWA windows")
        if cfg.enc_layers:
            raise ValueError("encoder-decoder archs are not paged-servable")
        self.params, self.cfg = params, cfg
        self.num_slots = num_slots
        self.max_pages = -(-max_seq_len // page_size)
        if num_pages is None:
            num_pages = num_slots * self.max_pages + 1
        self.pool = PagePool(num_pages, page_size)
        self.scheduler = Scheduler(self.pool)
        self.temperature, self.top_k, self.top_p = temperature, top_k, top_p
        self.eos_id = eos_id
        self._base_key = jax.random.PRNGKey(seed)
        self._specs = layer_specs(cfg)

        # device state: page-pool caches per layer; recurrent mixers keep
        # ordinary per-slot rows (their state is O(1) per sequence)
        kvh, hd = cfg.kv_heads, cfg.head_dim_()
        self.caches = []
        for spec, c in zip(self._specs, init_caches(cfg, num_slots, 1,
                                                    jnp.float32)):
            if spec.mixer == "attn":
                c = {"k": jnp.zeros((num_pages, page_size, kvh, hd),
                                    jnp.float32),
                     "v": jnp.zeros((num_pages, page_size, kvh, hd),
                                    jnp.float32)}
            self.caches.append(c)

        # host-mirrored per-slot state, pushed to device every tick
        self._tok = np.zeros((num_slots, 1), np.int32)
        self._cache_len = np.zeros((num_slots,), np.int32)
        self._tables = np.full((num_slots, self.max_pages), NULL_PAGE,
                               np.int32)
        self._rngs = np.zeros((num_slots, 2), np.uint32)
        self.slots: List[Optional[_Slot]] = [None] * num_slots
        self.tick = 0
        self._next_rid = 0
        self.active_slot_ticks = 0
        self.decode_ticks = 0

    # -- request intake ----------------------------------------------------

    def submit(self, prompt, max_new: int, arrival: int = 0) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        req = Request(rid=self._next_rid, prompt=prompt, max_new=max_new,
                      arrival=arrival)
        if max_new < 1 or prompt.size < 1:
            raise ValueError("need a non-empty prompt and max_new >= 1")
        if self.pool.pages_for(req.budget_tokens) > self.max_pages:
            raise ValueError(
                f"request needs {req.budget_tokens} tokens > "
                f"max_seq_len {self.max_pages * self.pool.page_size}")
        self._next_rid += 1
        self.scheduler.submit(req)
        return req.rid

    # -- engine loop -------------------------------------------------------

    def _admit(self) -> int:
        free = [i for i, s in enumerate(self.slots) if s is None]
        admitted = self.scheduler.admit(self.tick, len(free))
        for req in admitted:
            slot = free.pop(0)
            pages = self.pool.alloc(req.budget_tokens)
            first, row_caches = _prefill_step(
                self.params, jnp.asarray(req.prompt[None]), cfg=self.cfg)
            self.caches = _insert_step(
                self.caches, row_caches,
                jnp.asarray(pages, jnp.int32), jnp.asarray(slot, jnp.int32),
                cfg=self.cfg)
            self._tables[slot] = NULL_PAGE
            self._tables[slot, :len(pages)] = pages
            self._cache_len[slot] = req.prompt_len
            tok = int(first[0])
            self._tok[slot, 0] = tok
            self._rngs[slot] = np.asarray(
                jax.random.fold_in(self._base_key, req.rid), np.uint32)
            req.admitted_at = self.tick
            self.slots[slot] = _Slot(req=req, pages=pages, emitted=[tok])
            self._maybe_finish(slot)
        return len(admitted)

    def _maybe_finish(self, slot: int) -> None:
        s = self.slots[slot]
        if s is None:
            return
        if (len(s.emitted) >= s.req.max_new
                or (self.eos_id is not None
                    and s.emitted[-1] == self.eos_id)):
            s.req.tokens = np.asarray(s.emitted, np.int32)
            self.slots[slot] = None
            self._tables[slot] = NULL_PAGE
            self._cache_len[slot] = 0
            self._tok[slot, 0] = 0
            self.scheduler.retire(s.req, s.pages, self.tick)

    def step(self) -> int:
        """One engine tick: admit, then one batched decode step.  Returns
        the number of requests admitted this tick."""
        admitted = self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if active:
            nxt, self.caches, rngs = _decode_step(
                self.params, self.caches, jnp.asarray(self._tok),
                jnp.asarray(self._cache_len), jnp.asarray(self._tables),
                jnp.asarray(self._rngs), cfg=self.cfg,
                temperature=self.temperature, top_k=self.top_k,
                top_p=self.top_p)
            nxt = np.asarray(nxt)
            self._rngs = np.array(rngs)   # copy: host mirror stays writable
            for i in active:
                self._cache_len[i] += 1
                self._tok[i, 0] = int(nxt[i])
                self.slots[i].emitted.append(int(nxt[i]))
                self._maybe_finish(i)
            self.active_slot_ticks += len(active)
            self.decode_ticks += 1
        self.tick += 1
        return admitted

    def run(self, max_ticks: int = 100_000) -> Dict[int, Request]:
        """Drive ticks until every submitted request has finished."""
        while self.scheduler.pending or any(s is not None for s in self.slots):
            if self.tick >= max_ticks:
                raise RuntimeError(f"engine stalled after {max_ticks} ticks")
            # a tick that starts fully idle with a due request and admits
            # nothing can never make progress (no pages will ever free)
            idle = all(s is None for s in self.slots)
            due = (self.scheduler.pending
                   and self.scheduler.waiting[0].arrival <= self.tick)
            admitted = self.step()
            if idle and due and not admitted:
                raise RuntimeError(
                    "admission stalled: head request cannot fit "
                    f"({self.scheduler.waiting[0].budget_tokens} tokens) "
                    f"with {self.pool.free_pages} free pages")
        return {r.rid: r for r in self.scheduler.finished}

    @property
    def slot_utilization(self) -> float:
        if not self.decode_ticks:
            return 0.0
        return self.active_slot_ticks / (self.decode_ticks * self.num_slots)
