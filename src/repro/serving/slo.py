"""SLO-aware adaptive chunk scheduling (DESIGN.md §15).

Chunked decode trades admission latency for throughput: the engine only
admits, retires and services lifecycle events at chunk boundaries, so a
fixed ``ticks_per_sync=16`` leaves a freed slot idle for up to 15 ticks
(slot utilization 0.91 -> 0.775, DESIGN.md §10) and makes a waiting
request's time-to-first-token quantize up to the chunk grid.  This
module makes the chunk length a *policy* decided at every boundary from
host-mirrored state alone:

* **queue hot** — arrived waiters exist, so the next slot-free event is
  worth hitting exactly: cap the chunk at the minimum remaining token
  budget over active rows (the earliest tick a slot can free — EOS may
  free one sooner, which only means the boundary lands early);
* **SLO pressure** — an active request's hard ``deadline_ticks`` or a
  soft per-token target (``tpot_target_ticks``) is close, or a waiting
  request's soft ``ttft_target_ticks`` is about to pass: cap the chunk
  at the headroom so the boundary (where expiry/admission happen) lands
  before the target, not a chunk-width after it;
* **scheduled arrival inside the chunk** — arrivals are engine ticks,
  so the queue knows the next one: cap the chunk to land a boundary at
  it (a spanning chunk would strand the newcomer until the far
  boundary even with a slot sitting free);
* **calm** — no waiters, no pressure, no imminent arrival: run the
  largest chunk and amortize the host round-trip.

The cap is rounded DOWN to the policy's declared ``levels`` ladder —
the boundary never overshoots a slot-free event or an SLO edge by more
than the sub-level remainder, at the cost of a few extra host syncs
(geometric levels keep that logarithmic).

**The recompile contract.**  ``_decode_chunk`` takes the chunk length as
a *static* jit argument, so every distinct value is one XLA compile.  A
naive adaptive policy (``ticks = queue_depth`` or any unbounded
function of load) is a compile storm — exactly the hazard the
``recompile-hazard`` lint rule flags for loop-varying statics.  The
policy therefore only ever returns members of the frozen ``levels``
tuple (plus the degraded-mode 1), declared up front via
:attr:`compile_levels` so tests can prove with ``CompileTracker`` that
steady-state traffic compiles at most ``len(compile_levels)`` chunk
variants and zero thereafter.

The policy is deterministic and reads nothing from the device: every
signal in :class:`ChunkSignals` comes from the engine's host mirrors
(scheduler queue, per-slot emitted counts), so consulting it adds no
host sync.  It also cannot affect *what* tokens a request emits — chunk
boundaries only move admission/retirement timing, and the differential
policy-invariance test pins streams bit-identical across every fixed
and adaptive policy.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

__all__ = ["AdaptiveChunkPolicy", "ChunkSignals", "DEFAULT_LEVELS"]

# Geometric ladder: round-down loses at most ~half the cap per step and
# reaching an exact boundary from any cap takes O(log) chunks.
DEFAULT_LEVELS: Tuple[int, ...] = (1, 2, 4, 8, 16)


@dataclasses.dataclass(frozen=True)
class ChunkSignals:
    """Host-side inputs to one chunk-length decision (all tick units).

    ``queue_depth`` counts *arrived* waiters.  ``min_active_slack`` is
    the minimum remaining token budget over active rows — the earliest
    tick a slot is guaranteed to free — or None with no active rows.
    ``slo_headroom`` is the minimum, over every tracked soft target and
    hard deadline, of ticks until it passes (clamped >= 1), or None
    when nothing is close.  ``next_arrival_in`` is the distance to the
    nearest *scheduled* future arrival (arrivals are engine ticks, so
    the host queue knows them) — a chunk spanning it would strand the
    newcomer until the far boundary even with a slot free.
    ``free_slots`` counts idle decode rows: with none free, a future
    arrival cannot admit before a slot frees anyway, so its boundary
    target shifts out to the slot-free event."""
    tick: int
    queue_depth: int
    free_slots: int = 0
    min_active_slack: Optional[int] = None
    slo_headroom: Optional[int] = None
    next_arrival_in: Optional[int] = None


class AdaptiveChunkPolicy:
    """Pick the next decode-chunk length from a frozen level ladder.

    Parameters
    ----------
    levels : ascending tuple of permitted chunk lengths — the DECLARED
        compile set (each level is one ``_decode_chunk`` variant).
    hot_queue : arrived-waiter count at which the queue counts as hot
        and the slack cap engages (default 1: any waiter).

    One policy instance belongs to one engine: it keeps the last
    decision only so the engine can count shrink/grow transitions.
    """

    def __init__(self, levels: Tuple[int, ...] = DEFAULT_LEVELS,
                 hot_queue: int = 1):
        lv = tuple(sorted(set(int(l) for l in levels)))
        if not lv or lv[0] < 1:
            raise ValueError(f"levels must be positive ints, got {levels!r}")
        if hot_queue < 1:
            raise ValueError("hot_queue must be >= 1")
        self.levels = lv
        self.hot_queue = hot_queue

    @property
    def compile_levels(self) -> Tuple[int, ...]:
        """Every chunk length this policy can ever ask for, PLUS the
        degraded-mode single-tick fallback — the full set of static
        ``ticks`` values ``_decode_chunk`` may compile under it."""
        return tuple(sorted(set(self.levels) | {1}))

    def cap(self, sig: ChunkSignals) -> Optional[int]:
        """The boundary-distance cap implied by the signals, or None
        when nothing constrains the chunk (calm)."""
        cap: Optional[int] = None
        if (sig.queue_depth >= self.hot_queue
                and sig.min_active_slack is not None):
            cap = max(1, sig.min_active_slack)
        if sig.next_arrival_in is not None:
            # land a boundary where the newcomer can actually admit:
            # at its arrival with a slot free, else no earlier than the
            # next slot-free event (a boundary at arrival alone would
            # be a wasted sync — nothing could join there)
            a = sig.next_arrival_in
            if sig.free_slots <= 0 and sig.min_active_slack is not None:
                a = max(a, sig.min_active_slack)
            a = max(1, a)
            cap = a if cap is None else min(cap, a)
        if sig.slo_headroom is not None:
            h = max(1, sig.slo_headroom)
            cap = h if cap is None else min(cap, h)
        return cap

    def next_ticks(self, sig: ChunkSignals) -> int:
        """Largest level <= cap (never overshoot a slot-free event or an
        SLO edge), or the top level when calm."""
        cap = self.cap(sig)
        if cap is None:
            return self.levels[-1]
        pick = self.levels[0]
        for l in self.levels:
            if l <= cap:
                pick = l
        return pick

    def __repr__(self) -> str:  # shows up in engine diagnostics
        return (f"AdaptiveChunkPolicy(levels={self.levels}, "
                f"hot_queue={self.hot_queue})")


def percentiles(xs, qs=(50, 99)) -> Dict[str, float]:
    """p50/p99-style summary of a latency sample (empty-safe)."""
    import numpy as np

    if not len(xs):
        return {f"p{q}": 0.0 for q in qs}
    a = np.asarray(xs, np.float64)
    return {f"p{q}": float(np.percentile(a, q)) for q in qs}
