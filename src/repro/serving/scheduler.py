"""Continuous-batching scheduler: FIFO admission gated on slots + pages.

Requests queue in arrival order; at every engine tick the scheduler
admits from the head of the queue while (i) a decode slot is free and
(ii) the page pool can cover the request's *whole* budget —
``prompt_len + max_new`` tokens — up front.  Reserving the full budget
at admission is the eviction-freedom invariant: an admitted sequence can
always run to its last token without preemption, so mid-stream joins are
token-identical to solo decodes (DESIGN.md §9).  Head-of-line blocking
is deliberate — skipping ahead to smaller requests would starve long
prompts under sustained load.

With a :class:`~repro.serving.pages.PrefixIndex` attached, the
accounting runs *under sharing* (DESIGN.md §12): a request's page need
is discounted by its cached-prefix hits (those pages are mapped, not
allocated), and cache-only index pages (refcount 1, pinned by no
same-tick sibling's hits) count as available — the engine evicts them
leaf-first on demand.  The invariant is unchanged: once admitted, every
page a request will ever write is privately owned, so it still runs to
its last token without preemption.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Sequence, Set

import numpy as np

from .pages import PagePool, PrefixIndex

__all__ = ["Request", "Scheduler"]


@dataclasses.dataclass
class Request:
    """One generation request in the stream.

    ``temperature`` / ``top_k`` / ``top_p`` override the engine-level
    sampling defaults for this request alone — co-batched requests keep
    independent sampling because the decode chunk threads them through
    the scan as per-slot ``(B,)`` vectors (DESIGN.md §10).  ``None``
    means "inherit the engine default"."""
    rid: int
    prompt: np.ndarray            # (L,) int32 prompt tokens
    max_new: int                  # generation budget (incl. first token)
    arrival: int = 0              # earliest engine tick it may be admitted
    temperature: Optional[float] = None   # <= 0: greedy argmax
    top_k: Optional[int] = None
    top_p: Optional[float] = None
    # filled by the engine:
    tokens: Optional[np.ndarray] = None   # emitted tokens, set on finish
    admitted_at: Optional[int] = None
    finished_at: Optional[int] = None
    prefix_hit_pages: int = 0             # prefix-cache pages mapped at admit
    first_token_time: Optional[float] = None  # wall clock of first token

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def budget_tokens(self) -> int:
        """Cache slots the request needs end-to-end: the prompt plus every
        generated token except the last (whose KV is written but never
        attended — kept for simplicity)."""
        return self.prompt_len + self.max_new


class Scheduler:
    """FIFO queue + admission policy over a :class:`PagePool`, optionally
    prefix-cache-aware via a :class:`PrefixIndex`."""

    def __init__(self, pool: PagePool, index: Optional[PrefixIndex] = None):
        self.pool = pool
        self.index = index
        self.waiting: Deque[Request] = deque()
        self.finished: List[Request] = []

    def submit(self, req: Request) -> None:
        # keep the queue in (arrival, submit-order) order: an early-arrival
        # request submitted late must not sit behind an unarrived head
        # (admit() only ever pops the head)
        self.waiting.append(req)
        self.waiting = deque(sorted(self.waiting, key=lambda r: r.arrival))

    def pages_needed(self, req: Request) -> int:
        """Private pages the request would need right now: its full
        budget minus the page-aligned prefix blocks already cached."""
        need = self.pool.pages_for(req.budget_tokens)
        if self.index is not None:
            need -= len(self.index.match(req.prompt))
        return need

    def admit(self, tick: int, free_slots: int) -> List[Request]:
        """Pop admissible head-of-queue requests for this tick: arrived,
        a slot free, and the pool able to reserve the full token budget.

        Under prefix caching the budget is discounted by cached-prefix
        hits, and index pages evictable *right now* — refcount 1 and not
        among the hits already promised (``pinned``) to earlier
        admissions of this same tick — count as free.  Hits only ever
        grow between this gate and the engine's allocation (same-tick
        siblings insert fresh blocks; eviction never touches pinned
        pages), so the reservation is a safe upper bound."""
        out: List[Request] = []
        reserved = 0   # pages already committed to this tick's admissions
        pinned: Set[int] = set()
        while self.waiting and free_slots > 0:
            head = self.waiting[0]
            if head.arrival > tick:
                break
            hits: List[int] = []
            if self.index is not None:
                hits = self.index.match(head.prompt)
            need = self.pool.pages_for(head.budget_tokens) - len(hits)
            avail = self.pool.free_pages
            if self.index is not None:
                avail += self.index.evictable_pages(
                    exclude=pinned | set(hits))
            if reserved + need > avail:
                break  # head-of-line blocks until pages free up
            reserved += need
            pinned.update(hits)
            out.append(self.waiting.popleft())
            free_slots -= 1
        return out

    def retire(self, req: Request, pages: Sequence[int], tick: int) -> None:
        """Release the request's references.  Under sharing this is a
        refcount decrement: a page returns to the free list only when no
        other table (and no prefix-index entry) still maps it."""
        req.finished_at = tick
        self.pool.free(pages)
        self.finished.append(req)

    @property
    def pending(self) -> int:
        return len(self.waiting)
