"""Continuous-batching scheduler: priority admission gated on slots + pages.

Requests queue in (priority, arrival) order — all-default priorities
reduce to plain arrival FIFO; at every engine tick the scheduler
admits the arrived waiter with the best aging-adjusted priority while
(i) a decode slot is free and
(ii) the page pool can cover the request's *whole* budget —
``prompt_len + max_new`` tokens — up front.  Reserving the full budget
at admission is the eviction-freedom invariant: an admitted sequence can
always run to its last token without preemption, so mid-stream joins are
token-identical to solo decodes (DESIGN.md §9).  Head-of-line blocking
is deliberate — skipping ahead to smaller requests would starve long
prompts under sustained load.

With a :class:`~repro.serving.pages.PrefixIndex` attached, the
accounting runs *under sharing* (DESIGN.md §12): a request's page need
is discounted by its cached-prefix hits (those pages are mapped, not
allocated), and cache-only index pages (refcount 1, pinned by no
same-tick sibling's hits) count as available — the engine evicts them
leaf-first on demand.  The invariant is unchanged: once admitted, every
page a request will ever write is privately owned, so it still runs to
its last token without preemption.

DESIGN.md §13 adds the request *lifecycle*: every request carries a
:class:`RequestStatus` and ends in exactly one terminal state —

    QUEUED ──admit──> ACTIVE ──────────────> FINISHED (EOS / budget)
      │  │                │ │ │
      │  │                │ │ └─ guard trip ─> FAILED   (quarantined)
      │  │                │ └─── deadline ───> EXPIRED  (partial tokens)
      │  │                └──── cancel() ────> CANCELLED(partial tokens)
      │  ├──── cancel() ─────────────────────> CANCELLED(no tokens)
      │  └──── deadline ─────────────────────> EXPIRED  (no tokens)
      └ submit() over max_queue ─────────────> REJECTED (backpressure)

The waiting queue is *bounded* (``max_queue``): an over-capacity
:meth:`submit` marks the request REJECTED instead of growing the queue
without limit — explicit admission-reject backpressure rather than
unbounded latency.  Queue insertion is an ordered ``bisect.insort`` on
the ``(priority, arrival)`` key (stable within equal keys), replacing
the former re-sort of the whole deque on every submit (O(n²) total
under load).

DESIGN.md §15 adds **priority classes with aging**.  Requests carry a
``priority`` (lower = more urgent, default 0) and optional *soft* SLO
targets (``ttft_target_ticks`` / ``tpot_target_ticks`` — measured and
capped against, never enforced by killing, unlike the hard
``deadline_ticks``).  Admission picks the arrived waiter with the
smallest :meth:`effective_priority` — the static class minus one level
per ``aging_ticks`` of queue wait — with queue position (priority,
arrival, submit order) as the tie-break.  Aging is the anti-starvation
rule: a low-priority request's effective priority drops below any fresh
class after a bounded wait, so sustained high-priority load can delay
it only ``(priority - minimum priority + 1) * aging_ticks`` ticks
before it *is* the effective head.  Head-of-line blocking then applies
to that effective head exactly as it did to the FIFO head: nobody
skips past it just for being smaller, so big requests cannot starve
either.  With every priority equal (the default) the order degenerates
to the PR-8 arrival FIFO bit-for-bit.
"""
from __future__ import annotations

import bisect
import dataclasses
import enum
from typing import List, Optional, Sequence

import numpy as np

from .pages import PagePool, PrefixIndex

__all__ = ["Request", "RequestStatus", "Scheduler", "TERMINAL_STATUSES"]


class RequestStatus(str, enum.Enum):
    """Lifecycle states of a request (DESIGN.md §13).  The five
    right-hand states are terminal; every submitted request reaches
    exactly one of them."""
    QUEUED = "queued"          # waiting for a slot + pages
    ACTIVE = "active"          # holds a decode slot
    FINISHED = "finished"      # EOS or budget exhausted — the happy path
    CANCELLED = "cancelled"    # cancel(rid) honored (chunk boundary if active)
    EXPIRED = "expired"        # deadline passed (waiting or mid-stream)
    FAILED = "failed"          # quarantined by the non-finite guard
    REJECTED = "rejected"      # bounded-queue admission reject (backpressure)


TERMINAL_STATUSES = frozenset({
    RequestStatus.FINISHED, RequestStatus.CANCELLED, RequestStatus.EXPIRED,
    RequestStatus.FAILED, RequestStatus.REJECTED,
})


@dataclasses.dataclass
class Request:
    """One generation request in the stream.

    ``temperature`` / ``top_k`` / ``top_p`` override the engine-level
    sampling defaults for this request alone — co-batched requests keep
    independent sampling because the decode chunk threads them through
    the scan as per-slot ``(B,)`` vectors (DESIGN.md §10).  ``None``
    means "inherit the engine default".

    ``deadline_ticks`` is a per-request latency budget relative to
    ``arrival``: once ``engine.tick`` reaches ``arrival +
    deadline_ticks`` the request is EXPIRED — dropped from the queue if
    still waiting, aborted at the next chunk boundary (keeping the
    tokens emitted so far) if active.

    ``priority`` (lower = more urgent) orders admission;
    ``ttft_target_ticks`` / ``tpot_target_ticks`` are *soft* SLO
    targets (DESIGN.md §15): the adaptive chunk policy shrinks chunks
    to land boundaries inside them and :meth:`ServingEngine.slo_stats`
    counts the misses, but — unlike ``deadline_ticks`` — blowing one
    never terminates the request."""
    rid: int
    prompt: np.ndarray            # (L,) int32 prompt tokens
    max_new: int                  # generation budget (incl. first token)
    arrival: int = 0              # earliest engine tick it may be admitted
    temperature: Optional[float] = None   # <= 0: greedy argmax
    top_k: Optional[int] = None
    top_p: Optional[float] = None
    deadline_ticks: Optional[int] = None  # must FINISH by arrival + this
    priority: int = 0             # admission class; lower = more urgent
    ttft_target_ticks: Optional[int] = None  # soft: admit within this
    tpot_target_ticks: Optional[int] = None  # soft: stream cadence bound
    # filled by the engine:
    status: RequestStatus = RequestStatus.QUEUED
    status_reason: Optional[str] = None   # human-readable terminal cause
    tokens: Optional[np.ndarray] = None   # emitted tokens, set on finish
    admitted_at: Optional[int] = None
    finished_at: Optional[int] = None
    prefix_hit_pages: int = 0             # prefix-cache pages mapped at admit
    first_token_time: Optional[float] = None  # wall clock of first token
    finished_time: Optional[float] = None     # wall clock of terminal event

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def budget_tokens(self) -> int:
        """Cache slots the request needs end-to-end: the prompt plus every
        generated token except the last (whose KV is written but never
        attended — kept for simplicity)."""
        return self.prompt_len + self.max_new

    @property
    def deadline(self) -> Optional[int]:
        """Absolute engine tick this request must finish by, or None."""
        if self.deadline_ticks is None:
            return None
        return self.arrival + self.deadline_ticks

    @property
    def terminal(self) -> bool:
        return self.status in TERMINAL_STATUSES

    @property
    def ttft_ticks(self) -> Optional[int]:
        """Ticks from arrival to first token (prefill argmax lands at
        the admission tick), or None if never admitted."""
        if self.admitted_at is None:
            return None
        return self.admitted_at - self.arrival

    @property
    def tpot_ticks(self) -> Optional[float]:
        """Mean ticks per generated token after the first, or None
        before the request is terminal with tokens."""
        if (self.admitted_at is None or self.finished_at is None
                or self.tokens is None or len(self.tokens) == 0):
            return None
        return ((self.finished_at - self.admitted_at)
                / max(len(self.tokens) - 1, 1))

    @property
    def ttft_missed(self) -> bool:
        """Soft TTFT target blown: admitted later than ``arrival +
        ttft_target_ticks`` — or terminal without ever being admitted
        while a target was set."""
        if self.ttft_target_ticks is None:
            return False
        if self.admitted_at is None:
            return self.terminal
        return self.ttft_ticks > self.ttft_target_ticks

    @property
    def tpot_missed(self) -> bool:
        """Soft per-token target blown on average over the stream."""
        tpot = self.tpot_ticks
        return (self.tpot_target_ticks is not None and tpot is not None
                and tpot > self.tpot_target_ticks)


def _queue_key(r: Request):
    """Static queue order: priority class first, arrival inside it.
    Aging shifts *admission choice* (effective_priority), not storage
    order — the list stays sorted under one immutable key."""
    return (r.priority, r.arrival)


class Scheduler:
    """Priority queue + admission policy over a :class:`PagePool`,
    optionally prefix-cache-aware via a :class:`PrefixIndex` and bounded
    at ``max_queue`` waiting requests (None = unbounded).

    ``aging_ticks`` is the anti-starvation knob (DESIGN.md §15): every
    ``aging_ticks`` of queue wait promotes a request one effective
    priority level at admission time.  None disables aging (static
    classes only — a sustained stream of higher-priority arrivals can
    then starve lower classes; tests pin down that the default cannot).
    With every request at the default priority 0 the whole policy
    reduces to the PR-8 arrival FIFO exactly."""

    def __init__(self, pool: PagePool, index: Optional[PrefixIndex] = None,
                 max_queue: Optional[int] = None,
                 aging_ticks: Optional[int] = 32):
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None for unbounded)")
        if aging_ticks is not None and aging_ticks < 1:
            raise ValueError("aging_ticks must be >= 1 (or None to disable)")
        self.pool = pool
        self.index = index
        self.max_queue = max_queue
        self.aging_ticks = aging_ticks
        self.waiting: List[Request] = []
        self.finished: List[Request] = []      # every TERMINAL request

    def submit(self, req: Request) -> bool:
        """Queue a request, or REJECT it if the bounded queue is full.
        Returns True iff the request was queued.

        The queue is kept in (priority, arrival, submit-order) order —
        the static key admission tie-breaks on.  Ordered insertion via
        ``bisect.insort`` is O(log n) compares + one O(n) list shift per
        submit, replacing the former full re-sort on every call;
        ``insort``'s insert-after-equals keeps equal-key requests in
        submit order, exactly matching the stable sort it replaced."""
        if self.max_queue is not None and len(self.waiting) >= self.max_queue:
            self.finish_waiting(
                req, tick=None, status=RequestStatus.REJECTED,
                reason=f"queue full ({self.max_queue} waiting)")
            return False
        bisect.insort(self.waiting, req, key=_queue_key)
        return True

    def requeue(self, reqs: Sequence[Request]) -> None:
        """Put not-yet-started admissions back (e.g. after an allocator
        failure mid-admission): insort_left places each request *before*
        equal-key waiters, restoring its original queue position;
        inserting in reverse keeps the batch's own relative order."""
        for req in reversed(list(reqs)):
            bisect.insort_left(self.waiting, req, key=_queue_key)

    def remove(self, rid: int) -> Optional[Request]:
        """Pull a waiting request out of the queue (cancel path).
        Returns it, or None if ``rid`` is not waiting."""
        for i, r in enumerate(self.waiting):
            if r.rid == rid:
                return self.waiting.pop(i)
        return None

    def expire(self, tick: int) -> List[Request]:
        """Sweep the queue for requests whose deadline has passed:
        each is removed and marked EXPIRED (terminal, no tokens)."""
        out = []
        keep = []
        for r in self.waiting:
            if r.deadline is not None and tick >= r.deadline:
                self.finish_waiting(
                    r, tick, RequestStatus.EXPIRED,
                    reason=f"deadline {r.deadline} passed while queued")
                out.append(r)
            else:
                keep.append(r)
        if out:
            self.waiting = keep
        return out

    def finish_waiting(self, req: Request, tick: Optional[int],
                        status: RequestStatus, reason: str) -> None:
        """Terminal transition for a request that never held a slot."""
        req.status = status
        req.status_reason = reason
        req.tokens = np.zeros((0,), np.int32)
        req.finished_at = tick
        self.finished.append(req)

    def pages_needed(self, req: Request) -> int:
        """Private pages the request would need right now: its full
        budget minus the page-aligned prefix blocks already cached."""
        need = self.pool.pages_for(req.budget_tokens)
        if self.index is not None:
            need -= len(self.index.match(req.prompt))
        return need

    def effective_priority(self, req: Request, tick: int) -> int:
        """The request's priority as admission sees it *now*: the static
        class minus one level per ``aging_ticks`` of queue wait.  Lower
        wins.  Monotonically non-increasing in wait time, so any waiter
        eventually undercuts every fresh arrival of every class — the
        starvation-freedom argument the property tests replay."""
        if self.aging_ticks is None:
            return req.priority
        return req.priority - max(0, tick - req.arrival) // self.aging_ticks

    def _effective_head_index(self, tick: int) -> Optional[int]:
        best = None
        for i, r in enumerate(self.waiting):
            if r.arrival > tick:
                continue
            key = (self.effective_priority(r, tick), i)
            if best is None or key < best[0]:
                best = (key, i)
        return best[1] if best is not None else None

    def effective_head(self, tick: int) -> Optional[Request]:
        """The arrived waiter admission would consider next: minimum
        (effective_priority, queue position), or None if nothing has
        arrived.  Queue position — the static (priority, arrival,
        submit-order) — is the tie-break, so all-default-priority
        traffic selects exactly the old FIFO head."""
        i = self._effective_head_index(tick)
        return self.waiting[i] if i is not None else None

    def admit(self, tick: int, free_slots: int) -> List[Request]:
        """Pop admissible requests for this tick in effective-priority
        order: arrived, a slot free, and the pool able to reserve the
        full token budget.

        Under prefix caching the budget is discounted by cached-prefix
        hits, and index pages evictable *right now* — refcount 1 and not
        among the hits already promised (``pinned``) to earlier
        admissions of this same tick — count as free.  Hits only ever
        grow between this gate and the engine's allocation (same-tick
        siblings insert fresh blocks; eviction never touches pinned
        pages), so the reservation is a safe upper bound.

        Head-of-line blocking applies to the *effective* head: when the
        most-urgent arrived waiter does not fit, nothing behind it is
        admitted either — skipping ahead to smaller requests would
        starve long prompts, the exact hazard aging exists to rule
        out."""
        out: List[Request] = []
        reserved = 0   # pages already committed to this tick's admissions
        pinned: set = set()
        while self.waiting and free_slots > 0:
            hi = self._effective_head_index(tick)
            if hi is None:
                break
            head = self.waiting[hi]
            hits: List[int] = []
            if self.index is not None:
                hits = self.index.match(head.prompt)
            need = self.pool.pages_for(head.budget_tokens) - len(hits)
            avail = self.pool.free_pages
            if self.index is not None:
                avail += self.index.evictable_pages(
                    exclude=pinned | set(hits))
            if reserved + need > avail:
                break  # effective head-of-line blocks until pages free up
            reserved += need
            pinned.update(hits)
            out.append(self.waiting.pop(hi))
            free_slots -= 1
        return out

    def retire(self, req: Request, pages: Sequence[int], tick: int,
               status: RequestStatus = RequestStatus.FINISHED,
               reason: Optional[str] = None) -> None:
        """Release the request's references and record its terminal
        status.  Under sharing the free is a refcount decrement: a page
        returns to the free list only when no other table (and no
        prefix-index entry) still maps it."""
        req.status = status
        req.status_reason = reason
        req.finished_at = tick
        self.pool.free(pages)
        self.finished.append(req)

    @property
    def pending(self) -> int:
        return len(self.waiting)
