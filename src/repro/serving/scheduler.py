"""Continuous-batching scheduler: FIFO admission gated on slots + pages.

Requests queue in arrival order; at every engine tick the scheduler
admits from the head of the queue while (i) a decode slot is free and
(ii) the page pool can cover the request's *whole* budget —
``prompt_len + max_new`` tokens — up front.  Reserving the full budget
at admission is the eviction-freedom invariant: an admitted sequence can
always run to its last token without preemption, so mid-stream joins are
token-identical to solo decodes (DESIGN.md §9).  Head-of-line blocking
is deliberate — skipping ahead to smaller requests would starve long
prompts under sustained load.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Sequence

import numpy as np

from .pages import PagePool

__all__ = ["Request", "Scheduler"]


@dataclasses.dataclass
class Request:
    """One generation request in the stream.

    ``temperature`` / ``top_k`` / ``top_p`` override the engine-level
    sampling defaults for this request alone — co-batched requests keep
    independent sampling because the decode chunk threads them through
    the scan as per-slot ``(B,)`` vectors (DESIGN.md §10).  ``None``
    means "inherit the engine default"."""
    rid: int
    prompt: np.ndarray            # (L,) int32 prompt tokens
    max_new: int                  # generation budget (incl. first token)
    arrival: int = 0              # earliest engine tick it may be admitted
    temperature: Optional[float] = None   # <= 0: greedy argmax
    top_k: Optional[int] = None
    top_p: Optional[float] = None
    # filled by the engine:
    tokens: Optional[np.ndarray] = None   # emitted tokens, set on finish
    admitted_at: Optional[int] = None
    finished_at: Optional[int] = None

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def budget_tokens(self) -> int:
        """Cache slots the request needs end-to-end: the prompt plus every
        generated token except the last (whose KV is written but never
        attended — kept for simplicity)."""
        return self.prompt_len + self.max_new


class Scheduler:
    """FIFO queue + admission policy over a :class:`PagePool`."""

    def __init__(self, pool: PagePool):
        self.pool = pool
        self.waiting: Deque[Request] = deque()
        self.finished: List[Request] = []

    def submit(self, req: Request) -> None:
        # keep the queue in (arrival, submit-order) order: an early-arrival
        # request submitted late must not sit behind an unarrived head
        # (admit() only ever pops the head)
        self.waiting.append(req)
        self.waiting = deque(sorted(self.waiting, key=lambda r: r.arrival))

    def admit(self, tick: int, free_slots: int) -> List[Request]:
        """Pop admissible head-of-queue requests for this tick: arrived,
        a slot free, and the pool able to reserve the full token budget."""
        out: List[Request] = []
        reserved = 0   # pages already committed to this tick's admissions
        while self.waiting and free_slots > 0:
            head = self.waiting[0]
            if head.arrival > tick:
                break
            need = self.pool.pages_for(head.budget_tokens)
            if reserved + need > self.pool.free_pages:
                break  # head-of-line blocks until pages free up
            reserved += need
            out.append(self.waiting.popleft())
            free_slots -= 1
        return out

    def retire(self, req: Request, pages: Sequence[int], tick: int) -> None:
        req.finished_at = tick
        self.pool.free(pages)
        self.finished.append(req)

    @property
    def pending(self) -> int:
        return len(self.waiting)
