"""Request-level serving: paged KV caches + continuous batching.

See DESIGN.md §9/§10.  The static fixed-batch hot path stays in
``repro.models`` (``lm_prefill`` / ``lm_generate``); this package adds
the orchestration layer for streamed request arrival: a page-pool
allocator, a FIFO admission scheduler, and the engine that scans
``ticks_per_sync`` decode steps on device between scheduler events —
per-row ``cache_len``, page tables and per-slot sampling params all
threaded through ``lm_decode`` inside one ``lax.scan`` chunk.

DESIGN.md §12 adds prefix caching on top: ``PagePool`` refcounts let one
physical page appear in many tables, and ``PrefixIndex`` maps
page-aligned prompt-prefix blocks (chain-hashed token content) onto the
pages that already hold their K/V, so shared prefixes prefill once.

DESIGN.md §13 adds the fault-tolerance layer: an explicit request
lifecycle (``RequestStatus``) with cancellation and deadlines, bounded-
queue backpressure (REJECTED), a non-finite logit guard that quarantines
poisoned rows without touching their co-batched neighbours, prefix-index
self-verification, crash-consistent chunk stepping with degraded-mode
fallback, and a seeded fault-injection harness (``repro.serving.faults``)
to drive all of it deterministically.

DESIGN.md §15 makes the engine SLO-aware: requests carry priority
classes and soft TTFT/TPOT targets, the scheduler ages waiters so no
class starves, and an ``AdaptiveChunkPolicy`` turns ``ticks_per_sync``
into a per-boundary decision over a declared compile set of chunk
lengths — shrink when the queue is hot or a target is close, grow back
when calm — with ``engine.slo_stats()`` reporting per-class latency
distributions.  Token streams stay bit-identical to solo decode under
every policy.
"""
from .engine import ServingEngine
from .faults import (Fault, FaultInjector, InjectedFault, alloc_failure,
                     chunk_exception, index_corruption, nan_logit)
from .pages import NULL_PAGE, PagePool, PrefixIndex
from .scheduler import (Request, RequestStatus, Scheduler,
                        TERMINAL_STATUSES)
from .slo import DEFAULT_LEVELS, AdaptiveChunkPolicy, ChunkSignals

__all__ = ["ServingEngine", "PagePool", "PrefixIndex", "NULL_PAGE",
           "Request", "RequestStatus", "Scheduler", "TERMINAL_STATUSES",
           "Fault", "FaultInjector", "InjectedFault", "nan_logit",
           "alloc_failure", "index_corruption", "chunk_exception",
           "AdaptiveChunkPolicy", "ChunkSignals", "DEFAULT_LEVELS"]
