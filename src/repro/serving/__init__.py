"""Request-level serving: paged KV caches + continuous batching.

See DESIGN.md §9/§10.  The static fixed-batch hot path stays in
``repro.models`` (``lm_prefill`` / ``lm_generate``); this package adds
the orchestration layer for streamed request arrival: a page-pool
allocator, a FIFO admission scheduler, and the engine that scans
``ticks_per_sync`` decode steps on device between scheduler events —
per-row ``cache_len``, page tables and per-slot sampling params all
threaded through ``lm_decode`` inside one ``lax.scan`` chunk.
"""
from .engine import ServingEngine
from .pages import NULL_PAGE, PagePool
from .scheduler import Request, Scheduler

__all__ = ["ServingEngine", "PagePool", "NULL_PAGE", "Request", "Scheduler"]
