"""Request-level serving: paged KV caches + continuous batching.

See DESIGN.md §9.  The static fixed-batch hot path stays in
``repro.models`` (``lm_prefill`` / ``lm_generate``); this package adds
the orchestration layer for streamed request arrival: a page-pool
allocator, a FIFO admission scheduler, and the engine whose decode step
threads per-row ``cache_len`` and page tables through ``lm_decode``.
"""
from .engine import ServingEngine
from .pages import NULL_PAGE, PagePool
from .scheduler import Request, Scheduler

__all__ = ["ServingEngine", "PagePool", "NULL_PAGE", "Request", "Scheduler"]
