"""Paged KV-cache pool: fixed-size pages + per-sequence page tables.

The physical cache for every attention layer is one pool array
``(num_pages, page_size, kv_heads, head_dim)`` shared by all sequences;
a sequence owns an ordered list of page ids (its *page table*) and its
logical positions ``[0, cache_len)`` live at
``pool[table[t // page_size], t % page_size]``.  The pool is the device
side; ``PagePool`` here is the host-side allocator that hands pages to
sequences as they join and reclaims them as they finish (DESIGN.md §9).

Page id 0 is reserved as the *null page*: free decode slots point their
whole table at it, so their (discarded) decode writes land in a scratch
page instead of corrupting a live sequence.
"""
from __future__ import annotations

from typing import List, Sequence

__all__ = ["NULL_PAGE", "PagePool"]

NULL_PAGE = 0


class PagePool:
    """Free-list allocator over ``num_pages`` fixed-size pages.

    Pages are recycled LIFO — a page freed by a finished sequence is the
    next one handed out, keeping the working set of the physical pool as
    small as the live traffic allows.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the null page)")
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.num_pages = num_pages
        self.page_size = page_size
        # LIFO free list; page 0 (null) is never handed out
        self._free: List[int] = list(range(num_pages - 1, 0, -1))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    def pages_for(self, num_tokens: int) -> int:
        """Pages needed to hold ``num_tokens`` cache slots."""
        return max(1, -(-num_tokens // self.page_size))

    def can_alloc(self, num_tokens: int) -> bool:
        return self.pages_for(num_tokens) <= len(self._free)

    def alloc(self, num_tokens: int) -> List[int]:
        """Claim pages for ``num_tokens`` slots; raises if the pool can't
        cover the request (callers gate on :meth:`can_alloc` first)."""
        n = self.pages_for(num_tokens)
        if n > len(self._free):
            raise RuntimeError(
                f"page pool exhausted: need {n}, have {len(self._free)}")
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: Sequence[int]) -> None:
        for pid in pages:
            if pid == NULL_PAGE:
                raise ValueError("cannot free the null page")
            if pid in self._free or not (0 < pid < self.num_pages):
                raise ValueError(f"double/invalid free of page {pid}")
            self._free.append(pid)
