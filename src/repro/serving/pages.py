"""Paged KV-cache pool: fixed-size pages, per-sequence page tables,
ref-counted sharing, and a content-hash prefix index.

The physical cache for every attention layer is one pool array
``(num_pages, page_size, kv_heads, head_dim)`` shared by all sequences;
a sequence owns an ordered list of page ids (its *page table*) and its
logical positions ``[0, cache_len)`` live at
``pool[table[t // page_size], t % page_size]``.  The pool is the device
side; ``PagePool`` here is the host-side allocator that hands pages to
sequences as they join and reclaims them as they finish (DESIGN.md §9).

Since PR 6 attention *walks* page tables without ever materializing a
logical view, so the same physical page may appear in many tables for
free.  ``PagePool`` therefore keeps a per-page reference count:
:meth:`alloc` hands out pages at refcount 1, :meth:`share` maps an
existing page into another table, and :meth:`free` releases one
reference — the page returns to the free list only when the last holder
drops it.  :meth:`cow` implements copy-on-write claims for writers that
do not exclusively own a page.  ``PrefixIndex`` builds the sharing
policy on top: a chain-hash index over page-aligned full prompt blocks
so N requests with a common prefix prefill it once (DESIGN.md §12).

Page id 0 is reserved as the *null page*: free decode slots point their
whole table at it, so their (discarded) decode writes land in a scratch
page instead of corrupting a live sequence.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

__all__ = ["NULL_PAGE", "PagePool", "PrefixIndex"]

NULL_PAGE = 0


class PagePool:
    """Free-list allocator over ``num_pages`` fixed-size pages with
    per-page reference counts.

    Pages are recycled LIFO — a page freed by a finished sequence is the
    next one handed out, keeping the working set of the physical pool as
    small as the live traffic allows.  Conservation invariant (checked
    by tests/test_page_pool_props.py every trace step):

        free_pages + #{pages with refcount > 0} == num_pages - 1
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the null page)")
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.num_pages = num_pages
        self.page_size = page_size
        # LIFO free list; page 0 (null) is never handed out
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._ref: List[int] = [0] * num_pages
        self.ref_high_water = 0   # max refcount any page ever reached
        self.cow_copies = 0       # copy-on-write page claims served

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    def refcount(self, pid: int) -> int:
        return self._ref[pid]

    def live_refs(self) -> int:
        """Total outstanding references (a shared page counts once per
        table it appears in)."""
        return sum(self._ref)

    def pages_for(self, num_tokens: int) -> int:
        """Pages needed to hold ``num_tokens`` cache slots."""
        return max(1, -(-num_tokens // self.page_size))

    def can_alloc(self, num_tokens: int) -> bool:
        return self.pages_for(num_tokens) <= len(self._free)

    def alloc(self, num_tokens: int) -> List[int]:
        """Claim pages for ``num_tokens`` slots; raises if the pool can't
        cover the request (callers gate on :meth:`can_alloc` first)."""
        return self.alloc_pages(self.pages_for(num_tokens))

    def alloc_pages(self, n: int) -> List[int]:
        """Claim ``n`` fresh pages, each at refcount 1."""
        if n > len(self._free):
            raise RuntimeError(
                f"page pool exhausted: need {n}, have {len(self._free)}")
        out = [self._free.pop() for _ in range(n)]
        for pid in out:
            self._ref[pid] = 1
        if out and self.ref_high_water < 1:
            self.ref_high_water = 1
        return out

    def share(self, pages: Sequence[int]) -> None:
        """Add one reference per page — the caller is mapping an already
        live page into another table (prefix-cache hit, index insert)."""
        for pid in pages:
            self._check_live(pid, "share")
            self._ref[pid] += 1
            if self._ref[pid] > self.ref_high_water:
                self.ref_high_water = self._ref[pid]

    def free(self, pages: Sequence[int]) -> None:
        """Release one reference per page; a page returns to the free
        list only when its last reference drops (refcount hits 0)."""
        for pid in pages:
            self._check_live(pid, "free")
            self._ref[pid] -= 1
            if self._ref[pid] == 0:
                self._free.append(pid)

    def cow(self, pid: int) -> int:
        """Copy-on-write claim: return a page id the caller may write.

        Exclusively owned pages (refcount 1) are returned as-is — no
        copy needed.  Shared pages transfer the caller's reference to a
        fresh page (old page refcount -1, new page refcount 1); the
        caller must copy the device contents and repoint its table.
        """
        self._check_live(pid, "cow")
        if self._ref[pid] == 1:
            return pid
        new = self.alloc_pages(1)[0]
        self._ref[pid] -= 1
        self.cow_copies += 1
        return new

    def _check_live(self, pid: int, op: str) -> None:
        if pid == NULL_PAGE:
            raise ValueError(f"cannot {op} the null page")
        if not (0 < pid < self.num_pages):
            raise ValueError(f"{op} of invalid page {pid}")
        if self._ref[pid] <= 0:
            raise ValueError(f"{op} of unreferenced page {pid} "
                             "(double free?)")


@dataclasses.dataclass
class _IndexEntry:
    page: int                 # physical page holding this block's K/V
    parent: Optional[int]     # chain key of the previous block (None = root)
    children: int = 0         # cached continuations (leaf iff 0)


class PrefixIndex:
    """Content-hash index over page-aligned full prompt-prefix blocks.

    The key of block ``i`` is a *chain* hash — ``hash((key_{i-1},
    tokens[i·ps:(i+1)·ps]))`` — so a block can only match behind its
    exact full prefix; equal page content at different positions never
    aliases.  Each entry holds ONE pool reference on its page, taken at
    :meth:`insert`: cached K/V survives the request that computed it
    (retire → readmit reuse) until evicted.

    Eviction is leaf-first LRU: only entries with no cached continuation
    (``children == 0``) and no other reference holder (refcount 1) may
    drop, so chains stay contiguous from the root and a page is never
    reclaimed while any table still maps it.  Active sharers always pin
    ancestors before descendants (matching is prefix-contiguous), so the
    evictable entries form whole subtrees and :meth:`evictable_pages` is
    exactly what leaf-first eviction can realize.

    **Fault tolerance (DESIGN.md §13).**  Alongside the entries the
    index keeps ``_owned`` — a ledger of the pool references it has
    taken, keyed by page id.  Entries are the *lookup* structure (and
    may be corrupted by bugs or bit flips); the ledger is the
    *accounting* ground truth, mutated only at ref-take/ref-release.
    :meth:`verify` cross-checks the two (plus chain links, children
    counts, and pool refcounts) and :meth:`clear` releases by ledger —
    so a corrupted index can always be dropped without leaking or
    double-freeing a single page, and the engine keeps serving without
    the cache instead of handing poisoned page ids to new tables.
    :meth:`drop_pages` quarantines entries touching a failed request's
    pages (plus their descendant chains) the same way.
    """

    def __init__(self, pool: PagePool):
        self.pool = pool
        self._entries: "OrderedDict[int, _IndexEntry]" = OrderedDict()
        self._owned: Dict[int, int] = {}     # page -> refs this index holds
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    @staticmethod
    def _chain_key(parent: Optional[int], block: np.ndarray) -> int:
        return hash((parent, np.ascontiguousarray(block, np.int32).tobytes()))

    def match(self, prompt: np.ndarray) -> List[int]:
        """Pages of the longest cached page-aligned *proper* prefix of
        ``prompt``, capped at ``(len-1) // page_size`` blocks so the
        uncached tail is never empty — prefill must still run at least
        one token to produce the first-token logits, and every position
        the request will ever write (tail + decode) stays past the
        shared region, which is what makes COW unreachable on the
        standard path (DESIGN.md §12).  Hit entries are touched MRU."""
        prompt = np.asarray(prompt).reshape(-1)
        ps = self.pool.page_size
        out: List[int] = []
        keys: List[int] = []
        key: Optional[int] = None
        for i in range((len(prompt) - 1) // ps):
            key = self._chain_key(key, prompt[i * ps:(i + 1) * ps])
            entry = self._entries.get(key)
            if entry is None:
                break
            out.append(entry.page)
            keys.append(key)
        for k in keys:
            self._entries.move_to_end(k)
        return out

    def insert(self, prompt: np.ndarray, pages: Sequence[int]) -> int:
        """Register every full page-aligned block of ``prompt`` (block
        ``i`` lives in ``pages[i]`` of the request's table), taking one
        pool reference per newly indexed page.  Blocks already indexed
        (the request's own hits, or a same-content sibling) are touched
        MRU and skipped.  Returns the number of new entries."""
        prompt = np.asarray(prompt).reshape(-1)
        ps = self.pool.page_size
        key: Optional[int] = None
        new = 0
        for i in range(len(prompt) // ps):
            parent = key
            key = self._chain_key(key, prompt[i * ps:(i + 1) * ps])
            if key in self._entries:
                self._entries.move_to_end(key)
                continue
            self._take(int(pages[i]))
            self._entries[key] = _IndexEntry(page=int(pages[i]), parent=parent)
            pe = self._entries.get(parent) if parent is not None else None
            if pe is not None:
                pe.children += 1
            new += 1
        return new

    def evictable_pages(self, exclude: Iterable[int] = ()) -> int:
        """Pages the index could return to the pool right now: indexed
        pages nobody else holds (refcount 1) and not pinned by
        ``exclude`` (pages promised to this tick's other admissions)."""
        ex = set(exclude)
        return sum(1 for e in self._entries.values()
                   if self.pool.refcount(e.page) == 1 and e.page not in ex)

    def evict(self, n_pages: int, exclude: Iterable[int] = ()) -> int:
        """Drop LRU leaf entries until ``n_pages`` pages returned to the
        free list (or nothing evictable remains).  Returns pages freed."""
        ex = set(exclude)
        freed = 0
        while freed < n_pages:
            victim = None
            for k, e in self._entries.items():       # OrderedDict: LRU first
                if (e.children == 0 and e.page not in ex
                        and self.pool.refcount(e.page) == 1):
                    victim = k
                    break
            if victim is None:
                break
            entry = self._entries.pop(victim)
            if entry.parent is not None:
                pe = self._entries.get(entry.parent)
                if pe is not None:
                    pe.children -= 1
            self._release(entry.page)
            self.evictions += 1
            freed += 1
        return freed

    # -- reference ledger (fault-tolerant accounting) ----------------------

    def _take(self, page: int) -> None:
        self.pool.share([page])
        self._owned[page] = self._owned.get(page, 0) + 1

    def _release(self, page: int) -> None:
        """Release one index reference *if the ledger holds one* — the
        ledger, not the (possibly corrupted) entry field, decides what
        may be freed, so a scrambled entry can never double-free."""
        if self._owned.get(page, 0) > 0:
            self._owned[page] -= 1
            if not self._owned[page]:
                del self._owned[page]
            self.pool.free([page])

    def verify(self) -> List[str]:
        """Self-check: cross-validate the lookup entries against the
        reference ledger and the pool.  Returns a list of inconsistency
        descriptions (empty == healthy).  Checked invariants:

        * every entry's page is a valid, non-null, live (refcount >= 1)
          pool page,
        * the multiset of entry pages equals the ledger exactly (one
          entry per owned reference — no orphan refs, no unref'd entry),
        * every non-root parent link resolves to an existing entry,
        * stored ``children`` counts match the actual link structure.

        The engine runs this each step; on any report it drops the whole
        cache via :meth:`clear` (ledger-exact, so no page leaks) and
        keeps serving uncached rather than mapping poisoned pages into
        new tables."""
        issues: List[str] = []
        counts: Dict[int, int] = {}
        actual_children: Dict[int, int] = {}
        for e in self._entries.values():
            if e.parent is not None:
                actual_children[e.parent] = \
                    actual_children.get(e.parent, 0) + 1
        for key, e in self._entries.items():
            counts[e.page] = counts.get(e.page, 0) + 1
            if not (0 < e.page < self.pool.num_pages):
                issues.append(f"entry {key}: invalid page id {e.page}")
            elif self.pool.refcount(e.page) < 1:
                issues.append(f"entry {key}: page {e.page} is unreferenced")
            if e.parent is not None and e.parent not in self._entries:
                issues.append(f"entry {key}: dangling parent link")
            want = actual_children.get(key, 0)
            if e.children != want:
                issues.append(f"entry {key}: children count {e.children} "
                              f"!= actual {want}")
        if counts != self._owned:
            extra = {p: c for p, c in counts.items()
                     if self._owned.get(p, 0) != c}
            missing = {p: c for p, c in self._owned.items()
                       if counts.get(p, 0) != c}
            issues.append(f"entry pages diverge from owned-ref ledger "
                          f"(entries {extra} vs ledger {missing})")
        return issues

    def drop_pages(self, pages: Iterable[int]) -> int:
        """Quarantine: remove every entry whose page is in ``pages`` —
        plus all descendant entries, so chains stay contiguous from the
        root — releasing their ledger references.  Used when a request
        FAILS the non-finite guard: its pages' cached K/V is suspect and
        must never be mapped into a later table.  Returns entries
        dropped."""
        targets = {int(p) for p in pages}
        doomed = {k for k, e in self._entries.items() if e.page in targets}
        grew = True
        while grew:          # descendants of doomed entries go too
            grew = False
            for k, e in self._entries.items():
                if k not in doomed and e.parent in doomed:
                    doomed.add(k)
                    grew = True
        for k in doomed:
            e = self._entries.pop(k)
            pe = self._entries.get(e.parent) if e.parent is not None else None
            if pe is not None:
                pe.children -= 1
            self._release(e.page)
        return len(doomed)

    def clear(self) -> int:
        """Release every index reference (pages still mapped by active
        requests stay alive through the requests' own refs).  Returns
        the number of entries dropped.  Frees by the *ledger*, not the
        entries, so it is safe to call on a corrupted index — exactly
        the references taken are returned, never more or less."""
        n = len(self._entries)
        for page, cnt in list(self._owned.items()):
            self.pool.free([page] * cnt)
        self._owned.clear()
        self._entries.clear()
        return n

    def stats(self) -> Dict[str, int]:
        return {"entries": len(self._entries), "evictions": self.evictions}
