"""Deterministic fault injection for the serving engine (DESIGN.md §13).

Chaos testing for the continuous-batching stack: a :class:`FaultInjector`
carries a seeded plan of :class:`Fault` events and is consulted by the
engine at its natural hook points — step start, page allocation, chunk
start.  Every fault is injected through the engine's *public surface*
(device cache contents, the allocator call, the prefix-index entries, an
exception at the chunk boundary), never by monkey-patching internals, so
the recovery paths exercised are exactly the ones production traffic
would hit.  Fault kinds:

``nan_logit``
    Poison the K/V page holding the target request's last attended
    position with NaN before a decode chunk — its next logits go
    non-finite and the engine's guard must quarantine ONLY that row
    (status FAILED, pages freed and purged from the prefix index) while
    co-batched rows keep streaming bit-identically.  Prefers a
    refcount-1 (privately owned) page so the blast radius is exactly
    one request; fires only once the target is actually active.

``alloc_fail``
    The next ``count`` page allocations at admission raise
    :class:`InjectedFault` — modeling transient allocator failure.  The
    engine must unwind the half-admitted batch (no leaked refs), requeue
    it in order, and admit it cleanly on a later tick.

``index_corrupt``
    Scramble one prefix-index entry's page field (seeded choice) just
    before the engine's own ``verify()`` pass — the self-check must
    detect the inconsistency and drop the cache via the reference
    ledger (no leak, no double-free) instead of mapping a poisoned page
    into a new table.  Defers until the index actually has entries.

``chunk_exception``
    Raise :class:`InjectedFault` at the decode-chunk boundary — modeling
    a crash mid-``step()``.  The engine must restore its snapshot, stay
    usable, and fall back to degraded single-tick chunks.

Fire order within a plan is deterministic (sorted by tick, stable), the
corruption choice is seeded, and every fired fault is appended to
``injector.fired`` so tests and the ``serve.py --chaos`` smoke can
assert exactly what happened.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "Fault", "FaultInjector", "InjectedFault",
    "nan_logit", "alloc_failure", "index_corruption", "chunk_exception",
]


class InjectedFault(RuntimeError):
    """An injector-raised failure standing in for a real crash."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One planned fault.  ``tick`` is the earliest engine tick (chunk
    boundary) at which it may fire; some kinds defer further until their
    precondition holds (see module docstring)."""
    kind: str                  # nan_logit | alloc_fail | index_corrupt
    #                          # | chunk_exception
    tick: int
    rid: Optional[int] = None  # nan_logit: target request (None = any active)
    count: int = 1             # alloc_fail: allocations to fail

    def __post_init__(self):
        kinds = ("nan_logit", "alloc_fail", "index_corrupt",
                 "chunk_exception")
        if self.kind not in kinds:
            raise ValueError(f"unknown fault kind {self.kind!r}")


def nan_logit(tick: int, rid: Optional[int] = None) -> Fault:
    return Fault("nan_logit", tick, rid=rid)


def alloc_failure(tick: int, count: int = 1) -> Fault:
    return Fault("alloc_fail", tick, count=count)


def index_corruption(tick: int) -> Fault:
    return Fault("index_corrupt", tick)


def chunk_exception(tick: int) -> Fault:
    return Fault("chunk_exception", tick)


class FaultInjector:
    """Seeded, deterministic fault plan the engine consults at its hook
    points.  ``fired`` logs every injected event as ``(kind, tick,
    detail)`` tuples; ``pending`` lists what has not fired yet."""

    def __init__(self, faults: Sequence[Fault], seed: int = 0):
        self._pending: List[Fault] = sorted(faults, key=lambda f: f.tick)
        self._rng = np.random.default_rng(seed)
        self._alloc_budget = 0          # admissions still to fail
        self.fired: List[Tuple[str, int, Any]] = []

    @property
    def pending(self) -> List[Fault]:
        return list(self._pending)

    def exhausted(self) -> bool:
        return not self._pending and self._alloc_budget == 0

    def _due(self, engine, kind: str) -> List[Fault]:
        due = [f for f in self._pending
               if f.kind == kind and engine.tick >= f.tick]
        for f in due:
            self._pending.remove(f)
        return due

    # -- engine hooks ------------------------------------------------------

    def on_step_start(self, engine) -> None:
        """Chunk-boundary hook, called before the engine's own index
        verify pass — so an injected corruption must be caught by the
        self-check in the very same step."""
        for f in self._due(engine, "index_corrupt"):
            if not self._corrupt_index(engine):
                self._pending.append(f)      # no entries yet: defer

    def on_alloc(self, engine, need: int) -> None:
        """Called immediately before ``pool.alloc_pages`` at admission."""
        for f in self._due(engine, "alloc_fail"):
            self._alloc_budget += f.count
        if self._alloc_budget > 0:
            self._alloc_budget -= 1
            self.fired.append(("alloc_fail", engine.tick, need))
            raise InjectedFault(
                f"injected allocator failure at tick {engine.tick} "
                f"({need} pages requested)")

    def on_chunk_start(self, engine, active: Sequence[int],
                       ticks: Optional[int] = None) -> None:
        """Called after the COW guard, right before the decode chunk.
        ``ticks`` is the length the engine committed to for THIS chunk —
        under an adaptive policy (DESIGN.md §15) that varies per
        boundary, and logging it lets chaos × SLO tests assert a fault
        fired inside a specific chunk length (e.g. a shrunk one).  A
        chunk_exception here aborts the whole chunk before any tick of
        it runs: the engine restores its snapshot and degrades to
        single-tick chunks, which overrides the adaptive policy until
        the engine is rebuilt (degraded wins — every retry must be the
        smallest replayable unit)."""
        for f in self._due(engine, "nan_logit"):
            if not self._poison(engine, active, f.rid):
                self._pending.append(f)      # target not active yet: defer
        for f in self._due(engine, "chunk_exception"):
            self.fired.append(("chunk_exception", engine.tick,
                               {"ticks": ticks}))
            raise InjectedFault(
                f"injected decode-chunk crash at tick {engine.tick}")

    # -- fault implementations ---------------------------------------------

    def _poison(self, engine, active: Sequence[int],
                rid: Optional[int]) -> bool:
        """NaN-fill one K/V page of the target row in every attention
        layer.  The page must hold at least one attended position
        (< cache_len) for the poison to reach the logits; pages are
        scanned back from the one holding ``cache_len - 1``, preferring
        refcount 1 so only the target row reads it."""
        slot = None
        for i in active:
            s = engine.slots[i]
            if s is not None and (rid is None or s.req.rid == rid):
                slot = i
                break
        if slot is None:
            if rid is not None and rid in engine.requests \
                    and engine.requests[rid].terminal:
                self.fired.append(("nan_logit", engine.tick,
                                   f"rid {rid} already terminal: skipped"))
                return True                  # never going to be active
            return False
        ps = engine.pool.page_size
        last = (int(engine._cache_len[slot]) - 1) // ps
        candidates = [int(engine._tables[slot, j]) for j in range(last, -1, -1)]
        pid = next((p for p in candidates if engine.pool.refcount(p) == 1),
                   candidates[0])
        for li, c in enumerate(engine.caches):
            if isinstance(c, dict) and "k" in c:
                engine.caches[li] = {
                    **c,
                    "k": c["k"].at[pid].set(np.nan),
                    "v": c["v"].at[pid].set(np.nan),
                }
        self.fired.append(
            ("nan_logit", engine.tick,
             {"rid": engine.slots[slot].req.rid, "slot": slot, "page": pid}))
        return True

    def _corrupt_index(self, engine) -> bool:
        """Scramble one entry's page field to a different id (seeded
        pick among the index's other pages, else the null page)."""
        idx = engine.prefix_index
        if idx is None or not len(idx):
            return False
        entries = list(idx._entries.values())
        victim = entries[int(self._rng.integers(len(entries)))]
        others = sorted(p for p in idx._owned if p != victim.page)
        bogus = (int(others[int(self._rng.integers(len(others)))])
                 if others else 0)
        self.fired.append(("index_corrupt", engine.tick,
                           {"page": victim.page, "scrambled_to": bogus}))
        victim.page = bogus
        return True
