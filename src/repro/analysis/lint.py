"""AST-based lint framework for JAX/Pallas hazards (DESIGN.md section 14).

The serving stack's efficiency killers are invisible to Python tooling:
silent recompiles from unhashable static args, stray host syncs inside
the decode loop, reused PRNG keys, Pallas index maps that close over
traced values.  Each was found *by hand* in earlier PRs; this module is
the tooling that finds them mechanically.

Architecture
------------
``ProjectIndex`` parses every ``.py`` file under the scanned roots into
``ModuleInfo``/``FunctionInfo`` records, builds a base-name call graph,
and computes the set of functions reachable from the jitted serving hot
roots (``HOT_ROOTS``).  Rules (see ``repro.analysis.rules``) receive the
index and yield ``Finding``s.  The framework applies inline suppression
comments (``# lint: ignore[rule-name]``), compares against a checked-in
baseline (``analysis_baseline.json``) keyed by *stable* finding keys
(no line numbers, so unrelated churn never invalidates the baseline),
and reports new / fixed / baselined counts.

Everything here is stdlib-only (``ast``, ``json``) by design — the
analyzer must run in any environment the repo runs in.
"""
from __future__ import annotations

import ast
import hashlib
import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

# Functions whose bodies execute inside (or drive) the jitted serving hot
# paths.  The host-sync rule treats everything reachable from these as
# hot.  NOTE for the tensor-parallel PR: reachability is plain base-name
# call-graph closure today — it must learn to see through `shard_map`
# wrappers once lm_prefill/_decode_chunk run under one (ROADMAP).
HOT_ROOTS: Tuple[str, ...] = (
    "_decode_chunk",
    "_paged_prefill_step",
    "lm_prefill",
    "lm_decode",
    "lm_generate",
)

BASELINE_NAME = "analysis_baseline.json"

# `# lint: ignore` suppresses every rule on that line;
# `# lint: ignore[rule-a, rule-b]` suppresses just those rules.
_SUPPRESS_RE = re.compile(r"#\s*lint:\s*ignore(?:\[([a-z0-9_,\-\s]+)\])?")


@dataclass(frozen=True)
class Finding:
    """One rule violation at a concrete source location."""

    rule: str
    path: str  # repo-relative posix path
    line: int
    col: int
    symbol: str  # enclosing function qualname ("<module>" at top level)
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule}: {self.message} [{self.symbol}]"

    def key(self) -> str:
        """Stable identity: path + symbol + rule + message digest.

        Deliberately excludes line/col so that unrelated edits (moving a
        function, adding imports) do not invalidate baseline entries.
        """
        digest = hashlib.sha1(self.message.encode("utf-8")).hexdigest()[:10]
        return f"{self.path}::{self.symbol}::{self.rule}::{digest}"


@dataclass
class FunctionInfo:
    """A def (or async def) with its callees and enclosing module."""

    qualname: str  # e.g. "ServingEngine._admit"
    name: str  # base name, e.g. "_admit"
    node: ast.AST
    module: "ModuleInfo"
    calls: Set[str] = field(default_factory=set)  # base names of callees

    @property
    def location(self) -> str:
        return f"{self.module.path}::{self.qualname}"


@dataclass
class JitInfo:
    """A binding produced by jax.jit (decorator or assignment)."""

    name: str  # bound name the call sites use
    static_argnums: Tuple[int, ...]
    static_argnames: Tuple[str, ...]
    params: Tuple[str, ...]  # positional params of the wrapped fn ((), if unknown)
    module: "ModuleInfo"
    lineno: int


@dataclass
class ModuleInfo:
    path: str  # repo-relative posix path
    tree: ast.Module
    source_lines: List[str]
    # line -> set of suppressed rule names ("*" = all rules)
    suppressions: Dict[int, Set[str]] = field(default_factory=dict)
    functions: List[FunctionInfo] = field(default_factory=list)
    jits: List[JitInfo] = field(default_factory=list)

    def suppressed(self, line: int, rule: str) -> bool:
        rules = self.suppressions.get(line)
        return bool(rules) and ("*" in rules or rule in rules)


def call_base_name(node: ast.Call) -> Optional[str]:
    """Base name of a call target: f() -> 'f', a.b.f() -> 'f'."""
    fn = node.func
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return None


def dotted_root(node: ast.AST) -> Optional[str]:
    """Leftmost name of a dotted expression: jnp.ones(...) -> 'jnp'."""
    while isinstance(node, ast.Attribute):
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return None


def dotted_name(node: ast.AST) -> Optional[str]:
    """Full dotted path of an expression if it is a plain Name/Attribute chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_jax_jit(node: ast.AST) -> bool:
    """True for `jax.jit` / `jit` expressions."""
    name = dotted_name(node)
    return name in ("jax.jit", "jit")


def _const_int_tuple(node: ast.AST) -> Tuple[int, ...]:
    try:
        val = ast.literal_eval(node)
    except (ValueError, SyntaxError):
        return ()
    if isinstance(val, int):
        return (val,)
    if isinstance(val, (tuple, list)) and all(isinstance(v, int) for v in val):
        return tuple(val)
    return ()


def _const_str_tuple(node: ast.AST) -> Tuple[str, ...]:
    try:
        val = ast.literal_eval(node)
    except (ValueError, SyntaxError):
        return ()
    if isinstance(val, str):
        return (val,)
    if isinstance(val, (tuple, list)) and all(isinstance(v, str) for v in val):
        return tuple(val)
    return ()


def _jit_kwargs(call: ast.Call) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    nums: Tuple[int, ...] = ()
    names: Tuple[str, ...] = ()
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            nums = _const_int_tuple(kw.value)
        elif kw.arg == "static_argnames":
            names = _const_str_tuple(kw.value)
    return nums, names


def _fn_params(node: ast.AST) -> Tuple[str, ...]:
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
        return tuple(a.arg for a in node.args.args)
    return ()


class _ModuleScanner(ast.NodeVisitor):
    """Collects functions, their callees, and jit bindings for one module."""

    def __init__(self, mod: ModuleInfo) -> None:
        self.mod = mod
        self._stack: List[str] = []

    # -- functions -------------------------------------------------------
    def _visit_def(self, node) -> None:
        self._stack.append(node.name)
        qualname = ".".join(self._stack)
        info = FunctionInfo(qualname=qualname, name=node.name, node=node, module=self.mod)
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                base = call_base_name(sub)
                if base:
                    info.calls.add(base)
        self.mod.functions.append(info)
        self._scan_jit_decorators(node)
        self.generic_visit(node)
        self._stack.pop()

    visit_FunctionDef = _visit_def
    visit_AsyncFunctionDef = _visit_def

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._stack.append(node.name)
        self.generic_visit(node)
        self._stack.pop()

    # -- jit bindings ----------------------------------------------------
    def _scan_jit_decorators(self, node) -> None:
        for dec in node.decorator_list:
            if _is_jax_jit(dec):
                self.mod.jits.append(
                    JitInfo(node.name, (), (), _fn_params(node), self.mod, node.lineno)
                )
            elif isinstance(dec, ast.Call):
                # @jax.jit(...) or @functools.partial(jax.jit, ...)
                target = dec
                if call_base_name(dec) == "partial" and dec.args and _is_jax_jit(dec.args[0]):
                    target = dec
                elif not _is_jax_jit(dec.func):
                    continue
                nums, names = _jit_kwargs(target)
                self.mod.jits.append(
                    JitInfo(node.name, nums, names, _fn_params(node), self.mod, node.lineno)
                )

    def visit_Assign(self, node: ast.Assign) -> None:
        # name = jax.jit(fn_or_lambda, static_argnums=..., static_argnames=...)
        if isinstance(node.value, ast.Call) and _is_jax_jit(node.value.func):
            nums, names = _jit_kwargs(node.value)
            params: Tuple[str, ...] = ()
            if node.value.args and isinstance(node.value.args[0], ast.Lambda):
                params = _fn_params(node.value.args[0])
            elif node.value.args:
                wrapped = dotted_name(node.value.args[0])
                if wrapped:
                    for fi in self.mod.functions:
                        if fi.name == wrapped.split(".")[-1]:
                            params = _fn_params(fi.node)
                            break
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    self.mod.jits.append(
                        JitInfo(tgt.id, nums, names, params, self.mod, node.lineno)
                    )
        self.generic_visit(node)


class ProjectIndex:
    """Parsed modules + call graph + hot-path reachability for the scan roots."""

    def __init__(self, root: Path, modules: List[ModuleInfo]) -> None:
        self.root = root
        self.modules = modules
        self.defs_by_name: Dict[str, List[FunctionInfo]] = {}
        self.jits_by_name: Dict[str, JitInfo] = {}
        for mod in modules:
            for fi in mod.functions:
                self.defs_by_name.setdefault(fi.name, []).append(fi)
            for ji in mod.jits:
                self.jits_by_name[ji.name] = ji
        self.hot_functions: Set[str] = self._reach(HOT_ROOTS)

    def _reach(self, roots: Sequence[str]) -> Set[str]:
        """Base-name call-graph closure from `roots` (callee direction).

        Conservative over-approximation: two unrelated functions sharing
        a name are merged.  Good enough at repo scale, and errs toward
        flagging (a suppression is one comment away).
        """
        seen: Set[str] = set()
        frontier: List[str] = list(roots)
        while frontier:
            name = frontier.pop()
            if name in seen:
                continue
            seen.add(name)
            for fi in self.defs_by_name.get(name, []):
                frontier.extend(fi.calls - seen)
        return seen

    def is_hot(self, fi: FunctionInfo) -> bool:
        return fi.name in self.hot_functions

    def jit_names(self) -> Set[str]:
        """Names bound to jitted callables (plus the known hot roots)."""
        return set(self.jits_by_name) | set(HOT_ROOTS)


def _parse_suppressions(lines: List[str]) -> Dict[int, Set[str]]:
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(line)
        if not m:
            continue
        if m.group(1):
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
        else:
            out[i] = {"*"}
    return out


def load_module(path: Path, root: Path) -> Optional[ModuleInfo]:
    try:
        source = path.read_text(encoding="utf-8")
        tree = ast.parse(source, filename=str(path))
    except (SyntaxError, UnicodeDecodeError, OSError):
        return None
    rel = path.relative_to(root).as_posix() if root in path.parents or path == root else path.as_posix()
    mod = ModuleInfo(path=rel, tree=tree, source_lines=source.splitlines())
    mod.suppressions = _parse_suppressions(mod.source_lines)
    _ModuleScanner(mod).visit(tree)
    return mod


def build_index(root: Path, paths: Sequence[Path]) -> ProjectIndex:
    modules: List[ModuleInfo] = []
    seen: Set[Path] = set()
    for p in paths:
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            f = f.resolve()
            if f in seen:
                continue
            seen.add(f)
            mod = load_module(f, root)
            if mod is not None:
                modules.append(mod)
    return ProjectIndex(root, modules)


# ---------------------------------------------------------------------------
# Rule protocol + driver
# ---------------------------------------------------------------------------

class Rule:
    """Base class: subclasses set `name`/`doc` and implement `check`."""

    name: str = ""
    doc: str = ""

    def check(self, index: ProjectIndex) -> Iterable[Finding]:  # pragma: no cover
        raise NotImplementedError


def run_rules(
    index: ProjectIndex,
    rules: Sequence[Rule],
    enabled: Optional[Set[str]] = None,
) -> Tuple[List[Finding], int]:
    """Run rules over the index; returns (findings, n_inline_suppressed)."""
    by_path = {m.path: m for m in index.modules}
    findings: List[Finding] = []
    suppressed = 0
    for rule in rules:
        if enabled is not None and rule.name not in enabled:
            continue
        for f in rule.check(index):
            mod = by_path.get(f.path)
            if mod is not None and mod.suppressed(f.line, f.rule):
                suppressed += 1
                continue
            findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings, suppressed


# ---------------------------------------------------------------------------
# Baseline
# ---------------------------------------------------------------------------

def unique_keys(findings: Sequence[Finding]) -> List[str]:
    """Finding keys with '#n' suffixes for same-key repeats (stable order)."""
    counts: Dict[str, int] = {}
    keys: List[str] = []
    for f in findings:
        k = f.key()
        n = counts.get(k, 0)
        counts[k] = n + 1
        keys.append(k if n == 0 else f"{k}#{n}")
    return keys


def load_baseline(path: Path) -> Dict[str, Dict[str, str]]:
    if not path.exists():
        return {}
    data = json.loads(path.read_text(encoding="utf-8"))
    return dict(data.get("entries", {}))


def write_baseline(path: Path, findings: Sequence[Finding], notes: Optional[Dict[str, str]] = None) -> None:
    notes = notes or {}
    entries = {}
    for f, k in zip(findings, unique_keys(findings)):
        entries[k] = {
            "rule": f.rule,
            "note": notes.get(k, "TODO: justify or fix"),
        }
    payload = {
        "version": 1,
        "comment": "Baseline for `python -m repro.analysis` (DESIGN.md section 14). "
        "Keys are path::symbol::rule::message-digest — line-number free, so "
        "unrelated churn never invalidates an entry. Every entry carries a "
        "one-line justification; fix the code instead of adding entries "
        "whenever possible.",
        "entries": entries,
    }
    path.write_text(json.dumps(payload, indent=2, sort_keys=False) + "\n", encoding="utf-8")


@dataclass
class BaselineDiff:
    new: List[Finding]
    known: List[Finding]
    stale: List[str]  # baseline keys with no matching finding


def diff_baseline(findings: Sequence[Finding], baseline: Dict[str, Dict[str, str]]) -> BaselineDiff:
    new: List[Finding] = []
    known: List[Finding] = []
    seen_keys: Set[str] = set()
    for f, k in zip(findings, unique_keys(findings)):
        seen_keys.add(k)
        (known if k in baseline else new).append(f)
    stale = sorted(set(baseline) - seen_keys)
    return BaselineDiff(new=new, known=known, stale=stale)


# ---------------------------------------------------------------------------
# Project entry point (used by CLI, tests, and bench_serving.py)
# ---------------------------------------------------------------------------

DEFAULT_SCAN_PATHS = ("src/repro", "benchmarks", "examples")


def default_rules() -> List[Rule]:
    from .rules import all_rules

    return all_rules()


@dataclass
class ProjectReport:
    findings: List[Finding]
    diff: BaselineDiff
    inline_suppressed: int
    files_scanned: int

    def by_rule(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for f in self.findings:
            out[f.rule] = out.get(f.rule, 0) + 1
        return dict(sorted(out.items()))


def run_project(
    root: Path,
    paths: Optional[Sequence[str]] = None,
    baseline_path: Optional[Path] = None,
    enabled: Optional[Set[str]] = None,
) -> ProjectReport:
    root = Path(root).resolve()
    scan = [root / p for p in (paths or DEFAULT_SCAN_PATHS)]
    scan = [p for p in scan if p.exists()]
    index = build_index(root, scan)
    findings, suppressed = run_rules(index, default_rules(), enabled=enabled)
    baseline = load_baseline(baseline_path or (root / BASELINE_NAME))
    diff = diff_baseline(findings, baseline)
    return ProjectReport(
        findings=findings,
        diff=diff,
        inline_suppressed=suppressed,
        files_scanned=len(index.modules),
    )
