"""JAX/Pallas-aware static analysis + runtime enforcement (DESIGN.md §14).

Static side: `python -m repro.analysis` lints the repo for host syncs in
hot paths, PRNG key reuse, recompile hazards, and Pallas structural
errors (see `repro.analysis.rules`).  Runtime side:
`repro.analysis.runtime` counts compiles and host-transfer boundaries so
tests — and `ServingEngine.analysis_stats()` — can prove steady-state
decode does zero recompiles and one transfer per chunk.
"""
from .lint import (  # noqa: F401
    Finding,
    HOT_ROOTS,
    ProjectIndex,
    ProjectReport,
    Rule,
    build_index,
    run_project,
    run_rules,
)
