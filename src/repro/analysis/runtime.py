"""Runtime enforcement for the static analyzer's two dynamic claims
(DESIGN.md section 14): *steady-state decode performs zero recompiles*
and *at most one device->host transfer boundary per chunk*.

Three cooperating pieces:

* **Compile tracking** — `CompileTracker` snapshots per-function jit
  cache sizes (`fn._cache_size()`) plus a process-wide compile-event
  counter fed by `jax.monitoring`.  Cache sizes are exact per tracked
  function; the event counter is a tripwire for compiles anywhere else.
* **Sync regions** — `sync_region(tag)` declares an *intentional*
  blocking host round-trip (the engine wraps its one-per-chunk
  `jax.device_get` in one).  Regions are counted per tag; "<=1 transfer
  per chunk" means exactly one region entered per decode chunk.
* **Stray-pull interception** — `no_host_sync()` patches the concrete
  jax Array host-materialisation hooks (`__array__`, `item`,
  `__float__`, ...) *and* the module entry points `np.asarray`,
  `np.array`, `jax.device_get`, so any pull *outside* a declared region
  raises `HostSyncError`.  The module-level patches matter: on CPU,
  `ArrayImpl` exposes the C buffer protocol, so `np.asarray` grabs a
  zero-copy view without ever calling the Python `__array__` hook — the
  only Python-visible choke point is the caller's module attribute.
  `jax.transfer_guard_device_to_host("disallow")` is layered on as
  well; the transfer guard only enforces on accelerator backends — on
  CPU the host "transfer" is zero-copy and the guard never fires, which
  is exactly why the patch-based meter exists.

All counters are process-global (jit caches are module-global too); the
engine keeps its own per-instance region counts for `analysis_stats()`.
"""
from __future__ import annotations

import contextlib
import functools
import threading
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


class HostSyncError(RuntimeError):
    """A device->host pull happened outside any declared sync_region."""


# ---------------------------------------------------------------------------
# Compile-event counter (process-wide tripwire)
# ---------------------------------------------------------------------------

_compile_events = 0
_listener_installed = False


def _on_event(event: str, **kwargs: Any) -> None:
    global _compile_events
    if "compile" in event:
        _compile_events += 1


def _install_listener() -> None:
    global _listener_installed
    if _listener_installed:
        return
    try:
        from jax import monitoring  # type: ignore[attr-defined]
    except ImportError:  # pragma: no cover - old/new jax layouts
        from jax._src import monitoring  # type: ignore[no-redef]
    monitoring.register_event_listener(_on_event)
    _listener_installed = True


def compile_events() -> int:
    """Process-wide count of compile-related monitoring events so far."""
    _install_listener()
    return _compile_events


def cache_size(fn: Any) -> int:
    """Size of a jitted function's compile cache (-1 if unknown)."""
    try:
        return int(fn._cache_size())
    except Exception:
        return -1


class CompileTracker:
    """Snapshot/diff jit cache sizes for a set of tracked functions."""

    def __init__(self, **fns: Any) -> None:
        self._fns = dict(fns)
        _install_listener()

    def snapshot(self) -> Dict[str, Any]:
        return {
            "events": compile_events(),
            "caches": {name: cache_size(fn) for name, fn in self._fns.items()},
        }

    @staticmethod
    def new_compiles(before: Dict[str, Any], after: Dict[str, Any]) -> Dict[str, int]:
        """Per-function cache growth between two snapshots (+ event delta)."""
        out = {
            name: after["caches"].get(name, -1) - size
            for name, size in before["caches"].items()
        }
        out["_events"] = after["events"] - before["events"]
        return out


# ---------------------------------------------------------------------------
# Sync regions + stray-pull interception
# ---------------------------------------------------------------------------

_lock = threading.Lock()
_region_stack: List[str] = []
_region_counts: Dict[str, int] = {}
_pull_counts: Dict[str, int] = {}
_strict_depth = 0
_meter_depth = 0
_saved_attrs: Dict[str, Any] = {}
_saved_mod_attrs: Dict[str, Any] = {}
_in_pull = threading.local()

_PULL_HOOKS = ("__array__", "item", "__float__", "__int__", "__bool__", "__index__", "tolist")
# caller-side entry points: on CPU the buffer protocol serves np.asarray
# a zero-copy view with no Python hook in the path, so the module
# attribute is the only interceptable choke point.
_MODULE_FUNCS = (("np.asarray", np, "asarray"), ("np.array", np, "array"),
                 ("jax.device_get", jax, "device_get"))


_array_cls_cache: Optional[type] = None


def _array_cls() -> type:
    # cached: computing it runs jnp.zeros, which itself routes through
    # the patched np.asarray while the meter is active.
    global _array_cls_cache
    if _array_cls_cache is None:
        _array_cls_cache = type(jnp.zeros((), jnp.int32))
    return _array_cls_cache


def _record_pull(hook: str) -> None:
    tag = _region_stack[-1] if _region_stack else None
    if tag is None and _strict_depth > 0:
        raise HostSyncError(
            f"device->host pull via `{hook}` outside any sync_region while "
            f"no_host_sync() is active — wrap the pull in "
            f"repro.analysis.runtime.sync_region(tag) or remove it"
        )
    key = tag if tag is not None else "<untagged>"
    _pull_counts[key] = _pull_counts.get(key, 0) + 1


def _has_device_leaf(args: Any, kwargs: Any) -> bool:
    cls = _array_cls()
    try:
        leaves = jax.tree_util.tree_leaves((args, kwargs))
    except Exception:  # exotic containers — be quiet rather than wrong
        return False
    return any(isinstance(leaf, cls) for leaf in leaves)


def _activate_meter() -> None:
    global _meter_depth
    with _lock:
        _meter_depth += 1
        if _meter_depth > 1:
            return
        cls = _array_cls()
        for name in _PULL_HOOKS:
            orig = getattr(cls, name, None)
            if orig is None:
                continue
            _saved_attrs[name] = orig

            def _wrap(orig: Callable, hook: str) -> Callable:
                @functools.wraps(orig)
                def wrapper(self, *args: Any, **kwargs: Any):
                    if not getattr(_in_pull, "depth", 0):
                        _record_pull(hook)
                    return orig(self, *args, **kwargs)

                return wrapper

            setattr(cls, name, _wrap(orig, name))
        for label, mod, attr in _MODULE_FUNCS:
            orig = getattr(mod, attr)
            _saved_mod_attrs[label] = orig

            def _wrap_mod(orig: Callable, hook: str) -> Callable:
                def wrapper(*args: Any, **kwargs: Any):
                    # record once per outermost pull: device_get calls
                    # np.asarray internally, don't double-count.
                    nested = getattr(_in_pull, "depth", 0)
                    if not nested and _has_device_leaf(args, kwargs):
                        _record_pull(hook)
                    _in_pull.depth = nested + 1
                    try:
                        return orig(*args, **kwargs)
                    finally:
                        _in_pull.depth = nested

                return wrapper

            setattr(mod, attr, _wrap_mod(orig, label))


def _deactivate_meter() -> None:
    global _meter_depth
    with _lock:
        _meter_depth -= 1
        if _meter_depth > 0:
            return
        cls = _array_cls()
        for name, orig in _saved_attrs.items():
            setattr(cls, name, orig)
        _saved_attrs.clear()
        for label, mod, attr in _MODULE_FUNCS:
            if label in _saved_mod_attrs:
                setattr(mod, attr, _saved_mod_attrs.pop(label))


@contextlib.contextmanager
def sync_region(tag: str) -> Iterator[None]:
    """Declare one intentional blocking host round-trip.

    Counted per tag; inside the region host pulls are allowed (and
    counted when a meter is active).  Layered transfer-guard `allow`
    covers accelerator backends where the guard actually enforces.
    """
    _region_counts[tag] = _region_counts.get(tag, 0) + 1
    _region_stack.append(tag)
    try:
        with jax.transfer_guard_device_to_host("allow"):
            yield
    finally:
        _region_stack.pop()


@contextlib.contextmanager
def no_host_sync(strict: bool = True) -> Iterator[None]:
    """Forbid device->host pulls outside declared sync_regions.

    `strict=True` raises `HostSyncError` on the first stray pull;
    `strict=False` only counts them (under the "<untagged>" tag).
    """
    global _strict_depth
    _activate_meter()
    if strict:
        _strict_depth += 1
    try:
        with jax.transfer_guard_device_to_host("disallow"):
            yield
    finally:
        if strict:
            _strict_depth -= 1
        _deactivate_meter()


@contextlib.contextmanager
def measure_pulls() -> Iterator[Dict[str, int]]:
    """Count host pulls per region tag without forbidding anything."""
    start = dict(_pull_counts)
    _activate_meter()
    try:
        delta: Dict[str, int] = {}
        yield delta
    finally:
        _deactivate_meter()
        for k, v in _pull_counts.items():
            d = v - start.get(k, 0)
            if d:
                delta[k] = d


def region_counts() -> Dict[str, int]:
    return dict(_region_counts)


def pull_counts() -> Dict[str, int]:
    return dict(_pull_counts)


def reset_counters() -> None:
    _region_counts.clear()
    _pull_counts.clear()
