"""Pluggable lint rules (DESIGN.md section 14).

Each module defines one `Rule` subclass and registers it with
`@register`.  To add a rule: subclass `repro.analysis.lint.Rule`, set a
unique kebab-case `name` and one-line `doc`, implement
`check(index) -> Iterable[Finding]`, decorate with `@register`, and
import the module here.  Fixture-based tests live in
`tests/test_analysis.py` — every rule must come with at least one
snippet it fires on.
"""
from __future__ import annotations

from typing import Dict, List, Type

from ..lint import Rule

_REGISTRY: Dict[str, Type[Rule]] = {}


def register(cls: Type[Rule]) -> Type[Rule]:
    assert cls.name and cls.name not in _REGISTRY, f"bad rule registration: {cls}"
    _REGISTRY[cls.name] = cls
    return cls


def all_rules() -> List[Rule]:
    # Imports deferred so `register` decorators run exactly once.
    from . import host_sync, prng, recompile, pallas  # noqa: F401

    return [cls() for _, cls in sorted(_REGISTRY.items())]


def rule_names() -> List[str]:
    from . import host_sync, prng, recompile, pallas  # noqa: F401

    return sorted(_REGISTRY)
