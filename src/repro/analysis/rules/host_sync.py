"""host-sync rule: device->host synchronisation in the wrong place.

Two flavors:

* **in-trace** — a sync op (`.item()`, `np.asarray`, `float()/int()/
  bool()` on a device value, `.block_until_ready()`, `jax.device_get`)
  inside a function reachable from the jitted hot roots.  Under trace
  these are at best a silent sync, at worst a `TracerArrayConversion`
  crash.
* **driver-loop** — the same ops inside a `for`/`while` loop that also
  calls a known-jitted function.  Each iteration blocks on the device,
  serialising the loop (the exact bug class the chunked decode loop was
  built to kill).

Coercions (`float`/`int`/`bool`, `np.asarray`) are only flagged when the
argument *derives from a device computation* (assigned from a
`jax.`/`jnp.` call or a known-jitted call, possibly through unpacking /
indexing / arithmetic) — `int(cfg.d_model * 4)` is static Python and
stays silent.  Inside jitted functions, parameters count as
device-derived except declared static argnames and a small blocklist
(`self`, `cfg`, `config`, `spec`).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set

from ..lint import (
    Finding,
    FunctionInfo,
    ProjectIndex,
    Rule,
    call_base_name,
    dotted_name,
    dotted_root,
)
from . import register

_DEVICE_ROOTS = {"jax", "jnp", "lax"}
_NP_ROOTS = {"np", "numpy", "onp"}
_NP_CONVERTERS = {"asarray", "array"}
_COERCIONS = {"float", "int", "bool"}
_STATIC_PARAM_BLOCKLIST = {"self", "cls", "cfg", "config", "spec"}


def _device_vars(fi: FunctionInfo, jit_names: Set[str], params_device: bool, static_names: Set[str]) -> Set[str]:
    """Names in `fi` bound (transitively) to device-computation results."""
    dv: Set[str] = set()
    if params_device:
        for p in ast.walk(fi.node):
            if isinstance(p, ast.arguments):
                for a in list(p.args) + list(p.kwonlyargs):
                    if a.arg not in static_names and a.arg not in _STATIC_PARAM_BLOCKLIST:
                        dv.add(a.arg)
                break

    def is_device(node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in dv
        if isinstance(node, ast.Call):
            if dotted_name(node.func) == "jax.device_get":
                return False  # device_get returns numpy: host-side from here on
            root = dotted_root(node.func)
            if root in _DEVICE_ROOTS:
                return True
            base = call_base_name(node)
            if base in jit_names:
                return True
            # method call on a device value: x.astype(...), x.sum()
            if isinstance(node.func, ast.Attribute) and is_device(node.func.value):
                return True
            return False
        if isinstance(node, (ast.Attribute, ast.Subscript, ast.Starred)):
            return is_device(node.value)
        if isinstance(node, ast.BinOp):
            return is_device(node.left) or is_device(node.right)
        if isinstance(node, ast.UnaryOp):
            return is_device(node.operand)
        if isinstance(node, ast.Compare):
            return is_device(node.left) or any(is_device(c) for c in node.comparators)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(is_device(e) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return is_device(node.body) or is_device(node.orelse)
        return False

    def mark(target: ast.AST) -> None:
        if isinstance(target, ast.Name):
            dv.add(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                mark(e)
        elif isinstance(target, ast.Starred):
            mark(target.value)

    # two passes for simple forward chains (a = jit_f(); b = a[0]; c = b + 1)
    for _ in range(2):
        for node in ast.walk(fi.node):
            if isinstance(node, ast.Assign) and is_device(node.value):
                for t in node.targets:
                    mark(t)
            elif isinstance(node, ast.AugAssign) and (is_device(node.value) or is_device(node.target)):
                mark(node.target)
            elif isinstance(node, ast.For) and is_device(node.iter):
                mark(node.target)
    return dv


class _SyncOp:
    def __init__(self, node: ast.Call, what: str, needs_device_arg: bool) -> None:
        self.node = node
        self.what = what
        self.needs_device_arg = needs_device_arg


def _sync_ops(body: ast.AST) -> List[_SyncOp]:
    out: List[_SyncOp] = []
    for node in ast.walk(body):
        if not isinstance(node, ast.Call):
            continue
        name = dotted_name(node.func)
        if isinstance(node.func, ast.Attribute):
            attr = node.func.attr
            if attr == "item" and not node.args:
                out.append(_SyncOp(node, "`.item()` blocks on the device", False))
                continue
            if attr == "block_until_ready":
                out.append(_SyncOp(node, "`.block_until_ready()` is an explicit device barrier", False))
                continue
        if name == "jax.block_until_ready":
            out.append(_SyncOp(node, "`jax.block_until_ready` is an explicit device barrier", False))
            continue
        if name == "jax.device_get":
            out.append(_SyncOp(node, "`jax.device_get` pulls device buffers to host", False))
            continue
        root = dotted_root(node.func)
        if (
            root in _NP_ROOTS
            and isinstance(node.func, ast.Attribute)
            and node.func.attr in _NP_CONVERTERS
            and node.args
        ):
            out.append(_SyncOp(node, f"`{root}.{node.func.attr}` on a device value copies to host", True))
            continue
        if (
            isinstance(node.func, ast.Name)
            and node.func.id in _COERCIONS
            and len(node.args) == 1
        ):
            out.append(_SyncOp(node, f"`{node.func.id}()` on a device value forces a host sync", True))
    return out


def _declared_sync_nodes(fi: FunctionInfo) -> Set[ast.AST]:
    """AST nodes inside `with ...sync_region(tag):` blocks.

    A pull wrapped in `repro.analysis.runtime.sync_region` is a
    *declared* blocking boundary — counted at runtime, exempt from the
    driver-loop flavor (but never from in-trace: a sync region inside a
    jitted function is still a bug).
    """
    out: Set[ast.AST] = set()
    for node in ast.walk(fi.node):
        if not isinstance(node, (ast.With, ast.AsyncWith)):
            continue
        for item in node.items:
            ce = item.context_expr
            if isinstance(ce, ast.Call) and call_base_name(ce) == "sync_region":
                for stmt in node.body:
                    out.update(ast.walk(stmt))
                break
    return out


def _loops_with_jit_calls(fi: FunctionInfo, jit_names: Set[str]) -> List[ast.AST]:
    loops = []
    for node in ast.walk(fi.node):
        if isinstance(node, (ast.For, ast.While)):
            for sub in ast.walk(node):
                if isinstance(sub, ast.Call) and call_base_name(sub) in jit_names:
                    loops.append(node)
                    break
    return loops


@register
class HostSyncRule(Rule):
    name = "host-sync"
    doc = (
        "Device->host sync ops (.item(), np.asarray, float/int/bool "
        "coercions, block_until_ready, device_get) inside hot-path "
        "functions or inside driver loops that call jitted functions."
    )

    def check(self, index: ProjectIndex) -> Iterable[Finding]:
        jit_names = index.jit_names()
        static_by_fn: Dict[str, Set[str]] = {
            ji.name: set(ji.static_argnames)
            | {ji.params[i] for i in ji.static_argnums if i < len(ji.params)}
            for ji in index.jits_by_name.values()
        }
        for mod in index.modules:
            for fi in mod.functions:
                hot = index.is_hot(fi)
                if hot:
                    dv = _device_vars(
                        fi, jit_names, params_device=True,
                        static_names=static_by_fn.get(fi.name, set()),
                    )
                    for op in _sync_ops(fi.node):
                        if op.needs_device_arg:
                            # np converters on a tracer crash outright -> always flag in-trace;
                            # python coercions only when provably device-derived.
                            is_np = "copies to host" in op.what
                            arg_dev = any(_arg_is_device(a, dv, jit_names) for a in op.node.args)
                            if not is_np and not arg_dev:
                                continue
                            if is_np and not arg_dev and not _any_name_arg(op.node):
                                continue
                        yield Finding(
                            rule=self.name, path=mod.path,
                            line=op.node.lineno, col=op.node.col_offset,
                            symbol=fi.qualname,
                            message=f"{op.what} in hot-path function `{fi.name}` "
                            f"(reachable from jitted roots)",
                        )
                else:
                    dv = _device_vars(fi, jit_names, params_device=False, static_names=set())
                    declared = _declared_sync_nodes(fi)
                    for loop in _loops_with_jit_calls(fi, jit_names):
                        for op in _sync_ops(loop):
                            if op.node in declared:
                                continue
                            if op.needs_device_arg and not any(
                                _arg_is_device(a, dv, jit_names) for a in op.node.args
                            ):
                                continue
                            yield Finding(
                                rule=self.name, path=mod.path,
                                line=op.node.lineno, col=op.node.col_offset,
                                symbol=fi.qualname,
                                message=f"{op.what} inside a driver loop that calls "
                                f"jitted functions — one blocking round-trip per iteration",
                            )


def _any_name_arg(call: ast.Call) -> bool:
    return any(isinstance(a, (ast.Name, ast.Attribute, ast.Subscript)) for a in call.args)


def _arg_is_device(arg: ast.AST, dv: Set[str], jit_names: Set[str]) -> bool:
    if isinstance(arg, ast.Name):
        return arg.id in dv
    if isinstance(arg, ast.Call):
        if dotted_name(arg.func) == "jax.device_get":
            return False  # numpy result — host-side
        root = dotted_root(arg.func)
        if root in _DEVICE_ROOTS:
            return True
        if call_base_name(arg) in jit_names:
            return True
        if isinstance(arg.func, ast.Attribute):
            return _arg_is_device(arg.func.value, dv, jit_names)
        return False
    if isinstance(arg, (ast.Attribute, ast.Subscript)):
        return _arg_is_device(arg.value, dv, jit_names)
    if isinstance(arg, ast.BinOp):
        return _arg_is_device(arg.left, dv, jit_names) or _arg_is_device(arg.right, dv, jit_names)
    if isinstance(arg, ast.UnaryOp):
        return _arg_is_device(arg.operand, dv, jit_names)
    if isinstance(arg, ast.Compare):
        return _arg_is_device(arg.left, dv, jit_names) or any(
            _arg_is_device(c, dv, jit_names) for c in arg.comparators
        )
    if isinstance(arg, (ast.Tuple, ast.List)):
        return any(_arg_is_device(e, dv, jit_names) for e in arg.elts)
    return False
