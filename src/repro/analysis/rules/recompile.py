"""recompile-hazard rule: call patterns that defeat the jit cache.

jax.jit caches on (fn identity, static arg *values*, traced arg
shapes/dtypes).  Three ways user code silently recompiles every call:

* `jax.jit(...)` constructed inside a loop, or immediately invoked
  (`jax.jit(f)(x)`) — fresh wrapper identity each time;
* an unhashable literal (list/dict/set) or a fresh `lambda` passed in a
  static position — either a TypeError or a cache miss per call;
* a static argument bound to a name that is reassigned inside the
  enclosing loop — one compile per distinct value, which is a deliberate
  bucketing strategy at best (suppress with a note) and a compile storm
  at worst.

Static positions are resolved from the project-wide jit registry
(decorated defs and `name = jax.jit(...)` bindings with literal
`static_argnums`/`static_argnames`).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from ..lint import (
    Finding,
    FunctionInfo,
    JitInfo,
    ProjectIndex,
    Rule,
    call_base_name,
    dotted_name,
)
from . import register

_UNHASHABLE = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp, ast.SetComp, ast.GeneratorExp)


def _static_args_at_call(call: ast.Call, ji: JitInfo) -> List[Tuple[str, ast.AST]]:
    """(static-param-label, value-expr) pairs bound at this call site."""
    out: List[Tuple[str, ast.AST]] = []
    static_names = set(ji.static_argnames)
    for i in ji.static_argnums:
        if i < len(ji.params):
            static_names.add(ji.params[i])
    for i, arg in enumerate(call.args):
        label = ji.params[i] if i < len(ji.params) else f"arg{i}"
        if i in ji.static_argnums or label in static_names:
            out.append((label, arg))
    for kw in call.keywords:
        if kw.arg is not None and kw.arg in static_names:
            out.append((kw.arg, kw.value))
    return out


def _loop_assigned_names(loop: ast.AST) -> Set[str]:
    names: Set[str] = set()

    def mark(t: ast.AST) -> None:
        if isinstance(t, ast.Name):
            names.add(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                mark(e)
        elif isinstance(t, ast.Starred):
            mark(t.value)

    for node in ast.walk(loop):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                mark(t)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            mark(node.target)
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            mark(node.target)
    return names


def _is_jit_expr(node: ast.AST) -> bool:
    return isinstance(node, ast.Call) and dotted_name(node.func) in ("jax.jit", "jit")


@register
class RecompileHazardRule(Rule):
    name = "recompile-hazard"
    doc = (
        "jit-in-loop / jit-then-call-immediately, unhashable or fresh-"
        "lambda static args, and static args reassigned per loop "
        "iteration."
    )

    def check(self, index: ProjectIndex) -> Iterable[Finding]:
        for mod in index.modules:
            for fi in mod.functions:
                yield from self._check_fn(index, mod, fi)

    def _check_fn(self, index: ProjectIndex, mod, fi: FunctionInfo) -> Iterable[Finding]:
        loops = [n for n in ast.walk(fi.node) if isinstance(n, (ast.For, ast.While))]
        loop_nodes = {loop: set(ast.walk(loop)) for loop in loops}
        loop_assigned = {loop: _loop_assigned_names(loop) for loop in loops}

        for node in ast.walk(fi.node):
            if not isinstance(node, ast.Call):
                continue
            # jax.jit(f)(x): fresh wrapper per call -> compile per call
            if _is_jit_expr(node.func):
                yield Finding(
                    rule=self.name, path=mod.path, line=node.lineno, col=node.col_offset,
                    symbol=fi.qualname,
                    message="`jax.jit(...)` invoked immediately — a fresh wrapper (and "
                    "compile) per call; bind the jitted function once instead",
                )
                continue
            # jax.jit constructed inside a loop
            if _is_jit_expr(node):
                for loop, members in loop_nodes.items():
                    if node in members:
                        yield Finding(
                            rule=self.name, path=mod.path, line=node.lineno, col=node.col_offset,
                            symbol=fi.qualname,
                            message="`jax.jit` constructed inside a loop — new wrapper "
                            "identity every iteration defeats the compile cache",
                        )
                        break
                continue
            # static-arg hazards at call sites of known jitted functions
            base = call_base_name(node)
            ji = index.jits_by_name.get(base) if base else None
            if ji is None:
                continue
            for label, value in _static_args_at_call(node, ji):
                if isinstance(value, _UNHASHABLE):
                    yield Finding(
                        rule=self.name, path=mod.path, line=value.lineno, col=value.col_offset,
                        symbol=fi.qualname,
                        message=f"unhashable literal passed to static arg `{label}` of "
                        f"jitted `{base}` — TypeError or cache miss per call",
                    )
                elif isinstance(value, ast.Lambda):
                    yield Finding(
                        rule=self.name, path=mod.path, line=value.lineno, col=value.col_offset,
                        symbol=fi.qualname,
                        message=f"fresh lambda passed to static arg `{label}` of jitted "
                        f"`{base}` — new identity per call forces a recompile",
                    )
                elif isinstance(value, ast.Name):
                    for loop, members in loop_nodes.items():
                        if node in members and value.id in loop_assigned[loop]:
                            yield Finding(
                                rule=self.name, path=mod.path,
                                line=value.lineno, col=value.col_offset,
                                symbol=fi.qualname,
                                message=f"static arg `{label}` of jitted `{base}` is bound to "
                                f"`{value.id}`, reassigned inside the enclosing loop — one "
                                f"compile per distinct value",
                            )
                            break
