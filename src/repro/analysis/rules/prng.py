"""prng-reuse rule: a jax.random key used more than its one allowed time.

JAX keys are single-use: consume a key with exactly one sampling call,
or derive children with `split`/`fold_in` — never both, never twice.
Violations tracked per function, per key variable:

* consumed by two calls without an interleaving reassignment
  (`key, sub = jax.random.split(key)` resets the state);
* consumed *and* used as a `split`/`fold_in` parent — the child keys
  are then correlated with the stream the consumer already drew from
  (the exact serve.py bug fixed by hand in PR 5);
* consumed inside a loop while defined outside it — every iteration
  draws the same stream.

Key variables are recognised from `jax.random.PRNGKey`/`split`/
`fold_in` results and from parameters named like keys (`key`, `rng`,
`*_key`, `*_rng`).  Subscripted keys (`keys[i]`) are not tracked — the
indexing itself is the discipline.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Set, Tuple

from ..lint import Finding, FunctionInfo, ProjectIndex, Rule, dotted_name
from . import register

_DERIVERS = {"jax.random.split", "jax.random.fold_in", "random.split", "random.fold_in"}
_KEY_MAKERS = {"jax.random.PRNGKey", "random.PRNGKey", "jax.random.key", "jax.random.wrap_key_data"}
_NON_CONSUMING = {"print", "len", "repr", "str", "type", "id", "isinstance"}
# No jnp/np/lax function draws randomness — a key passed through
# jnp.where/stack/asarray is selected or reshaped, not consumed.
_NON_CONSUMING_ROOTS = {"jnp", "np", "numpy", "lax"}


def _is_keyish_param(name: str) -> bool:
    return name in ("key", "rng") or name.endswith("_key") or name.endswith("_rng") or name.startswith("key_")


def _key_expr(node: ast.AST, keys: Set[str]) -> bool:
    """Does this expression produce a PRNG key (syntactically)?"""
    if isinstance(node, ast.Call):
        name = dotted_name(node.func)
        return name in _KEY_MAKERS or name in _DERIVERS
    if isinstance(node, ast.Name):
        return node.id in keys
    if isinstance(node, ast.IfExp):
        return _key_expr(node.body, keys) or _key_expr(node.orelse, keys)
    if isinstance(node, ast.Subscript):
        return _key_expr(node.value, keys)
    return False


def _terminates(body: List[ast.stmt]) -> bool:
    """Does this branch body unconditionally leave the function?"""
    return bool(body) and isinstance(body[-1], (ast.Return, ast.Raise, ast.Continue, ast.Break))


@dataclass
class _KeyState:
    consumes: List[ast.Call] = field(default_factory=list)
    derives: List[ast.Call] = field(default_factory=list)
    loop_depth_at_def: int = 0


class _FnWalker:
    """Sequential walk of a function body tracking per-key use counts."""

    def __init__(self, fi: FunctionInfo) -> None:
        self.fi = fi
        self.env: Dict[str, _KeyState] = {}
        self.violations: List[Tuple[ast.Call, str]] = []
        self.depth = 0
        node = fi.node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for a in list(node.args.args) + list(node.args.kwonlyargs):
                if _is_keyish_param(a.arg):
                    self.env[a.arg] = _KeyState()

    # -- events ----------------------------------------------------------
    def _use(self, var: str, call: ast.Call, derive: bool) -> None:
        st = self.env.get(var)
        if st is None:
            return
        if derive:
            if st.consumes:
                self.violations.append(
                    (call, f"key `{var}` already consumed, now used as split/fold_in parent "
                           f"— child keys correlate with the consumed stream")
                )
            st.derives.append(call)
        else:
            if st.consumes:
                self.violations.append(
                    (call, f"key `{var}` consumed twice without an interleaving split/fold_in")
                )
            elif st.derives:
                self.violations.append(
                    (call, f"key `{var}` used as split/fold_in parent and then consumed "
                           f"— consumer stream overlaps the derived children")
                )
            elif self.depth > st.loop_depth_at_def:
                self.violations.append(
                    (call, f"key `{var}` consumed inside a loop but defined outside it "
                           f"— every iteration draws the same stream")
                )
            st.consumes.append(call)

    def _bind(self, target: ast.AST, keyish: bool) -> None:
        if isinstance(target, ast.Name):
            if keyish:
                self.env[target.id] = _KeyState(loop_depth_at_def=self.depth)
            else:
                self.env.pop(target.id, None)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._bind(e, keyish)

    # -- expression scan: find key args fed to calls ---------------------
    def _scan_expr(self, node: ast.AST) -> None:
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            name = dotted_name(sub.func)
            if name and name.split(".")[-1] in _NON_CONSUMING:
                continue
            derive = name in _DERIVERS
            if not derive and name and name.split(".")[0] in _NON_CONSUMING_ROOTS:
                continue
            if not derive and name and name.startswith(("jax.numpy.", "jax.lax.")):
                continue
            for arg in list(sub.args) + [kw.value for kw in sub.keywords]:
                if isinstance(arg, ast.Name) and arg.id in self.env:
                    self._use(arg.id, sub, derive)

    # -- statements ------------------------------------------------------
    def walk(self, stmts: List[ast.stmt]) -> None:
        for stmt in stmts:
            self._stmt(stmt)

    def _stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.Assign):
            self._scan_expr(stmt.value)
            is_key = _key_expr(stmt.value, set(self.env))
            for t in stmt.targets:
                self._bind(t, is_key)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self._scan_expr(stmt.value)
            self._bind(stmt.target, _key_expr(stmt.value, set(self.env)))
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._scan_expr(stmt.iter)
            self._bind(stmt.target, _key_expr(stmt.iter, set(self.env)))
            self.depth += 1
            self.walk(stmt.body)
            self.depth -= 1
            self.walk(stmt.orelse)
        elif isinstance(stmt, ast.While):
            self._scan_expr(stmt.test)
            self.depth += 1
            self.walk(stmt.body)
            self.depth -= 1
            self.walk(stmt.orelse)
        elif isinstance(stmt, ast.If):
            self._scan_expr(stmt.test)
            # branches are exclusive: evaluate each against a copy, merge
            # max — unless the branch terminates (return/raise), in which
            # case the fall-through path never sees its key uses
            # (`if kind == "a": return init_a(key)` chains).
            import copy as _copy

            before = {k: _copy.deepcopy(v) for k, v in self.env.items()}
            self.walk(stmt.body)
            after_body = self.env
            self.env = before
            self.walk(stmt.orelse)
            if not _terminates(stmt.body):
                for k, st in after_body.items():
                    cur = self.env.get(k)
                    if cur is None or len(st.consumes) > len(cur.consumes) or len(st.derives) > len(cur.derives):
                        self.env[k] = st
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            pass  # nested defs get their own FunctionInfo walk
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._scan_expr(item.context_expr)
            self.walk(stmt.body)
        elif isinstance(stmt, ast.Try):
            self.walk(stmt.body)
            for h in stmt.handlers:
                self.walk(h.body)
            self.walk(stmt.orelse)
            self.walk(stmt.finalbody)
        elif isinstance(stmt, (ast.Return, ast.Expr)):
            if stmt.value is not None:
                self._scan_expr(stmt.value)
        else:
            self._scan_expr(stmt)


@register
class PrngReuseRule(Rule):
    name = "prng-reuse"
    doc = (
        "A jax.random key consumed twice, consumed and re-used as a "
        "split/fold_in parent, or consumed in a loop it was defined "
        "outside of."
    )

    def check(self, index: ProjectIndex) -> Iterable[Finding]:
        for mod in index.modules:
            for fi in mod.functions:
                if not isinstance(fi.node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                w = _FnWalker(fi)
                w.walk(fi.node.body)
                for call, msg in w.violations:
                    yield Finding(
                        rule=self.name, path=mod.path,
                        line=call.lineno, col=call.col_offset,
                        symbol=fi.qualname, message=msg,
                    )
