"""pallas-constraints rule: structural checks on `pl.pallas_call` sites.

Three checks per call site:

* **index-map arity** — every `BlockSpec` index map must take exactly
  `len(grid) + num_scalar_prefetch` parameters, and (when both are
  literal) return as many coordinates as the block shape has dims.
  Mismatches surface as shape errors deep inside lowering; here they
  are one line.
* **traced captures** — an index map runs at trace/lowering time; a
  lambda that closes over a name whose *staticness is not locally
  provable* (not a constant, `.shape` access, int-annotated/defaulted
  parameter, or arithmetic over those) risks capturing a tracer.  The
  prover is deliberately conservative: `min(...)`-style calls are
  unproven even when static by construction — suppress with a note.
* **interpret path** — every `pallas_call` must thread an `interpret=`
  kwarg and the enclosing function must expose an `interpret`
  parameter, so kernels stay debuggable/testable off-accelerator
  (the repo's CPU CI runs every kernel in interpret mode).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from ..lint import Finding, FunctionInfo, ProjectIndex, Rule, dotted_name
from . import register

_PALLAS_CALL_NAMES = {"pallas_call", "pl.pallas_call"}
_GRID_SPEC_NAMES = {"PrefetchScalarGridSpec", "GridSpec"}

# Builtins/globals an index map may reference freely.
_SAFE_GLOBALS = {
    "len", "min", "max", "abs", "int", "sum", "range", "tuple", "divmod",
    "jnp", "jax", "pl", "lax", "np", "functools", "math",
}


def _is_pallas_call(node: ast.Call) -> bool:
    name = dotted_name(node.func)
    return name is not None and name.split(".")[-1] == "pallas_call"


def _static_env(fn: ast.AST) -> Dict[str, List[ast.AST]]:
    """name -> *every* defining expression, for local staticness proofs.

    A name is provably static only if all of its bindings are — no flow
    analysis, so one unproven reassignment poisons the name.
    """
    env: Dict[str, List[ast.AST]] = {}
    if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
        args = fn.args
        defaults = list(args.defaults)
        pos = list(args.args)
        # align defaults to the tail of positional params
        for i, a in enumerate(pos):
            d_idx = i - (len(pos) - len(defaults))
            default = defaults[d_idx] if d_idx >= 0 else None
            is_int_ann = (
                isinstance(a.annotation, ast.Name) and a.annotation.id in ("int", "bool")
            )
            if isinstance(default, ast.Constant) and isinstance(default.value, (int, bool)):
                env[a.arg] = [default]
            elif is_int_ann:
                env[a.arg] = [ast.Constant(value=0)]  # marker: int-typed param
        for a, d in zip(args.kwonlyargs, args.kw_defaults):
            if isinstance(d, ast.Constant) and isinstance(d.value, (int, bool)):
                env[a.arg] = [d]
            elif isinstance(a.annotation, ast.Name) and a.annotation.id in ("int", "bool"):
                env[a.arg] = [ast.Constant(value=0)]
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and isinstance(node.targets[0], ast.Name):
            env.setdefault(node.targets[0].id, []).append(node.value)
    return env


def provably_static(expr: ast.AST, env: Dict[str, List[ast.AST]], _seen: Optional[Set[str]] = None) -> bool:
    """Conservative proof that `expr` is a Python value at trace time."""
    seen = _seen or set()
    if isinstance(expr, ast.Constant):
        return True
    if isinstance(expr, ast.Name):
        if expr.id in seen:
            return False
        bindings = env.get(expr.id)
        if not bindings:
            return False
        return all(provably_static(b, env, seen | {expr.id}) for b in bindings)
    if isinstance(expr, ast.Attribute):
        # x.shape / x.ndim / x.size are static under trace regardless of x
        return expr.attr in ("shape", "ndim", "size", "dtype")
    if isinstance(expr, ast.Subscript):
        return provably_static(expr.value, env, seen)
    if isinstance(expr, ast.BinOp):
        return provably_static(expr.left, env, seen) and provably_static(expr.right, env, seen)
    if isinstance(expr, ast.UnaryOp):
        return provably_static(expr.operand, env, seen)
    if isinstance(expr, ast.Call):
        # len(...) of anything is static under trace; everything else unproven
        return dotted_name(expr.func) == "len"
    if isinstance(expr, (ast.Tuple, ast.List)):
        return all(provably_static(e, env, seen) for e in expr.elts)
    return False


def _map_params(fn) -> List[str]:
    return [a.arg for a in fn.args.args]


def _map_body(fn) -> ast.AST:
    if isinstance(fn, ast.Lambda):
        return fn.body
    # nested `def pool_map(...)`: use the returned expression if single-return
    rets = [n.value for n in ast.walk(fn) if isinstance(n, ast.Return) and n.value is not None]
    return rets[0] if len(rets) == 1 else fn


def _index_map_free_names(fn) -> Set[str]:
    bound = set(_map_params(fn))
    body = fn.body if isinstance(fn, ast.Lambda) else fn
    nodes = list(ast.walk(body if isinstance(body, ast.AST) else fn))
    # names assigned inside the map body are its locals, not captures
    for node in nodes:
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            bound.add(node.id)
    free: Set[str] = set()
    for node in nodes:
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            if node.id not in bound and node.id not in _SAFE_GLOBALS:
                free.add(node.id)
    return free


def _kw(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _block_specs(node: ast.AST) -> List[ast.Call]:
    out = []
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            n = dotted_name(sub.func)
            if n is not None and n.split(".")[-1] == "BlockSpec":
                out.append(sub)
    return out


def _spec_parts(spec: ast.Call, local_defs: Dict[str, ast.FunctionDef]) -> Tuple[Optional[ast.AST], Optional[ast.AST]]:
    """(index_map callable, block_shape expr) from a BlockSpec call.

    Either argument order; the index map may be an inline lambda or a
    Name referring to a nested `def` in the enclosing function.
    """
    fn: Optional[ast.AST] = None
    shape: Optional[ast.AST] = None
    candidates = list(spec.args) + [kw.value for kw in spec.keywords]
    for a in candidates:
        if isinstance(a, ast.Lambda) and fn is None:
            fn = a
        elif isinstance(a, ast.Name) and a.id in local_defs and fn is None:
            fn = local_defs[a.id]
        elif shape is None:
            shape = a
    return fn, shape


def _grid_rank_and_prefetch(call: ast.Call, fn_env: Dict[str, List[ast.AST]]) -> Tuple[Optional[int], int]:
    """Grid rank + num_scalar_prefetch for a pallas_call, following one
    level of local name indirection for `grid_spec=name` bindings."""
    grid = _kw(call, "grid")
    prefetch = 0
    spec = _kw(call, "grid_spec")
    if spec is not None:
        if isinstance(spec, ast.Name):
            bindings = fn_env.get(spec.id)
            spec = bindings[-1] if bindings else None
        if isinstance(spec, ast.Call) and dotted_name(spec.func) is not None and \
                dotted_name(spec.func).split(".")[-1] in _GRID_SPEC_NAMES:
            grid = _kw(spec, "grid") or (spec.args[0] if spec.args else None)
            pf = _kw(spec, "num_scalar_prefetch")
            if isinstance(pf, ast.Constant) and isinstance(pf.value, int):
                prefetch = pf.value
    if isinstance(grid, (ast.Tuple, ast.List)):
        return len(grid.elts), prefetch
    if isinstance(grid, ast.Name):
        bindings = fn_env.get(grid.id)
        if bindings and isinstance(bindings[-1], (ast.Tuple, ast.List)):
            return len(bindings[-1].elts), prefetch
    return None, prefetch


@register
class PallasConstraintsRule(Rule):
    name = "pallas-constraints"
    doc = (
        "BlockSpec index-map arity vs grid, index maps capturing names "
        "not provably static, and pallas_call sites without an "
        "interpret-mode path."
    )

    def check(self, index: ProjectIndex) -> Iterable[Finding]:
        for mod in index.modules:
            mod_env: Dict[str, List[ast.AST]] = {}
            for stmt in mod.tree.body:
                if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 and \
                        isinstance(stmt.targets[0], ast.Name):
                    mod_env[stmt.targets[0].id] = [stmt.value]
            for fi in mod.functions:
                if not isinstance(fi.node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                calls = [
                    n for n in ast.walk(fi.node)
                    if isinstance(n, ast.Call) and _is_pallas_call(n)
                ]
                if not calls:
                    continue
                env = {**mod_env, **_static_env(fi.node)}
                fn_params = {a.arg for a in fi.node.args.args} | {
                    a.arg for a in fi.node.args.kwonlyargs
                }
                local_defs = {
                    n.name: n for n in ast.walk(fi.node)
                    if isinstance(n, ast.FunctionDef) and n is not fi.node
                }
                for call in calls:
                    yield from self._check_call(mod, fi, call, env, fn_params, local_defs)

    def _check_call(self, mod, fi, call: ast.Call, env, fn_params, local_defs) -> Iterable[Finding]:
        # interpret path
        if _kw(call, "interpret") is None or "interpret" not in fn_params:
            yield Finding(
                rule=self.name, path=mod.path, line=call.lineno, col=call.col_offset,
                symbol=fi.qualname,
                message="pallas_call without an `interpret=` kwarg threaded from an "
                "`interpret` parameter — kernel has no off-accelerator path",
            )
        rank, prefetch = _grid_rank_and_prefetch(call, env)
        # BlockSpecs may sit inside a `grid_spec = PrefetchScalarGridSpec(...)`
        # local binding rather than inline in the pallas_call
        spec_sources: List[ast.AST] = [call]
        gs = _kw(call, "grid_spec")
        if isinstance(gs, ast.Name):
            bindings = env.get(gs.id)
            if bindings:
                spec_sources.append(bindings[-1])
        for spec in [s for src in spec_sources for s in _block_specs(src)]:
            imap, shape = _spec_parts(spec, local_defs)
            if imap is None:
                continue
            map_name = imap.name if isinstance(imap, ast.FunctionDef) else "<lambda>"
            n_params = len(_map_params(imap))
            if rank is not None and n_params != rank + prefetch:
                yield Finding(
                    rule=self.name, path=mod.path, line=spec.lineno, col=spec.col_offset,
                    symbol=fi.qualname,
                    message=f"index_map `{map_name}` takes {n_params} args but grid rank "
                    f"{rank} + {prefetch} scalar-prefetch refs = {rank + prefetch} expected",
                )
            body = _map_body(imap)
            if isinstance(shape, (ast.Tuple, ast.List)) and isinstance(body, (ast.Tuple, ast.List)):
                if len(body.elts) != len(shape.elts):
                    yield Finding(
                        rule=self.name, path=mod.path, line=spec.lineno, col=spec.col_offset,
                        symbol=fi.qualname,
                        message=f"index_map `{map_name}` returns {len(body.elts)} coords "
                        f"but block_shape has {len(shape.elts)} dims",
                    )
            for name in sorted(_index_map_free_names(imap)):
                if not provably_static(ast.Name(id=name, ctx=ast.Load()), env):
                    yield Finding(
                        rule=self.name, path=mod.path, line=spec.lineno, col=spec.col_offset,
                        symbol=fi.qualname,
                        message=f"index_map `{map_name}` captures `{name}` whose staticness "
                        f"is not locally provable — a traced capture would lower into "
                        f"the index computation",
                    )
