"""CLI: `python -m repro.analysis` — lint the repo for JAX/Pallas hazards.

Exit codes: 0 clean vs baseline, 1 new findings (with --fail-on-new),
2 usage error.  See DESIGN.md section 14 for the baseline workflow.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from .lint import (
    BASELINE_NAME,
    DEFAULT_SCAN_PATHS,
    load_baseline,
    run_project,
    unique_keys,
    write_baseline,
)
from .rules import rule_names


def _find_root(start: Path) -> Path:
    """Walk up from `start` to the repo root (dir containing src/repro)."""
    for cand in [start, *start.parents]:
        if (cand / "src" / "repro").is_dir():
            return cand
    return start


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST lint for JAX/Pallas hazards: host syncs in hot paths, "
        "PRNG reuse, recompile hazards, Pallas constraints.",
    )
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"paths to scan (default: {' '.join(DEFAULT_SCAN_PATHS)})")
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root (default: auto-detect from cwd)")
    ap.add_argument("--baseline", type=Path, default=None,
                    help=f"baseline file (default: <root>/{BASELINE_NAME})")
    ap.add_argument("--fail-on-new", action="store_true",
                    help="exit 1 if any finding is not in the baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline from current findings "
                    "(preserves existing notes)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule names to run (default: all)")
    ap.add_argument("--list-rules", action="store_true", help="list rule names and exit")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable report on stdout")
    args = ap.parse_args(argv)

    if args.list_rules:
        for name in rule_names():
            print(name)
        return 0

    root = (args.root or _find_root(Path.cwd())).resolve()
    baseline_path = args.baseline or (root / BASELINE_NAME)
    enabled = None
    if args.rules:
        enabled = {r.strip() for r in args.rules.split(",") if r.strip()}
        unknown = enabled - set(rule_names())
        if unknown:
            print(f"unknown rules: {', '.join(sorted(unknown))}", file=sys.stderr)
            return 2

    t0 = time.perf_counter()
    report = run_project(root, paths=args.paths or None,
                         baseline_path=baseline_path, enabled=enabled)
    elapsed_ms = (time.perf_counter() - t0) * 1e3

    if args.write_baseline:
        old = load_baseline(baseline_path)
        notes = {k: v.get("note", "") for k, v in old.items() if v.get("note")}
        write_baseline(baseline_path, report.findings, notes=notes)
        print(f"wrote {baseline_path} with {len(report.findings)} entries")
        return 0

    if args.as_json:
        payload = {
            "runtime_ms": round(elapsed_ms, 2),
            "files_scanned": report.files_scanned,
            "findings": len(report.findings),
            "new": len(report.diff.new),
            "baselined": len(report.diff.known),
            "inline_suppressed": report.inline_suppressed,
            "stale_baseline_entries": len(report.diff.stale),
            "by_rule": report.by_rule(),
            "new_findings": [f.format() for f in report.diff.new],
        }
        print(json.dumps(payload, indent=2))
    else:
        for f in report.diff.new:
            print(f"NEW  {f.format()}")
        if not args.fail_on_new:
            for f in report.diff.known:
                print(f"BASE {f.format()}")
        for k in report.diff.stale:
            print(f"STALE baseline entry (finding fixed — prune it): {k}", file=sys.stderr)
        print(
            f"{report.files_scanned} files, {len(report.findings)} findings "
            f"({len(report.diff.new)} new, {len(report.diff.known)} baselined, "
            f"{report.inline_suppressed} inline-suppressed, "
            f"{len(report.diff.stale)} stale) in {elapsed_ms:.0f} ms"
        )

    if args.fail_on_new and report.diff.new:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
