"""Logical-axis sharding: rules, constraints, and per-param PartitionSpecs.

Models annotate activations with *logical* axis names ("batch", "seq",
"embed", "heads", "mlp", "experts", "vocab", "kv_seq").  The launcher
installs a rule set mapping logical names to mesh axes; outside any rule
context the constraints are no-ops, so the same model code runs on one CPU
device in tests and on the 512-chip production mesh in the dry-run.

Parameter shardings are produced by path-pattern rules (Megatron TP on the
"model" axis + ZeRO-3/FSDP on the "data" axis), with divisibility-aware
fallbacks: a dim that does not divide its assigned mesh axes falls back to
replication on that axis (e.g. mixtral's 8 experts on a 16-way model axis
fall back to intra-expert TP — see DESIGN.md §4).
"""
from __future__ import annotations

import contextlib
import contextvars
import re
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "axis_rules",
    "logical_constraint",
    "make_train_rules",
    "make_decode_rules",
    "param_pspecs",
    "named_sharding_tree",
    "current_rules",
    "make_mesh",
    "use_mesh",
    "shard_map",
    "cost_analysis",
    "HAS_AXIS_TYPE",
]

# ---------------------------------------------------------------------------
# jax-version compatibility gate (AxisType landed after 0.4.x; set_mesh
# likewise).  Everything downstream goes through these shims so the same
# code runs on the pinned container jax and on current releases.
# ---------------------------------------------------------------------------

try:
    from jax.sharding import AxisType as _AxisType  # type: ignore
    HAS_AXIS_TYPE = True
except ImportError:
    _AxisType = None
    HAS_AXIS_TYPE = False


def make_mesh(shape, axes, *, devices=None) -> Mesh:
    """jax.make_mesh with explicit Auto axis types where supported."""
    kwargs = {}
    if HAS_AXIS_TYPE:
        kwargs["axis_types"] = (_AxisType.Auto,) * len(axes)
    if devices is not None:
        kwargs["devices"] = devices
    return jax.make_mesh(shape, axes, **kwargs)


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """``jax.set_mesh`` when available, else the legacy ``with mesh:``
    thread-resources context — either way ``_concrete_mesh`` sees it."""
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


def shard_map(f, *, mesh: Mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` (check_vma) or the 0.4.x
    ``jax.experimental.shard_map`` (check_rep), whichever is installed."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check)


def cost_analysis(compiled) -> Dict[str, Any]:
    """``Compiled.cost_analysis()`` normalized to a dict — pre-0.5 jax
    returns a one-entry-per-program list."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca

AxisVal = Union[None, str, Tuple[str, ...]]

_RULES: contextvars.ContextVar[Optional[Dict[str, AxisVal]]] = contextvars.ContextVar(
    "repro_axis_rules", default=None
)


@contextlib.contextmanager
def axis_rules(rules: Optional[Mapping[str, AxisVal]]):
    token = _RULES.set(dict(rules) if rules is not None else None)
    try:
        yield
    finally:
        _RULES.reset(token)


def current_rules() -> Optional[Dict[str, AxisVal]]:
    return _RULES.get()


def _mesh_axis_size(mesh: Mesh, axis: AxisVal) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis]
    return int(np.prod([mesh.shape[a] for a in axis]))


def logical_constraint(x, *logical_axes: Optional[str]):
    """with_sharding_constraint by logical names; no-op without rules/mesh.

    Dims whose size does not divide the mapped mesh axes are left
    unconstrained (None) rather than failing.
    """
    rules = _RULES.get()
    if rules is None:
        return x
    mesh = _current_mesh()
    if mesh is None or mesh.empty:
        return x
    spec = []
    for dim, name in enumerate(logical_axes):
        axis = rules.get(name) if name is not None else None
        if axis is not None and x.shape[dim] % _mesh_axis_size(mesh, axis) != 0:
            axis = None
        spec.append(axis)
    return jax.lax.with_sharding_constraint(x, P(*spec))


def _current_mesh() -> Optional[Mesh]:
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except AttributeError:       # pre-set_mesh jax: thread resources only
        return _concrete_mesh()
    if mesh is not None and not mesh.empty:
        # constraints accept PartitionSpec directly under set_mesh
        return _concrete_mesh() or mesh
    return _concrete_mesh()


def _concrete_mesh() -> Optional[Mesh]:
    """Ambient mesh: `with mesh:` thread resources OR `jax.set_mesh(...)`."""
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def make_train_rules(multi_pod: bool) -> Dict[str, AxisVal]:
    dp = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": dp,
        "seq": None,
        "embed": None,
        "heads": "model",
        "kv": None,
        "mlp": "model",
        "experts": "model",   # EP weights (only when cfg.moe_ep)
        "expert_cap": "model", # MoE dispatch-buffer capacity dim
        "vocab": "model",
        "kv_seq": None,       # training: KV not sharded on seq
        "res_seq": "model",   # used only when cfg.seq_sharded_acts (SP)
        "fsdp": "data",
        "tp": "model",
    }


def make_decode_rules(multi_pod: bool, *, shard_cache_seq: bool) -> Dict[str, AxisVal]:
    """Decode: small batches; optionally context-parallel KV cache."""
    rules = make_train_rules(multi_pod)
    if shard_cache_seq:
        # batch=1 long-context: batch unshardable, cache seq over data
        rules["batch"] = None
        rules["kv_seq"] = "data"
        rules["seq"] = None
    else:
        rules["kv_seq"] = None
    return rules


# ---------------------------------------------------------------------------
# Parameter sharding
# ---------------------------------------------------------------------------

# (path regex, spec builder) — first match wins.  Spec builders receive the
# shape and mesh and return a PartitionSpec with divisibility fallbacks.
def _spec(shape, mesh, *axes: AxisVal) -> P:
    fixed = []
    for dim, axis in enumerate(axes):
        if axis is not None and shape[dim] % _mesh_axis_size(mesh, axis) != 0:
            axis = None
        fixed.append(axis)
    return P(*fixed)


def param_pspecs(
    shapes: Mapping[str, Any], mesh: Mesh, *, fsdp_axis: str = "data", tp_axis: str = "model"
):
    """PartitionSpec pytree for a params pytree of ShapeDtypeStructs/arrays.

    Patterns (matched on '/'-joined path):
      embedding (V, D)                   -> (tp, fsdp)     vocab-parallel
      attn q/o, mlp in/out, generic 2-D  -> col/row TP + FSDP
      moe experts (E, D, F)              -> EP on tp if divisible else
                                             intra-expert TP
      1-D (norm scales, biases)          -> replicated (tiny)
    """
    d, t = fsdp_axis, tp_axis

    def rule(path: str, shape: Tuple[int, ...]) -> P:
        n = len(shape)
        pl = path.lower()
        if n <= 1:
            return P()
        if re.search(r"(embed|tok_embeddings|lm_head|unembed)", pl):
            # (V, D) — vocab on TP axis, embed on FSDP
            return _spec(shape, mesh, t, d)
        if n == 3 and re.search(r"(expert|moe)", pl):
            # default: weights FSDP-sharded over data, replicated over model
            # (compute parallelism comes from the capacity dim — §Perf G2);
            # large-expert models (mixtral) TP the inner dims instead.
            e = shape[0]
            if e % _mesh_axis_size(mesh, t) != 0 or shape[1] * shape[2] >= 16_000_000:
                if re.search(r"(w_down|down|wo)", pl):
                    return _spec(shape, mesh, None, t, d)   # (E, F, D)
                return _spec(shape, mesh, None, d, t)       # (E, D, F)
            return _spec(shape, mesh, None, d, None)        # FSDP only
        if n == 2:
            if re.search(r"(wo|out_proj|o_proj|down|w2|dense_4h|proj_out)", pl):
                return _spec(shape, mesh, t, d)             # row-parallel
            return _spec(shape, mesh, d, t)                 # col-parallel
        if n == 3:
            # fused qkv (D, H, dh) or conv (kw, cin, cout)
            return _spec(shape, mesh, d, t, None)
        if n >= 4:
            return _spec(shape, mesh, *([None] * (n - 2)), d, t)
        return P()

    def walk(node, prefix):
        if isinstance(node, Mapping):
            return {k: walk(v, f"{prefix}/{k}" if prefix else str(k)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            out = [walk(v, f"{prefix}/{i}") for i, v in enumerate(node)]
            return type(node)(out) if isinstance(node, tuple) else out
        if node is None:
            return None
        return rule(prefix, tuple(node.shape))

    return walk(shapes, "")


def named_sharding_tree(pspecs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if s is not None else None,
        pspecs,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )
