"""Distribution substrate: sharding rules, collectives, compression."""
from .sharding import (
    axis_rules,
    current_rules,
    logical_constraint,
    make_decode_rules,
    make_train_rules,
    named_sharding_tree,
    param_pspecs,
)

__all__ = [
    "axis_rules", "current_rules", "logical_constraint", "make_decode_rules",
    "make_train_rules", "named_sharding_tree", "param_pspecs",
]
