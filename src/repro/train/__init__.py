"""Training/serving steps + fault-tolerant trainer."""
from .train_step import (
    init_train_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from .trainer import Trainer, TrainerConfig

__all__ = [
    "init_train_state", "make_decode_step", "make_prefill_step",
    "make_train_step", "Trainer", "TrainerConfig",
]
