"""Train / prefill / decode step builders.

``make_train_step`` produces a pure (state, batch) -> (state, metrics)
function with:
* mask-aware forward (params * mask so pruned structures contribute zero
  and receive zero gradient — the paper's fine-tuning semantics),
* optional resource-aware group-lasso regularization (paper Alg. 2),
* microbatched gradient accumulation (python-unrolled: correct XLA cost
  analysis, bounded activation memory),
* AdamW with fp32 state + global-norm clipping,
* MoE aux-loss folding.

State pytree: {"params", "opt", "masks" (optional), "step"}.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.masks import apply_masks
from repro.models.transformer import (
    cross_entropy_loss,
    encode_kv_caches,
    encoder_forward,
    init_caches,
    lm_decode,
    lm_forward,
)
from repro.optim.adamw import AdamWConfig, adamw_update

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step", "init_train_state"]


def init_train_state(params, opt_cfg: AdamWConfig, masks=None) -> Dict[str, Any]:
    from repro.optim.adamw import init_opt_state

    state = {
        "params": params,
        "opt": init_opt_state(params, opt_cfg),
        "step": jnp.zeros((), jnp.int32),
    }
    if masks is not None:
        state["masks"] = masks
    return state


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    lr_schedule: Callable[[jnp.ndarray], jnp.ndarray],
    *,
    reg_fn: Optional[Callable] = None,
    moe_aux_weight: float = 0.01,
    microbatches: int = 1,
) -> Callable:
    def loss_fn(params, masks, batch):
        p = apply_masks(params, masks) if masks is not None else params
        logits, aux = lm_forward(p, batch, cfg)
        xent = cross_entropy_loss(logits, batch["labels"])
        total = xent + moe_aux_weight * aux["moe_aux"]
        if reg_fn is not None:
            total = total + reg_fn(params)
        return total, {"loss": xent, "moe_aux": aux["moe_aux"]}

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: Dict[str, Any], batch: Dict[str, jnp.ndarray]):
        params = state["params"]
        masks = state.get("masks")

        if microbatches <= 1:
            (total, metrics), grads = grad_fn(params, masks, batch)
        else:
            b = batch["tokens"].shape[0]
            mb = b // microbatches
            grads = None
            total = jnp.zeros((), jnp.float32)
            metrics = {"loss": jnp.zeros((), jnp.float32),
                       "moe_aux": jnp.zeros((), jnp.float32)}
            for i in range(microbatches):
                sl = {k: v[i * mb: (i + 1) * mb] for k, v in batch.items()}
                (t_i, m_i), g_i = grad_fn(params, masks, sl)
                total = total + t_i / microbatches
                metrics = {k: metrics[k] + m_i[k] / microbatches for k in metrics}
                grads = g_i if grads is None else jax.tree.map(
                    lambda a, b_: a + b_, grads, g_i)
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        lr = lr_schedule(state["step"])
        new_params, new_opt = adamw_update(
            params, grads, state["opt"], opt_cfg, lr, masks=masks
        )
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        if masks is not None:
            new_state["masks"] = masks
        metrics = dict(metrics)
        metrics["total_loss"] = total
        metrics["lr"] = lr
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    """Inference prefill: forward to logits (no labels, no backward)."""

    def prefill_step(params, batch):
        logits, _ = lm_forward(params, batch, cfg)
        # return only the last position's token to keep outputs small
        return jnp.argmax(logits[:, -1, :], axis=-1)

    return prefill_step


def make_decode_step(cfg: ModelConfig, *, greedy: bool = True) -> Callable:
    """One new token with existing caches (the assigned decode_* cells)."""

    def decode_step(params, caches, batch, cache_len):
        logits, caches = lm_decode(params, caches, batch, cache_len, cfg)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, caches

    return decode_step
