"""Fault-tolerant training loop.

Production behaviors (DESIGN.md §4):
* auto-resume from the latest committed checkpoint (atomic commits — a
  crash mid-save can never corrupt the resume point);
* SIGTERM/SIGINT preemption hook: one final blocking checkpoint before the
  process dies (cloud TPU preemption semantics);
* async checkpointing every ``ckpt_every`` steps (step loop blocks only
  for the device->host snapshot);
* deterministic step-indexed data: restart/elastic-resize replays the
  exact same batch sequence with zero pipeline state;
* straggler monitor: EWMA of step wall-time; steps slower than
  ``straggler_factor`` x EWMA are logged with their step index (on real
  fleets this feeds the controller's replace-node decision);
* elastic restore: checkpoints hold full logical arrays; ``restore`` can
  re-place them onto a different mesh (checkpoint/checkpointer.py).
* optional iterative pruning (paper Alg. 2) between training phases via
  ``IterativePruner`` — the paper's technique as a first-class trainer
  feature.
"""
from __future__ import annotations

import dataclasses
import logging
import signal
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import Checkpointer

logger = logging.getLogger("repro.trainer")

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 1000
    ckpt_every: int = 100
    ckpt_dir: str = "checkpoints"
    keep_ckpts: int = 3
    log_every: int = 10
    straggler_factor: float = 2.5
    ewma_alpha: float = 0.1
    eval_every: int = 0


class Trainer:
    def __init__(
        self,
        step_fn: Callable,
        state: Dict[str, Any],
        batch_fn: Callable[[int], Dict[str, Any]],
        cfg: TrainerConfig,
        *,
        eval_fn: Optional[Callable] = None,
    ):
        self.step_fn = step_fn
        self.state = state
        self.batch_fn = batch_fn
        self.cfg = cfg
        self.eval_fn = eval_fn
        self.ckpt = Checkpointer(cfg.ckpt_dir, keep=cfg.keep_ckpts)
        self._preempted = False
        self._ewma = None
        self.metrics_log: list = []
        self.straggler_events: list = []

    # -- fault tolerance hooks -------------------------------------------------

    def _install_signal_handlers(self):
        def handler(signum, frame):
            logger.warning("preemption signal %s: checkpointing and exiting", signum)
            self._preempted = True

        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # not main thread (tests)

    def resume_if_available(self) -> int:
        latest = self.ckpt.latest_step()
        if latest is None:
            return 0
        self.state = self.ckpt.restore(latest, target=self.state)
        logger.info("resumed from checkpoint step %d", latest)
        return latest

    # -- loop ----------------------------------------------------------------

    def _monitor_step_time(self, step: int, dt: float):
        if self._ewma is None:
            self._ewma = dt
            return
        if dt > self.cfg.straggler_factor * self._ewma and step > 3:
            self.straggler_events.append({"step": step, "dt": dt, "ewma": self._ewma})
            logger.warning(
                "straggler: step %d took %.3fs (EWMA %.3fs, factor %.1f)",
                step, dt, self._ewma, dt / self._ewma,
            )
        a = self.cfg.ewma_alpha
        self._ewma = (1 - a) * self._ewma + a * dt

    def run(self) -> Dict[str, Any]:
        self._install_signal_handlers()
        start = self.resume_if_available()
        step = start
        for step in range(start, self.cfg.total_steps):
            if self._preempted:
                break
            t0 = time.time()
            batch = self.batch_fn(step)
            self.state, metrics = self.step_fn(self.state, batch)
            jax.block_until_ready(metrics["total_loss"])
            dt = time.time() - t0
            self._monitor_step_time(step, dt)

            if self.cfg.log_every and step % self.cfg.log_every == 0:
                row = {k: float(np.asarray(v)) for k, v in metrics.items()}
                row["step"] = step
                row["dt"] = dt
                self.metrics_log.append(row)
                logger.info("step %d loss=%.4f dt=%.3fs", step, row["total_loss"], dt)

            if self.cfg.ckpt_every and (step + 1) % self.cfg.ckpt_every == 0:
                self.ckpt.save_async(step + 1, self.state)

            if self.cfg.eval_every and self.eval_fn and (step + 1) % self.cfg.eval_every == 0:
                self.eval_fn(self.state, step + 1)

        final_step = step + (0 if self._preempted else 1)
        # drain any in-flight async save of this step before the final
        # blocking one — otherwise both writers race on the same .tmp dir
        self.ckpt.wait()
        if self.ckpt.latest_step() != final_step:
            self.ckpt.save(final_step, self.state, blocking=True)
        return {
            "final_step": final_step,
            "preempted": self._preempted,
            "stragglers": self.straggler_events,
            "metrics": self.metrics_log,
        }
