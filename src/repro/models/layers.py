"""Primitive layers: dense, norms, embeddings, rotary (+M-RoPE).

Conventions
-----------
* Params are nested dicts of jnp arrays; leaf names: "kernel", "bias",
  "scale".  Matmul kernels are (in, out) so the pruning structures map
  directly onto (bk, bn) MXU tiles of the (K, N) matmul.
* Matmuls accumulate in fp32 (``preferred_element_type``) and cast back to
  the activation dtype — the TPU-native mixed-precision policy.
* ``matmul`` is the single sparse-execution dispatch point (DESIGN.md §6):
  a kernel leaf may be a dense array *or* a packed ``BSRWeight`` /
  ``BSRPlanes`` (from ``repro.sparse.pack_params``); packed leaves route
  to ``kernels.ops.bsr_matmul`` which skips pruned tiles outright.
* ``logical_constraint`` annotates logical axes; it is a no-op outside a
  mesh/rules context so the same code runs in CPU unit tests.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import BSRPlanes, BSRWeight
from repro.distributed.sharding import logical_constraint
from repro.kernels.ops import (
    Epilogue,
    apply_epilogue,
    bsr_matmul,
    bsr_planes_matmul,
    make_epilogue,
)

__all__ = [
    "matmul", "expert_matmul",
    "dense_init", "dense",
    "rmsnorm_init", "rmsnorm",
    "layernorm_init", "layernorm",
    "embed_init", "embed_lookup", "unembed_logits",
    "rope_frequencies", "apply_rope", "apply_mrope",
    "sinusoidal_positions", "truncated_normal_init",
]


def truncated_normal_init(key, shape, stddev: float, dtype) -> jnp.ndarray:
    x = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * stddev
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------

def dense_init(
    key,
    in_dim: int,
    out_dim: int,
    *,
    use_bias: bool = False,
    dtype=jnp.float32,
    stddev: Optional[float] = None,
) -> Dict[str, jnp.ndarray]:
    stddev = stddev if stddev is not None else 1.0 / math.sqrt(in_dim)
    p = {"kernel": truncated_normal_init(key, (in_dim, out_dim), stddev, dtype)}
    if use_bias:
        p["bias"] = jnp.zeros((out_dim,), dtype)
    return p


def matmul(x: jnp.ndarray, w, *, accum=jnp.float32, epilogue=None) -> jnp.ndarray:
    """x (..., K) @ w (K, N) -> (..., N) in ``accum`` dtype.

    The sparse-execution dispatch point: a packed ``BSRWeight`` routes to
    the zero-skipping BSR kernel (ref on CPU, Pallas on TPU); dense arrays
    take the einsum path.  Everything above (dense/ffn/attention/moe and
    both the forward and decode stacks) is agnostic to which it gets.

    ``epilogue`` (kernels.Epilogue) fuses bias/activation/gate/residual
    into the kernel on the packed path; the dense path applies the same
    fp32 op order on the einsum output, so both paths stay bit-compatible
    with the unfused composition (DESIGN.md §8)."""
    if isinstance(w, BSRWeight):
        return bsr_matmul(x, w, epilogue=epilogue).astype(accum)
    y = jnp.einsum("...k,kn->...n", x, w, preferred_element_type=accum)
    return apply_epilogue(y, epilogue)


def expert_matmul(h: jnp.ndarray, w, *, accum=jnp.float32, epilogue=None) -> jnp.ndarray:
    """Batched expert matmul (g, E, C, d) @ (E, d, f) -> (g, E, C, f).

    ``BSRPlanes`` (flattened per-expert BSR) issue ONE fused zero-skipping
    kernel call over the whole plane stack — no python loop over experts,
    no per-expert output stack; a fully-pruned expert costs only its
    skipped padding slots.  Dense 3-D weights take the batched einsum.
    ``epilogue`` operands (multiplier/residual) are output-shaped
    (g, E, C, f); the packed path transposes them alongside ``h``."""
    if isinstance(w, BSRPlanes):
        he = jnp.moveaxis(h, 1, 0)                            # (E, g, C, d)
        epi = None if epilogue is None else epilogue.map_operands(
            lambda a: jnp.moveaxis(a, 1, 0))
        y = bsr_planes_matmul(he, w, epilogue=epi)
        return jnp.moveaxis(y, 0, 1).astype(accum)            # (g, E, C, f)
    y = jnp.einsum("gecd,edf->gecf", h, w, preferred_element_type=accum)
    return apply_epilogue(y, epilogue)


def dense(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,
    *,
    accum=jnp.float32,
    activation: Optional[str] = None,
    multiplier: Optional[jnp.ndarray] = None,
    residual: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Matmul with selectable accumulation dtype and a fused epilogue.

    ``accum=bfloat16`` on *row-parallel* matmuls (wo, w_down) lets GSPMD
    all-reduce the partial sums in bf16 — halves the dominant TP collective
    bytes (EXPERIMENTS.md §Perf); the MXU still accumulates each partial in
    fp32 internally.

    ``activation``/``multiplier``/``residual`` (plus the layer bias) form
    the fused tail ``act(y + bias) * multiplier + residual`` — one kernel
    on the packed path instead of three (M, N) round-trips."""
    epi = make_epilogue(bias=p.get("bias"), activation=activation,
                        multiplier=multiplier, residual=residual)
    y = matmul(x, p["kernel"], accum=accum, epilogue=epi)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int, dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(p, x: jnp.ndarray, *, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(dim: int, dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    return {"scale": jnp.ones((dim,), dtype), "bias_vec": jnp.zeros((dim,), dtype)}


def layernorm(p, x: jnp.ndarray, *, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias_vec"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding (vocab-parallel)
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, dim: int, dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    return {"embedding": truncated_normal_init(key, (vocab, dim), 1.0, dtype)}


def embed_lookup(p, tokens: jnp.ndarray, dtype=None) -> jnp.ndarray:
    """(B, S) int32 -> (B, S, D).  Table is vocab-sharded on the TP axis;
    GSPMD partitions the gather (partial gather + all-reduce)."""
    table = p["embedding"]
    out = jnp.take(table, tokens, axis=0)
    out = logical_constraint(out, "batch", "seq", "embed")
    return out.astype(dtype or table.dtype)


def unembed_logits(p, x: jnp.ndarray) -> jnp.ndarray:
    """(B, S, D) -> (B, S, V) fp32 logits, vocab-sharded."""
    table = p["embedding"]
    logits = jnp.einsum("bsd,vd->bsv", x, table, preferred_element_type=jnp.float32)
    return logical_constraint(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + multimodal M-RoPE)
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def _rope_rotate(x: jnp.ndarray, sin: jnp.ndarray, cos: jnp.ndarray) -> jnp.ndarray:
    """x (..., dh); sin/cos broadcastable to (..., dh/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, *, theta: float = 10000.0) -> jnp.ndarray:
    """x (B, S, H, dh), positions (B, S) -> rotated x."""
    inv = rope_frequencies(x.shape[-1], theta)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (B, S, dh/2)
    sin, cos = jnp.sin(ang)[:, :, None, :], jnp.cos(ang)[:, :, None, :]
    return _rope_rotate(x, sin, cos)


def apply_mrope(
    x: jnp.ndarray,
    positions: jnp.ndarray,
    sections: Sequence[int],
    *,
    theta: float = 10000.0,
) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.

    positions (B, S, 3) = (temporal, height, width) ids; the dh/2 frequency
    slots are split into ``sections`` (e.g. [16, 24, 24]) and each section
    uses its own position component.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    inv = rope_frequencies(x.shape[-1], theta)  # (half,)
    comp = np.concatenate(
        [np.full(s, i, dtype=np.int32) for i, s in enumerate(sections)]
    )
    pos_per_slot = jnp.take(positions.astype(jnp.float32), jnp.asarray(comp), axis=-1)
    ang = pos_per_slot * inv  # (B, S, half)
    sin, cos = jnp.sin(ang)[:, :, None, :], jnp.cos(ang)[:, :, None, :]
    return _rope_rotate(x, sin, cos)


def sinusoidal_positions(length: int, dim: int) -> jnp.ndarray:
    """Whisper-style fixed sinusoidal embeddings, (length, dim) fp32."""
    pos = np.arange(length)[:, None]
    idx = np.arange(dim // 2)[None, :]
    angle = pos / (10000.0 ** (2 * idx / dim))
    out = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(out, dtype=jnp.float32)
