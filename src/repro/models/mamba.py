"""Selective SSM (Mamba-1) block, TPU-adapted.

Jamba interleaves Mamba blocks with attention 7:1.  The GPU reference
implementation is a fused CUDA scan; the TPU-native formulation here is
*chunked*: the sequence is split into chunks, each chunk runs an exact
associative scan (log-depth, fully unrolled HLO => correct cost analysis),
and a small carry (B, d_inner, d_state) links chunks.  When the chunk
count is small the chunk loop is python-unrolled; above
``CHUNK_UNROLL_LIMIT`` it becomes a ``lax.scan`` whose body cost is
re-counted by the roofline supplement machinery (launch/roofline.py).

Recurrence (diagonal A):
    h_t = exp(dt_t ⊙ A) ⊙ h_{t-1} + (dt_t ⊙ B_t) x_t
    y_t = C_t · h_t + D ⊙ x_t
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_constraint
from .layers import dense, dense_init, truncated_normal_init

__all__ = ["mamba_init", "mamba_apply", "mamba_prefill", "mamba_decode",
           "init_mamba_cache", "CHUNK_UNROLL_LIMIT"]

CHUNK_UNROLL_LIMIT = 4  # above this, chunk loop becomes lax.scan (roofline supplement
                        # counts it); scan bounds live memory to one chunk


def mamba_init(
    key,
    d_model: int,
    *,
    d_inner: Optional[int] = None,
    d_state: int = 16,
    d_conv: int = 4,
    dt_rank: Optional[int] = None,
    dtype=jnp.float32,
) -> Dict:
    d_inner = d_inner or 2 * d_model
    dt_rank = dt_rank or max(d_model // 16, 1)
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32)[None], (d_inner, 1))
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * d_inner, dtype=dtype),
        "conv_kernel": truncated_normal_init(ks[1], (d_conv, d_inner), 0.3, dtype),
        "conv_bias_vec": jnp.zeros((d_inner,), dtype),
        "x_proj": dense_init(ks[2], d_inner, dt_rank + 2 * d_state, dtype=dtype),
        "dt_proj": dense_init(ks[3], dt_rank, d_inner, use_bias=True, dtype=dtype),
        "a_log": jnp.log(a),                       # fp32 SSM scalars (not pruned)
        "d_skip": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[5], d_inner, d_model, dtype=dtype),
    }


def _causal_conv(x: jnp.ndarray, kernel: jnp.ndarray, bias: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv over seq. x (B,S,di), kernel (K,di).

    Returns (y, new_state) with state = last K-1 inputs for decode."""
    k = kernel.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)      # (B, S+K-1, di)
    y = sum(
        xp[:, i: i + x.shape[1]] * kernel[i][None, None].astype(jnp.float32)
        for i in range(k)
    )
    y = y + bias.astype(jnp.float32)
    new_state = xp[:, -(k - 1):]
    return y.astype(x.dtype), new_state


def _ssm_params(p, x):
    """x (B,L,di) -> dt (B,L,di), Bm (B,L,N), Cm (B,L,N), all fp32."""
    d_state = (p["x_proj"]["kernel"].shape[1] - p["dt_proj"]["kernel"].shape[0]) // 2
    proj = dense(p["x_proj"], x).astype(jnp.float32)
    dt_rank = p["dt_proj"]["kernel"].shape[0]
    dt_raw, bm, cm = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("blr,rd->bld", dt_raw, p["dt_proj"]["kernel"].astype(jnp.float32))
        + p["dt_proj"]["bias"].astype(jnp.float32)
    )
    return dt, bm, cm


def _scan_combine(left, right):
    (al, bl), (ar, br) = left, right
    return al * ar, bl * ar + br


def _ssm_chunk(h0, dt, bm, cm, x, a):
    """One chunk of the selective scan (exact, log-depth).

    h0 (B,di,N); dt/x (B,L,di); bm/cm (B,L,N); a (di,N) negative.
    Returns (y (B,L,di) fp32, h_last (B,di,N))."""
    dta = jnp.exp(dt[..., None] * a[None, None])                    # (B,L,di,N)
    dbx = (dt * x)[..., None] * bm[:, :, None, :]                   # (B,L,di,N)
    A_t, B_t = jax.lax.associative_scan(_scan_combine, (dta, dbx), axis=1)
    h = A_t * h0[:, None] + B_t                                     # (B,L,di,N)
    y = jnp.einsum("bldn,bln->bld", h, cm)
    return y, h[:, -1]


def _mamba_forward(
    p: Dict, x: jnp.ndarray, conv_state: Optional[jnp.ndarray],
    ssm_state: Optional[jnp.ndarray], *, chunk: int = 256,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward returning (out, conv_state, ssm_state)."""
    b, s, _ = x.shape
    xz = dense(p["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)                               # (B,S,di)
    xi = logical_constraint(xi, "batch", "seq", "mlp")
    xi, conv_state = _causal_conv(xi, p["conv_kernel"], p["conv_bias_vec"],
                                  state=conv_state)
    xi = jax.nn.silu(xi.astype(jnp.float32)).astype(x.dtype)

    a = -jnp.exp(p["a_log"])                                        # (di,N)
    di, n = a.shape
    chunk = min(chunk, s)
    n_chunks = -(-s // chunk)
    h = ssm_state if ssm_state is not None else jnp.zeros((b, di, n), jnp.float32)

    if n_chunks <= CHUNK_UNROLL_LIMIT or s % chunk != 0:
        ys = []
        for c0 in range(0, s, chunk):
            c1 = min(c0 + chunk, s)
            xc = xi[:, c0:c1].astype(jnp.float32)
            dt, bm, cm = _ssm_params(p, xi[:, c0:c1])
            y, h = _ssm_chunk(h, dt, bm, cm, xc, a)
            ys.append(y)
        y = jnp.concatenate(ys, axis=1)
    else:
        xr = xi.reshape(b, n_chunks, chunk, di).transpose(1, 0, 2, 3)

        @jax.checkpoint
        def body(hc, xc):
            # checkpointed: lax.scan otherwise saves every chunk's scan
            # intermediates for backward — 1.2 TB/dev measured on jamba
            # (EXPERIMENTS.md §Perf J1/J2); recompute costs ~1 extra fwd
            xcf = xc.astype(jnp.float32)
            dt, bm, cm = _ssm_params(p, xc)
            y, hn = _ssm_chunk(hc, dt, bm, cm, xcf, a)
            return hn, y

        h, yr = jax.lax.scan(body, h, xr)
        y = yr.transpose(1, 0, 2, 3).reshape(b, s, di)

    y = y + xi.astype(jnp.float32) * p["d_skip"][None, None]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return dense(p["out_proj"], y.astype(x.dtype)), conv_state, h


def mamba_apply(p: Dict, x: jnp.ndarray, *, chunk: int = 256) -> jnp.ndarray:
    """Training forward, x (B,S,D) -> (B,S,D)."""
    return _mamba_forward(p, x, None, None, chunk=chunk)[0]


def mamba_prefill(p: Dict, x: jnp.ndarray, cache: Dict, *, chunk: int = 256
                  ) -> Tuple[jnp.ndarray, Dict]:
    """Batched prefill: full-sequence forward that also returns the decode
    cache (last K-1 conv inputs + final SSM state)."""
    out, conv_state, h = _mamba_forward(
        p, x, cache["conv"].astype(x.dtype), cache["ssm"], chunk=chunk)
    return out, {"conv": conv_state.astype(cache["conv"].dtype), "ssm": h}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_mamba_cache(batch: int, d_inner: int, d_state: int, d_conv: int,
                     dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    return {
        "conv": jnp.zeros((batch, d_conv - 1, d_inner), dtype),
        "ssm": jnp.zeros((batch, d_inner, d_state), jnp.float32),
    }


def mamba_decode(p: Dict, x: jnp.ndarray, cache: Dict) -> Tuple[jnp.ndarray, Dict]:
    """One-token step. x (B,1,D) -> (y (B,1,D), new cache)."""
    xz = dense(p["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)                               # (B,1,di)
    xi, conv_state = _causal_conv(
        xi, p["conv_kernel"], p["conv_bias_vec"], state=cache["conv"].astype(xi.dtype)
    )
    xi = jax.nn.silu(xi.astype(jnp.float32)).astype(x.dtype)
    dt, bm, cm = _ssm_params(p, xi)                                 # (B,1,·)
    a = -jnp.exp(p["a_log"])
    dta = jnp.exp(dt[..., None] * a[None, None])                    # (B,1,di,N)
    dbx = (dt * xi.astype(jnp.float32))[..., None] * bm[:, :, None, :]
    h = dta[:, 0] * cache["ssm"] + dbx[:, 0]                        # (B,di,N)
    y = jnp.einsum("bdn,bn->bd", h, cm[:, 0])[:, None]              # (B,1,di)
    y = y + xi.astype(jnp.float32) * p["d_skip"][None, None]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = dense(p["out_proj"], y.astype(x.dtype))
    return out, {"conv": conv_state.astype(cache["conv"].dtype), "ssm": h}
