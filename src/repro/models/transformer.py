"""Composable decoder / encoder-decoder stack covering all assigned archs.

A model is a list of ``LayerSpec``s (mixer + mlp per layer) generated from
``ModelConfig`` patterns:

  dense LM        mixer=attn,  mlp=dense
  MoE LM          mixer=attn,  mlp=moe
  jamba           mixer cycles mamba/attn (7:1), mlp cycles dense/moe
  xlstm           mixer cycles mlstm/slstm (7:1), mlp=none
  whisper         encoder (bidir attn+dense) + decoder (causal+cross+dense)
  qwen2-vl        dense LM + M-RoPE + patch-embed stub

Layers are python-unrolled (accurate XLA cost analysis; DESIGN.md §4) and
optionally rematerialized per layer.

Sparse execution: ``lm_forward`` and ``lm_decode`` accept params whose
matmul kernels were packed to BSR by ``repro.sparse.pack_params`` — every
matmul routes through the ``layers.matmul`` dispatch point, so pruned
tiles are skipped on both the prefill and the KV-cache decode paths
(DESIGN.md §6).  Packed leaves are registered pytrees: jit, remat and the
cache mechanics are oblivious to them.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import logical_constraint
from . import attention as attn_mod
from . import mamba as mamba_mod
from . import xlstm as xlstm_mod
from .attention import (
    attention_apply,
    attention_decode,
    attention_init,
    attention_prefill,
    cross_attention_prefill,
    init_kv_cache,
)
from .ffn import mlp_apply, mlp_init
from .layers import (
    dense,
    dense_init,
    embed_init,
    embed_lookup,
    layernorm,
    layernorm_init,
    rmsnorm,
    rmsnorm_init,
    sinusoidal_positions,
    unembed_logits,
)
from .mamba import (
    init_mamba_cache,
    mamba_apply,
    mamba_decode,
    mamba_init,
    mamba_prefill,
)
from .moe import moe_apply, moe_decode, moe_init
from .moe_alltoall import alltoall_available, moe_alltoall_apply
from .xlstm import (
    init_mlstm_cache,
    init_slstm_cache,
    mlstm_apply,
    mlstm_decode,
    mlstm_init,
    mlstm_prefill,
    slstm_apply,
    slstm_decode,
    slstm_init,
    slstm_prefill,
)

__all__ = [
    "LayerSpec", "layer_specs", "init_params", "lm_forward", "lm_decode",
    "lm_prefill", "lm_generate", "init_caches", "encoder_forward",
    "encode_kv_caches", "cross_entropy_loss",
]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str                 # attn | mamba | mlstm | slstm | none
    mlp: str                   # dense | moe | none
    cross_attn: bool = False
    causal: bool = True
    use_rope: bool = True


def layer_specs(cfg: ModelConfig) -> List[LayerSpec]:
    mix = cfg.mixer_pattern or ("attn",)
    mlp = cfg.mlp_pattern or ("dense",)
    return [
        LayerSpec(
            mixer=mix[i % len(mix)],
            mlp=mlp[i % len(mlp)],
            cross_attn=False,
            causal=True,
            use_rope=cfg.use_rope,
        )
        for i in range(cfg.n_layers)
    ]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _accum(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.row_accum_dtype == "bfloat16" else jnp.float32


def _out_seq(cfg: ModelConfig) -> str:
    return "res_seq" if cfg.seq_sharded_acts else "seq"


def _residual(cfg: ModelConfig, x):
    """Megatron-SP: residual stream sharded on seq over the TP axis when
    cfg.seq_sharded_acts — converts the per-layer TP all-reduces into
    all-gather + reduce-scatter pairs (half the wire bytes) and shrinks
    every residual/norm op 16x (EXPERIMENTS.md §Perf)."""
    if cfg.seq_sharded_acts:
        return logical_constraint(x, "batch", "res_seq", "embed")
    return x


def _norm_init(cfg: ModelConfig):
    return layernorm_init(cfg.d_model, cfg.dtype) if cfg.norm_type == "layernorm" \
        else rmsnorm_init(cfg.d_model, cfg.dtype)


def _norm_apply(cfg: ModelConfig, p, x):
    return layernorm(p, x) if cfg.norm_type == "layernorm" else rmsnorm(p, x)


def _init_mixer(key, spec: LayerSpec, cfg: ModelConfig) -> Dict:
    if spec.mixer == "attn":
        p = {
            "attn": attention_init(
                key, cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.head_dim_(),
                qkv_bias=cfg.qkv_bias, dtype=cfg.dtype,
            )
        }
        if spec.cross_attn:
            k2 = jax.random.fold_in(key, 1)
            p["cross"] = attention_init(
                k2, cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.head_dim_(),
                qkv_bias=cfg.qkv_bias, dtype=cfg.dtype,
            )
            p["cross_norm"] = _norm_init(cfg)
        return p
    if spec.mixer == "mamba":
        return {"mamba": mamba_init(
            key, cfg.d_model, d_state=cfg.d_state, d_conv=cfg.d_conv, dtype=cfg.dtype)}
    if spec.mixer == "mlstm":
        return {"mlstm": mlstm_init(
            key, cfg.d_model, cfg.n_heads, proj_factor=cfg.mlstm_proj_factor, dtype=cfg.dtype)}
    if spec.mixer == "slstm":
        return {"slstm": slstm_init(key, cfg.d_model, cfg.n_heads, dtype=cfg.dtype)}
    if spec.mixer == "none":
        return {}
    raise ValueError(f"unknown mixer {spec.mixer}")


def _init_mlp(key, spec: LayerSpec, cfg: ModelConfig) -> Dict:
    if spec.mlp == "dense":
        return {"mlp": mlp_init(
            key, cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp, dtype=cfg.dtype)}
    if spec.mlp == "moe":
        return {"moe": moe_init(
            key, cfg.d_model, cfg.d_ff, cfg.moe_experts, gated=cfg.gated_mlp,
            dtype=cfg.dtype)}
    if spec.mlp == "none":
        return {}
    raise ValueError(f"unknown mlp {spec.mlp}")


def _init_layer(key, spec: LayerSpec, cfg: ModelConfig) -> Dict:
    km, kf = jax.random.split(key)
    p: Dict[str, Any] = {"pre_norm": _norm_init(cfg)}
    p.update(_init_mixer(km, spec, cfg))
    if spec.mlp != "none":
        p["post_norm"] = _norm_init(cfg)
        p.update(_init_mlp(kf, spec, cfg))
    return p


def init_params(key, cfg: ModelConfig) -> Dict:
    keys = jax.random.split(key, cfg.n_layers + 4)
    params: Dict[str, Any] = {
        "embed": embed_init(keys[0], cfg.vocab, cfg.d_model, cfg.dtype),
        "layers": [
            _init_layer(keys[2 + i], spec, cfg)
            for i, spec in enumerate(layer_specs(cfg))
        ],
        "final_norm": _norm_init(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(keys[1], cfg.vocab, cfg.d_model, cfg.dtype)
    if cfg.enc_layers > 0:  # encoder-decoder (whisper)
        ekeys = jax.random.split(keys[-1], cfg.enc_layers + 1)
        enc_spec = LayerSpec(mixer="attn", mlp="dense", causal=False, use_rope=False)
        params["encoder"] = {
            "layers": [_init_layer(ekeys[i], enc_spec, cfg) for i in range(cfg.enc_layers)],
            "final_norm": _norm_init(cfg),
        }
        # decoder layers gain cross-attention
        dec_spec = LayerSpec(mixer="attn", mlp="dense", cross_attn=True,
                             use_rope=cfg.use_rope)
        params["layers"] = [
            _init_layer(keys[2 + i], dec_spec, cfg) for i in range(cfg.n_layers)
        ]
    return params


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------

def _apply_mixer(
    p: Dict, spec: LayerSpec, cfg: ModelConfig, x: jnp.ndarray,
    positions, enc_out: Optional[jnp.ndarray],
    raw_x: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    if spec.mixer == "attn":
        h = attention_apply(
            p["attn"], x,
            num_heads=cfg.n_heads, kv_heads=cfg.kv_heads, head_dim=cfg.head_dim_(),
            positions=positions, causal=spec.causal, window=cfg.window,
            chunk=cfg.attn_chunk, rope_theta=cfg.rope_theta,
            mrope_sections=cfg.mrope_sections, use_rope=spec.use_rope,
            accum=_accum(cfg), out_seq=_out_seq(cfg),
        )
        if spec.cross_attn and enc_out is not None:
            # cross-attn reads the RAW residual + self-attn output (the
            # whisper pre-norm dataflow, and what the decode path does) —
            # not the pre-normed x this function received
            base = raw_x if raw_x is not None else x
            xc = _norm_apply(cfg, p["cross_norm"], base + h)
            hc = attention_apply(
                p["cross"], xc,
                num_heads=cfg.n_heads, kv_heads=cfg.kv_heads, head_dim=cfg.head_dim_(),
                causal=False, chunk=cfg.attn_chunk, kv_input=enc_out, use_rope=False,
            )
            h = h + hc
        return h
    if spec.mixer == "mamba":
        return mamba_apply(p["mamba"], x, chunk=cfg.ssm_chunk)
    if spec.mixer == "mlstm":
        return mlstm_apply(p["mlstm"], x, num_heads=cfg.n_heads, chunk=cfg.ssm_chunk)
    if spec.mixer == "slstm":
        return slstm_apply(p["slstm"], x, num_heads=cfg.n_heads)
    if spec.mixer == "none":
        return jnp.zeros_like(x)
    raise ValueError(spec.mixer)


def _apply_layer(
    p: Dict, spec: LayerSpec, cfg: ModelConfig, x: jnp.ndarray,
    positions, enc_out,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pre-norm residual layer. Returns (x, moe_aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = _apply_mixer(p, spec, cfg, _norm_apply(cfg, p["pre_norm"], x),
                     positions, enc_out, raw_x=x)
    x = _residual(cfg, x + h)
    if spec.mlp == "dense":
        # the residual rides the w_down epilogue (fused on packed params)
        x = _residual(cfg, mlp_apply(
            p["mlp"], _norm_apply(cfg, p["post_norm"], x),
            activation=cfg.activation, accum=_accum(cfg),
            out_seq=_out_seq(cfg), residual=x))
    elif spec.mlp == "moe":
        xn = _norm_apply(cfg, p["post_norm"], x)
        if cfg.moe_impl == "alltoall" and alltoall_available(cfg.moe_experts):
            y, aux = moe_alltoall_apply(
                p["moe"], xn,
                num_experts=cfg.moe_experts, top_k=cfg.moe_top_k,
                capacity_factor=cfg.capacity_factor, activation=cfg.activation,
            )
        else:
            y, aux = moe_apply(
                p["moe"], xn,
                num_experts=cfg.moe_experts, top_k=cfg.moe_top_k,
                capacity_factor=cfg.capacity_factor, activation=cfg.activation,
            )
        x = _residual(cfg, x + y)
    return x, aux


def _remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy, static_argnums=())


def encoder_forward(params: Dict, frames: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Whisper-style encoder over precomputed frame embeddings (stub
    frontend, per assignment).  frames (B, T, D)."""
    x = frames.astype(cfg.adtype)
    pos = sinusoidal_positions(frames.shape[1], cfg.d_model).astype(cfg.adtype)
    x = x + pos[None]
    spec = LayerSpec(mixer="attn", mlp="dense", causal=False, use_rope=False)
    for lp in params["encoder"]["layers"]:
        fn = _remat_wrap(
            lambda p, y: _apply_layer(p, spec, cfg, y, None, None)[0], cfg)
        x = fn(lp, x)
    return _norm_apply(cfg, params["encoder"]["final_norm"], x)


def lm_forward(
    params: Dict,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Forward to fp32 logits.  batch keys: tokens (B,S) [, positions,
    patch_embeds, frames]."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed_lookup(params["embed"], tokens, dtype=cfg.adtype)

    if cfg.num_patches > 0 and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(cfg.adtype)     # (B, P, D)
        x = jnp.concatenate([pe, x[:, pe.shape[1]:]], axis=1)

    positions = batch.get("positions")
    if positions is None:
        if cfg.mrope_sections is not None:
            positions = jnp.broadcast_to(jnp.arange(s)[None, :, None], (b, s, 3))
        else:
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    enc_out = None
    if cfg.enc_layers > 0:
        enc_out = encoder_forward(params, batch["frames"], cfg)

    x = logical_constraint(x, "batch", "seq", "embed")
    aux_total = jnp.zeros((), jnp.float32)
    specs = layer_specs(cfg) if cfg.enc_layers == 0 else [
        LayerSpec(mixer="attn", mlp="dense", cross_attn=True, use_rope=cfg.use_rope)
    ] * cfg.n_layers
    for lp, spec in zip(params["layers"], specs):
        fn = _remat_wrap(
            functools.partial(_apply_layer, spec=spec, cfg=cfg), cfg)
        x, aux = fn(lp, x=x, positions=positions, enc_out=enc_out)
        aux_total = aux_total + aux

    x = _norm_apply(cfg, params["final_norm"], x)
    head = params.get("lm_head", params["embed"])
    logits = unembed_logits(head, x)
    if cfg.logits_softcap:
        logits = cfg.logits_softcap * jnp.tanh(logits / cfg.logits_softcap)
    return logits, {"moe_aux": aux_total}


def cross_entropy_loss(
    logits: jnp.ndarray, labels: jnp.ndarray, *, z_loss: float = 1e-4
) -> jnp.ndarray:
    """Token-mean xent over vocab-sharded fp32 logits + z-loss.

    The label logit is extracted with a one-hot reduction, NOT
    take_along_axis: a gather over the vocab-sharded dim would all-gather
    the full logits (10 GB/step/device at qwen scale — measured in §Perf);
    the one-hot multiply-reduce keeps the vocab dim sharded and lowers to a
    partial sum + tiny all-reduce."""
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = labels[..., None] == jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, logits.shape[-1]), 2
    )
    ll = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    loss = jnp.mean(lse - ll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss


# ---------------------------------------------------------------------------
# Decode (serve path)
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16
                ) -> List[Dict]:
    caches: List[Dict] = []
    specs = layer_specs(cfg)
    for spec in specs:
        if cfg.enc_layers > 0:
            spec = LayerSpec(mixer="attn", mlp="dense", cross_attn=True,
                             use_rope=cfg.use_rope)
        if spec.mixer == "attn":
            alloc = max_len if cfg.window is None else min(max_len, cfg.window)
            c = init_kv_cache(batch, alloc, cfg.kv_heads, cfg.head_dim_(), dtype)
            if cfg.enc_layers > 0:
                c["cross_k"] = jnp.zeros(
                    (batch, cfg.enc_frames, cfg.kv_heads, cfg.head_dim_()), dtype)
                c["cross_v"] = jnp.zeros_like(c["cross_k"])
            caches.append(c)
        elif spec.mixer == "mamba":
            caches.append(init_mamba_cache(batch, 2 * cfg.d_model, cfg.d_state,
                                           cfg.d_conv, dtype))
        elif spec.mixer == "mlstm":
            d_in = int(cfg.mlstm_proj_factor * cfg.d_model)
            d_in -= d_in % cfg.n_heads
            caches.append(init_mlstm_cache(batch, cfg.n_heads, d_in // cfg.n_heads))
        elif spec.mixer == "slstm":
            caches.append(init_slstm_cache(batch, cfg.d_model))
        else:
            caches.append({})
    return caches


def encode_kv_caches(params: Dict, enc_out: jnp.ndarray, cfg: ModelConfig,
                     caches: List[Dict]) -> List[Dict]:
    """Precompute encoder K/V for decoder cross-attention (whisper)."""
    from .attention import _split_heads  # local: private helper

    for lp, c in zip(params["layers"], caches):
        k = _split_heads(dense(lp["cross"]["wk"], enc_out), cfg.kv_heads)
        v = _split_heads(dense(lp["cross"]["wv"], enc_out), cfg.kv_heads)
        c["cross_k"] = k.astype(c["cross_k"].dtype)
        c["cross_v"] = v.astype(c["cross_v"].dtype)
    return caches


def lm_decode(
    params: Dict,
    caches: List[Dict],
    batch: Dict[str, jnp.ndarray],
    cache_len: jnp.ndarray,
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, List[Dict]]:
    """One-token decode. batch["tokens"] (B, 1). Returns (logits, caches).

    ``cache_len`` is a scalar or per-row ``(B,)`` vector (ragged prompts).
    With ``batch["page_tables"]`` (B, max_pages) the attention caches are
    page pools — ``(num_pages, page_size, K, dh)`` — and every self-attn
    layer reads/writes through the tables (DESIGN.md §9)."""
    tokens = batch["tokens"]
    b = tokens.shape[0]
    page_tables = batch.get("page_tables")
    x = embed_lookup(params["embed"], tokens, dtype=cfg.adtype)
    x = logical_constraint(x, "batch", None, "embed")

    specs = layer_specs(cfg)
    if cfg.enc_layers > 0:
        specs = [LayerSpec(mixer="attn", mlp="dense", cross_attn=True,
                           use_rope=cfg.use_rope)] * cfg.n_layers

    new_caches: List[Dict] = []
    for lp, spec, cache in zip(params["layers"], specs, caches):
        h_in = _norm_apply(cfg, lp["pre_norm"], x)
        if spec.mixer == "attn":
            h, cache2 = attention_decode(
                lp["attn"], h_in, {"k": cache["k"], "v": cache["v"]}, cache_len,
                num_heads=cfg.n_heads, kv_heads=cfg.kv_heads,
                head_dim=cfg.head_dim_(), window=cfg.window,
                rope_theta=cfg.rope_theta, mrope_sections=cfg.mrope_sections,
                use_rope=spec.use_rope, page_table=page_tables,
                paged_impl=cfg.paged_attn_impl,
            )
            cache = {**cache, **cache2}
            if spec.cross_attn:
                xc = _norm_apply(cfg, lp["cross_norm"], x + h)
                enc_len = jnp.asarray(cache["cross_k"].shape[1], jnp.int32)
                hc, _ = attention_decode(
                    lp["cross"], xc,
                    {"k": cache["cross_k"], "v": cache["cross_v"]}, enc_len,
                    num_heads=cfg.n_heads, kv_heads=cfg.kv_heads,
                    head_dim=cfg.head_dim_(), update_cache=False,
                )
                h = h + hc
        elif spec.mixer == "mamba":
            h, cache = mamba_decode(lp["mamba"], h_in, cache)
        elif spec.mixer == "mlstm":
            h, cache = mlstm_decode(lp["mlstm"], h_in, cache, num_heads=cfg.n_heads)
        elif spec.mixer == "slstm":
            h, cache = slstm_decode(lp["slstm"], h_in, cache, num_heads=cfg.n_heads)
        else:
            h = jnp.zeros_like(x)
        x = x + h
        if spec.mlp == "dense":
            x = mlp_apply(lp["mlp"], _norm_apply(cfg, lp["post_norm"], x),
                          activation=cfg.activation, residual=x)
        elif spec.mlp == "moe":
            y, _ = moe_decode(lp["moe"], _norm_apply(cfg, lp["post_norm"], x),
                              num_experts=cfg.moe_experts, top_k=cfg.moe_top_k,
                              activation=cfg.activation)
            x = x + y
        new_caches.append(cache)

    x = _norm_apply(cfg, params["final_norm"], x)
    head = params.get("lm_head", params["embed"])
    logits = unembed_logits(head, x)
    if cfg.logits_softcap:
        # keep decode logits consistent with lm_forward/lm_prefill —
        # sampling inside lm_generate sees the same capped distribution
        logits = cfg.logits_softcap * jnp.tanh(logits / cfg.logits_softcap)
    return logits, new_caches


# ---------------------------------------------------------------------------
# Serving hot path: batched prefill + on-device decode loop (DESIGN.md §7)
# ---------------------------------------------------------------------------

def lm_prefill(
    params: Dict,
    caches: List[Dict],
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    *,
    start_pos: int = 0,
) -> Tuple[jnp.ndarray, List[Dict]]:
    """Cache-filling batched prefill: one `lm_forward`-style pass over the
    whole prompt that also fills every KV/SSM cache, replacing
    ``prompt_len`` sequential decode steps.  batch["tokens"] (B, S).
    Returns (fp32 logits (B, S, V), caches ready for ``cache_len=S``).

    With ``batch["page_tables"]`` (B, max_pages) the attention caches
    are page pools — ``(num_pages, page_size, K, dh)`` — and every
    self-attn layer scatters its prompt K/V straight into the pages the
    rows own (paged prefill, DESIGN.md §10); recurrent and cross-attn
    caches are unaffected.

    ``start_pos`` (static, paged-only) runs a *tail-only* prefill for a
    prefix-cache hit (DESIGN.md §12): ``batch["tokens"]`` holds only the
    uncached suffix, which sits at logical positions
    ``[start_pos, start_pos+S)``; the first ``start_pos`` tokens' K/V
    already live in shared prefix pages mapped into the rows' tables.
    Attention-only stacks only — a recurrent mixer's state cannot be
    resumed from pages it never saw.

    Runs unchanged on packed (BSR) params — every matmul routes through
    the ``layers.matmul`` / ``layers.expert_matmul`` dispatch points."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    page_tables = batch.get("page_tables")
    x = embed_lookup(params["embed"], tokens, dtype=cfg.adtype)

    if cfg.num_patches > 0 and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(cfg.adtype)
        x = jnp.concatenate([pe, x[:, pe.shape[1]:]], axis=1)

    positions = batch.get("positions")
    if positions is None:
        pos1 = jnp.arange(start_pos, start_pos + s)
        if cfg.mrope_sections is not None:
            positions = jnp.broadcast_to(pos1[None, :, None], (b, s, 3))
        else:
            positions = jnp.broadcast_to(pos1[None], (b, s))

    specs = layer_specs(cfg)
    if cfg.enc_layers > 0:
        specs = [LayerSpec(mixer="attn", mlp="dense", cross_attn=True,
                           use_rope=cfg.use_rope)] * cfg.n_layers
    if start_pos:
        bad = sorted({sp.mixer for sp in specs if sp.mixer != "attn"})
        if page_tables is None:
            raise ValueError(
                "lm_prefill: start_pos > 0 needs page_tables — the cached "
                "prefix lives in shared pool pages (DESIGN.md §12)")
        if bad or cfg.enc_layers > 0:
            raise ValueError(
                "lm_prefill: start_pos > 0 needs an attention-only stack — "
                f"recurrent/cross-attn mixers ({bad or ['cross-attn']}) carry "
                "state the cached pages do not hold")

    # mirrors _apply_layer (which cannot thread caches) — keep residual
    # sharding, out_seq and the MoE impl dispatch in sync with it
    x = logical_constraint(x, "batch", "seq", "embed")
    new_caches: List[Dict] = []
    for lp, spec, cache in zip(params["layers"], specs, caches):
        h_in = _norm_apply(cfg, lp["pre_norm"], x)
        if spec.mixer == "attn":
            h, cache = attention_prefill(
                lp["attn"], h_in, cache,
                num_heads=cfg.n_heads, kv_heads=cfg.kv_heads,
                head_dim=cfg.head_dim_(), positions=positions,
                window=cfg.window, chunk=cfg.attn_chunk,
                rope_theta=cfg.rope_theta, mrope_sections=cfg.mrope_sections,
                use_rope=spec.use_rope, accum=_accum(cfg),
                out_seq=_out_seq(cfg), page_table=page_tables,
                paged_impl=cfg.paged_attn_impl, start_pos=start_pos,
            )
            if spec.cross_attn:
                xc = _norm_apply(cfg, lp["cross_norm"], x + h)
                hc = cross_attention_prefill(
                    lp["cross"], xc, cache,
                    num_heads=cfg.n_heads, kv_heads=cfg.kv_heads,
                    head_dim=cfg.head_dim_(), chunk=cfg.attn_chunk,
                )
                h = h + hc
        elif spec.mixer == "mamba":
            h, cache = mamba_prefill(lp["mamba"], h_in, cache, chunk=cfg.ssm_chunk)
        elif spec.mixer == "mlstm":
            h, cache = mlstm_prefill(lp["mlstm"], h_in, cache,
                                     num_heads=cfg.n_heads, chunk=cfg.ssm_chunk)
        elif spec.mixer == "slstm":
            h, cache = slstm_prefill(lp["slstm"], h_in, cache,
                                     num_heads=cfg.n_heads)
        else:
            h = jnp.zeros_like(x)
        x = _residual(cfg, x + h)
        if spec.mlp == "dense":
            # keep in sync with _apply_layer: residual fused into w_down
            x = _residual(cfg, mlp_apply(
                lp["mlp"], _norm_apply(cfg, lp["post_norm"], x),
                activation=cfg.activation, accum=_accum(cfg),
                out_seq=_out_seq(cfg), residual=x))
        elif spec.mlp == "moe":
            xn = _norm_apply(cfg, lp["post_norm"], x)
            if cfg.moe_impl == "alltoall" and alltoall_available(cfg.moe_experts):
                y, _ = moe_alltoall_apply(
                    lp["moe"], xn,
                    num_experts=cfg.moe_experts, top_k=cfg.moe_top_k,
                    capacity_factor=cfg.capacity_factor,
                    activation=cfg.activation)
            else:
                y, _ = moe_apply(
                    lp["moe"], xn,
                    num_experts=cfg.moe_experts, top_k=cfg.moe_top_k,
                    capacity_factor=cfg.capacity_factor,
                    activation=cfg.activation)
            x = _residual(cfg, x + y)
        new_caches.append(cache)

    x = _norm_apply(cfg, params["final_norm"], x)
    head = params.get("lm_head", params["embed"])
    logits = unembed_logits(head, x)
    if cfg.logits_softcap:
        logits = cfg.logits_softcap * jnp.tanh(logits / cfg.logits_softcap)
    return logits, new_caches


def _nucleus_filter(logits: jnp.ndarray, top_p: float) -> jnp.ndarray:
    """Top-p (nucleus) mask: keep the smallest prefix of the
    probability-sorted vocab whose mass reaches ``top_p`` (always at
    least the top-1 token); everything else goes to -inf.

    The keep set is decided *positionally* in sorted order and scattered
    back through the inverse permutation — comparing against the
    threshold logit value would keep every token tied at the threshold,
    letting the kept mass blow well past ``top_p`` on tied logits.  Ties
    break by sorted position (stable sort: lowest vocab id first)."""
    order = jnp.argsort(-logits, axis=-1)                   # descending, stable
    srt = jnp.take_along_axis(logits, order, axis=-1)
    probs = jax.nn.softmax(srt, axis=-1)
    # a token stays if the mass strictly *before* it is < top_p (>=1 kept)
    keep_sorted = (jnp.cumsum(probs, axis=-1) - probs) < top_p
    inv = jnp.argsort(order, axis=-1)                       # undo the sort
    keep = jnp.take_along_axis(keep_sorted, inv, axis=-1)
    return jnp.where(keep, logits, -jnp.inf)


def _select_token(
    logits: jnp.ndarray,            # (B, V) fp32
    rng: jnp.ndarray,
    *,
    temperature: float,
    top_k: Optional[int],
    top_p: Optional[float],
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Greedy argmax (temperature <= 0) or filtered sampling — all on
    device.  Returns ((B,) int32 tokens, advanced rng)."""
    if not temperature or temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), rng
    lg = logits.astype(jnp.float32) / temperature
    if top_k is not None and 0 < top_k < lg.shape[-1]:
        # positional keep set, like _nucleus_filter: comparing against the
        # k-th *value* would keep every logit tied at it (>> k tokens on a
        # tie plateau); ranks break ties by vocab id (stable sort)
        ranks = jnp.argsort(jnp.argsort(-lg, axis=-1), axis=-1)
        lg = jnp.where(ranks < top_k, lg, -jnp.inf)
    if top_p is not None and top_p < 1.0:
        lg = _nucleus_filter(lg, top_p)
    rng, sub = jax.random.split(rng)
    return jax.random.categorical(sub, lg, axis=-1).astype(jnp.int32), rng


def _select_token_rows(
    logits: jnp.ndarray,            # (B, V) fp32
    rngs: jnp.ndarray,              # (B, 2) uint32 per-row keys
    temperature: jnp.ndarray,       # (B,) fp32; <= 0 rows are greedy
    top_k: jnp.ndarray,             # (B,) int32; <= 0 disables the filter
    top_p: jnp.ndarray,             # (B,) fp32; >= 1 disables the filter
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row token selection with *traced* ``(B,)`` sampling params —
    the continuous-batching analogue of :func:`_select_token`, where
    co-batched requests each carry their own temperature/top-k/top-p.

    Row semantics match ``_select_token`` **bitwise** for the same scalar
    params: disabled filters select the *unfiltered* logits (not a
    filtered copy that merely looks equivalent), greedy rows never
    advance their rng, and sampled rows split exactly once per call — so
    a request's stream is independent of what its co-batch is doing.
    Returns ((B,) int32 tokens, advanced per-row rngs)."""
    v = logits.shape[-1]

    def row(lg, rng, t, k, p):
        greedy = jnp.argmax(lg).astype(jnp.int32)
        scaled = lg / jnp.where(t > 0.0, t, 1.0)
        # rank-based top-k keep set (ties break by vocab id, like
        # _select_token); k outside (0, V) keeps every rank
        ranks = jnp.argsort(jnp.argsort(-scaled))
        kk = jnp.where((k > 0) & (k < v), k, v)
        lk = jnp.where(ranks < kk, scaled, -jnp.inf)
        lp = jnp.where(p < 1.0, _nucleus_filter(lk[None], p)[0], lk)
        rng2, sub = jax.random.split(rng)
        sampled = jax.random.categorical(sub, lp).astype(jnp.int32)
        tok = jnp.where(t > 0.0, sampled, greedy)
        return tok, jnp.where(t > 0.0, rng2, rng)

    return jax.vmap(row)(
        logits.astype(jnp.float32), rngs,
        jnp.asarray(temperature, jnp.float32),
        jnp.asarray(top_k, jnp.int32), jnp.asarray(top_p, jnp.float32))


def lm_generate(
    params: Dict,
    caches: List[Dict],
    first_token: jnp.ndarray,       # (B, 1) int32 — usually argmax of prefill
    start_len: jnp.ndarray,         # scalar or (B,) int32: tokens in cache
    num_tokens: int,                # static: tokens to emit
    cfg: ModelConfig,
    *,
    temperature: float = 0.0,       # <= 0: greedy argmax
    top_k: Optional[int] = None,
    top_p: Optional[float] = None,
    eos_id: Optional[int] = None,
    key: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, List[Dict]]:
    """On-device decode loop: ``num_tokens`` steps in ONE ``jax.lax.scan``
    — the caches ride the carry and token selection (greedy argmax, or
    temperature/top-k/top-p sampling with ``key``) happens on device, so
    there is zero host transfer per generated token.

    ``eos_id`` turns on EOS handling *inside* the scan: per-sequence
    ``done`` flags ride the carry, finished rows keep emitting ``eos_id``,
    and once every row is done the decode step body is skipped via
    ``lax.cond`` (the carry passes through untouched) — early exit without
    a single host sync.

    ``start_len`` may be per-row ``(B,)`` for ragged (right-padded)
    prompts: each row continues from its own prompt length — rope
    positions, cache writes and attention masks all stay per-row, so no
    row ever attends over another row's padding slots.

    Emits the running token *before* each decode step (so
    ``tokens[:, 0] == first_token``), matching the per-token serve loop it
    replaces.  Returns (tokens (B, num_tokens) int32, caches)."""
    start_len = jnp.asarray(start_len, jnp.int32)
    b = first_token.shape[0]
    select = functools.partial(
        _select_token, temperature=temperature, top_k=top_k, top_p=top_p)
    rng0 = key if key is not None else jax.random.PRNGKey(0)

    def live_step(i, operand):
        tok, rng, cs = operand
        logits, cs = lm_decode(params, cs, {"tokens": tok}, start_len + i, cfg)
        nxt, rng = select(logits[:, -1], rng)
        return nxt[:, None], rng, cs

    def step(carry, i):
        tok, done, rng, cs = carry
        emit = tok[:, 0]
        if eos_id is not None:
            done = done | (emit == eos_id)
            # mask-and-carry: skip the whole decode step once every row
            # is finished; finished rows keep emitting eos_id
            nxt, rng, cs = jax.lax.cond(
                jnp.all(done), lambda op: op, functools.partial(live_step, i),
                (tok, rng, cs))
            nxt = jnp.where(done[:, None], jnp.asarray(eos_id, jnp.int32), nxt)
        else:
            nxt, rng, cs = live_step(i, (tok, rng, cs))
        return (nxt, done, rng, cs), emit

    carry0 = (first_token.astype(jnp.int32), jnp.zeros((b,), bool),
              rng0, caches)
    (_, _, _, caches), toks = jax.lax.scan(
        step, carry0, jnp.arange(num_tokens, dtype=jnp.int32),
    )
    return jnp.moveaxis(toks, 0, 1), caches
