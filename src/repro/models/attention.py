"""Attention: GQA / MHA / sliding-window, chunked (flash-style) training
path, KV-cache decode path (flash-decode compatible sharding).

Training uses a *statically chunked* causal attention: an unrolled loop
over query chunks, each attending to keys `[lo, hi)` where the bounds are
python ints — so (i) peak memory is O(S·chunk) not O(S²), (ii) sliding
windows skip out-of-range KV chunks entirely (real FLOP savings, visible
in the roofline terms), (iii) XLA's cost analysis sees every chunk
(no while-loop undercount; see DESIGN.md §4).

Decode attends a single query over the whole cache with fp32 softmax.  For
``long_500k`` the cache's *sequence* dim is sharded ("kv_seq" logical
axis); the softmax over the sharded axis lowers to the flash-decode
partial-stats + all-reduce pattern under GSPMD.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.packing import BSRWeight
from repro.distributed.sharding import logical_constraint
from repro.kernels.ops import paged_attention_decode as _paged_decode_op
from repro.kernels.ops import paged_attention_prefill as _paged_prefill_op
from .layers import apply_mrope, apply_rope, dense, dense_init

__all__ = [
    "attention_init",
    "attention_apply",
    "attention_prefill",
    "attention_decode",
    "cross_attention_prefill",
    "chunked_causal_attention",
    "full_attention",
    "init_kv_cache",
]

NEG_INF = -1e30


def attention_init(
    key,
    d_model: int,
    num_heads: int,
    kv_heads: int,
    head_dim: int,
    *,
    qkv_bias: bool = False,
    out_bias: bool = False,
    dtype=jnp.float32,
) -> Dict:
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d_model, num_heads * head_dim, use_bias=qkv_bias, dtype=dtype),
        "wk": dense_init(ks[1], d_model, kv_heads * head_dim, use_bias=qkv_bias, dtype=dtype),
        "wv": dense_init(ks[2], d_model, kv_heads * head_dim, use_bias=qkv_bias, dtype=dtype),
        "wo": dense_init(
            ks[3], num_heads * head_dim, d_model, use_bias=out_bias, dtype=dtype,
            stddev=1.0 / math.sqrt(num_heads * head_dim),
        ),
    }


def _split_heads(x: jnp.ndarray, heads: int) -> jnp.ndarray:
    b, s, hd = x.shape
    return x.reshape(b, s, heads, hd // heads)


def _gqa_scores(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """q (B,Sq,K,G,dh), k (B,Sk,K,dh) -> (B,K,G,Sq,Sk) fp32."""
    return jnp.einsum("bqkgd,bskd->bkgqs", q, k, preferred_element_type=jnp.float32)


def _gqa_values(w: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """w (B,K,G,Sq,Sk) fp32, v (B,Sk,K,dh) -> (B,Sq,K,G,dh)."""
    return jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))


def chunked_causal_attention(
    q: jnp.ndarray,           # (B, S, H, dh) — already rotated
    k: jnp.ndarray,           # (B, S, K, dh)
    v: jnp.ndarray,           # (B, S, K, dh)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    chunk: int = 512,
    q_offset: int = 0,        # absolute position of q[0] (cross-chunk prefill)
) -> jnp.ndarray:
    b, s, h, dh = q.shape
    kv_heads = k.shape[2]
    g = h // kv_heads
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(b, s, kv_heads, g, dh)
    sk = k.shape[1]
    chunk = min(chunk, s)
    out = []
    for qs in range(0, s, chunk):
        qe = min(qs + chunk, s)
        abs_qs, abs_qe = qs + q_offset, qe + q_offset
        hi = min(abs_qe, sk) if causal else sk
        lo = 0 if window is None else max(0, abs_qs - window + 1)
        if hi <= lo:
            out.append(jnp.zeros((b, qe - qs, kv_heads, g, dh), q.dtype))
            continue
        kc, vc = k[:, lo:hi], v[:, lo:hi]
        scores = _gqa_scores(qg[:, qs:qe], kc) * scale  # (B,K,G,q,kv)
        if causal or window is not None:
            qpos = jnp.arange(abs_qs, abs_qe)[:, None]
            kpos = jnp.arange(lo, hi)[None, :]
            mask = jnp.ones((qe - qs, hi - lo), bool)
            if causal:
                mask &= kpos <= qpos
            if window is not None:
                mask &= kpos > qpos - window
            scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        out.append(_gqa_values(w, vc).astype(q.dtype))
    return jnp.concatenate(out, axis=1).reshape(b, s, h, dh)


def full_attention(q, k, v, *, causal=True, window=None):
    """Unchunked oracle (tests)."""
    return chunked_causal_attention(q, k, v, causal=causal, window=window, chunk=q.shape[1])


def attention_apply(
    p: Dict,
    x: jnp.ndarray,                       # (B, S, D)
    *,
    num_heads: int,
    kv_heads: int,
    head_dim: int,
    positions: Optional[jnp.ndarray] = None,
    causal: bool = True,
    window: Optional[int] = None,
    chunk: int = 512,
    rope_theta: float = 10000.0,
    mrope_sections: Optional[Tuple[int, ...]] = None,
    kv_input: Optional[jnp.ndarray] = None,   # cross-attention source
    use_rope: bool = True,
    accum=None,
    out_seq: str = "seq",
) -> jnp.ndarray:
    accum = accum or jnp.float32
    b, s, _ = x.shape
    src = kv_input if kv_input is not None else x
    q = _split_heads(dense(p["wq"], x), num_heads)
    k = _split_heads(dense(p["wk"], src), kv_heads)
    v = _split_heads(dense(p["wv"], src), kv_heads)
    q = logical_constraint(q, "batch", "seq", "heads", None)
    k = logical_constraint(k, "batch", "seq", "kv", None)
    v = logical_constraint(v, "batch", "seq", "kv", None)
    if use_rope:
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        if mrope_sections is not None:
            q = apply_mrope(q, positions, mrope_sections, theta=rope_theta)
            k = apply_mrope(k, positions, mrope_sections, theta=rope_theta)
        else:
            q = apply_rope(q, positions, theta=rope_theta)
            k = apply_rope(k, positions, theta=rope_theta)
    o = chunked_causal_attention(q, k, v, causal=causal, window=window, chunk=chunk)
    o = logical_constraint(o, "batch", "seq", "heads", None)
    out = _wo_project(p, o, num_heads, head_dim, accum, x.dtype)
    return logical_constraint(out, "batch", out_seq, "embed")


def _wo_project(p: Dict, o: jnp.ndarray, num_heads: int, head_dim: int,
                accum, dtype) -> jnp.ndarray:
    """Output projection for (B, S, H, dh) attention values."""
    b, s = o.shape[:2]
    if "bias" not in p["wo"] and not isinstance(p["wo"]["kernel"], BSRWeight):
        # contract (heads, dh) via a kernel-side reshape: reshaping the
        # *activation* (B,S,H,dh)->(B,S,H*dh) merges the heads-sharded dim
        # with dh and forces a full all-gather fwd+bwd (32 GB/step measured
        # on qwen/train_4k — EXPERIMENTS.md §Perf P5); the kernel reshape
        # is tile-aligned (whole heads per shard) and free.  A packed BSR
        # kernel has no dense (H*dh, D) view, so it takes the dispatch
        # path below — serving-only, where the all-gather concern is moot.
        w3 = p["wo"]["kernel"].reshape(num_heads, head_dim, -1)
        return jnp.einsum("bshd,hde->bse", o, w3,
                          preferred_element_type=accum).astype(dtype)
    return dense(p["wo"], o.reshape(b, s, num_heads * head_dim), accum=accum)


def attention_prefill(
    p: Dict,
    x: jnp.ndarray,                       # (B, S, D)
    cache: Dict[str, jnp.ndarray],
    *,
    num_heads: int,
    kv_heads: int,
    head_dim: int,
    positions: Optional[jnp.ndarray] = None,
    window: Optional[int] = None,
    chunk: int = 512,
    rope_theta: float = 10000.0,
    mrope_sections: Optional[Tuple[int, ...]] = None,
    use_rope: bool = True,
    accum=None,
    out_seq: str = "seq",
    page_table: Optional[jnp.ndarray] = None,   # (B, max_pages) -> pool ids
    paged_impl: str = "fused",                  # fused (page walk) | gather
    start_pos: int = 0,                         # static logical pos of x[:, 0]
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Batched causal prefill that also fills the KV cache.

    Runs the full-sequence attention (identical math to
    ``attention_apply``) and writes the (rotated) K/V for positions
    ``[0, S)`` into the cache so decode can continue at ``cache_len=S``.
    With a sliding-window ring cache (alloc <= window) only the last
    ``alloc`` tokens are kept, each at slot ``t % alloc`` — the same
    placement the per-token decode writes produce.

    With ``page_table`` the cache is a ``(num_pages, page_size, K, dh)``
    pool (DESIGN.md §9/§10): token ``t`` of row ``b`` is scattered
    straight into ``pool[table[b, t // ps], t % ps]``, then attention
    runs *over the pages themselves* with the fused bm-tiled page-walk
    kernel (kernels/paged_attention.py, DESIGN.md §11) — no contiguous
    logical view is ever materialized.  ``paged_impl="gather"`` keeps
    the legacy path for differential tests.  Ring (SWA) caches are not
    paged.

    ``start_pos`` (static, paged-only) runs a *tail-only* prefill: the
    tokens in ``x`` sit at logical positions ``[start_pos, start_pos+S)``
    and the first ``start_pos`` positions are already in the pool —
    shared prefix pages mapped into this row's table by the prefix cache
    (DESIGN.md §12).  K/V scatter at the offset slots and attention
    covers the full ``start_pos + S`` context."""
    accum = accum or jnp.float32
    if page_table is not None and window is not None:
        raise NotImplementedError(
            "attention_prefill: sliding-window attention over a paged KV "
            f"cache is not implemented (window={window} with page_table) — "
            "SWA uses contiguous ring caches (DESIGN.md §9); drop the "
            "window or use a contiguous cache")
    if paged_impl not in ("fused", "gather"):
        raise ValueError(f"unknown paged_impl {paged_impl!r}")
    if start_pos and page_table is None:
        raise ValueError(
            "attention_prefill: start_pos > 0 needs a page_table — the "
            "prefix lives in pool pages, a contiguous cache has no shared "
            "prefix to resume from (DESIGN.md §12)")
    b, s, _ = x.shape
    q = _split_heads(dense(p["wq"], x), num_heads)
    k = _split_heads(dense(p["wk"], x), kv_heads)
    v = _split_heads(dense(p["wv"], x), kv_heads)
    if use_rope:
        if positions is None:
            positions = jnp.broadcast_to(
                jnp.arange(start_pos, start_pos + s)[None], (b, s))
        if mrope_sections is not None:
            if positions.ndim == 2:
                positions = jnp.tile(positions[..., None], (1, 1, 3))
            q = apply_mrope(q, positions, mrope_sections, theta=rope_theta)
            k = apply_mrope(k, positions, mrope_sections, theta=rope_theta)
        else:
            q = apply_rope(q, positions, theta=rope_theta)
            k = apply_rope(k, positions, theta=rope_theta)

    alloc = cache["k"].shape[1]
    kc, vc = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)
    if page_table is not None:
        ps = cache["k"].shape[1]
        t = jnp.arange(start_pos, start_pos + s)
        pid = page_table[:, t // ps]                   # (B, S) pool pages
        off = jnp.broadcast_to(t % ps, (b, s))
        ck = cache["k"].at[pid, off].set(kc)
        cv = cache["v"].at[pid, off].set(vc)
        total = start_pos + s                          # full logical context
        if paged_impl == "fused":
            # attend straight over the pages: the fused kernel walks this
            # row's table from logical position 0 — covering shared
            # prefix pages this call never wrote — so other sequences'
            # pages (and unallocated ones) are never touched
            o = _paged_prefill_op(
                q, ck, cv, page_table, jnp.full((b,), total, jnp.int32),
                bm=min(chunk, s), q_offset=start_pos).astype(x.dtype)
        elif start_pos:
            # gather path with a prefix: materialize the logical view up
            # to the full context (every position < total is live), then
            # run the contiguous kernel with the query offset
            mp = page_table.shape[1]
            kv = ck[page_table].reshape(b, mp * ps, kv_heads, head_dim)
            vv = cv[page_table].reshape(b, mp * ps, kv_heads, head_dim)
            o = chunked_causal_attention(
                q, kv[:, :total].astype(q.dtype), vv[:, :total].astype(q.dtype),
                causal=True, window=None, chunk=chunk, q_offset=start_pos)
        else:
            o = chunked_causal_attention(q, k, v, causal=True, window=None,
                                         chunk=chunk)
    elif s <= alloc:
        ck = jax.lax.dynamic_update_slice(cache["k"], kc, (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], vc, (0, 0, 0, 0))
        o = chunked_causal_attention(q, k, v, causal=True, window=window,
                                     chunk=chunk)
    else:  # ring: keep the last `alloc` tokens at their decode slots
        slots = jnp.arange(s - alloc, s) % alloc
        ck = cache["k"].at[:, slots].set(kc[:, s - alloc:])
        cv = cache["v"].at[:, slots].set(vc[:, s - alloc:])
        o = chunked_causal_attention(q, k, v, causal=True, window=window,
                                     chunk=chunk)
    out = _wo_project(p, o, num_heads, head_dim, accum, x.dtype)
    out = logical_constraint(out, "batch", out_seq, "embed")
    return out, {**cache, "k": ck, "v": cv}


def cross_attention_prefill(
    p: Dict,
    x: jnp.ndarray,                       # (B, S, D) — normed decoder stream
    cache: Dict[str, jnp.ndarray],        # holds cross_k / cross_v
    *,
    num_heads: int,
    kv_heads: int,
    head_dim: int,
    chunk: int = 512,
) -> jnp.ndarray:
    """Full-sequence cross-attention over precomputed encoder K/V."""
    q = _split_heads(dense(p["wq"], x), num_heads)
    o = chunked_causal_attention(
        q, cache["cross_k"].astype(q.dtype), cache["cross_v"].astype(q.dtype),
        causal=False, window=None, chunk=chunk,
    )
    return _wo_project(p, o, num_heads, head_dim, jnp.float32, x.dtype)


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------

def init_kv_cache(
    batch: int, max_len: int, kv_heads: int, head_dim: int, dtype=jnp.bfloat16
) -> Dict[str, jnp.ndarray]:
    return {
        "k": jnp.zeros((batch, max_len, kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, kv_heads, head_dim), dtype),
    }


def attention_decode(
    p: Dict,
    x: jnp.ndarray,                       # (B, 1, D)
    cache: Dict[str, jnp.ndarray],
    cache_len: jnp.ndarray,               # scalar or (B,) int32: #valid positions
    *,
    num_heads: int,
    kv_heads: int,
    head_dim: int,
    window: Optional[int] = None,
    rope_theta: float = 10000.0,
    mrope_sections: Optional[Tuple[int, ...]] = None,
    use_rope: bool = True,
    update_cache: bool = True,
    page_table: Optional[jnp.ndarray] = None,   # (B, max_pages) -> pool ids
    paged_impl: str = "fused",                  # fused (page walk) | gather
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token decode over a (possibly seq-sharded) KV cache.

    ``cache_len`` may be a scalar (every row at the same position — the
    fixed-batch hot path) or a ``(B,)`` vector (ragged prompts /
    continuous batching): each row writes its new K/V at its own slot and
    masks scores past its own length, so right-padded rows never attend
    over garbage KV.

    With ``page_table`` the cache is a *pool*: ``k``/``v`` are
    ``(num_pages, page_size, K, dh)`` physical pages shared by all
    sequences, and row ``b`` reads/writes the logical slots named by
    ``page_table[b]`` (DESIGN.md §9).  The new token lands at page
    ``cache_len // page_size``, offset ``cache_len % page_size`` of its
    own table.  The default ``paged_impl="fused"`` attends by *walking*
    the table with an online softmax (kernels/paged_attention.py,
    DESIGN.md §11) — O(cache_len) traffic, the new token's K/V stays
    in-register; ``"gather"`` keeps the legacy logical-view gather
    (O(max_pages · page_size) traffic) for differential tests and
    benchmarks.  Ring (SWA) caches are not paged.

    With the cache's seq dim sharded ("kv_seq"), GSPMD lowers the softmax
    to partial stats + all-reduce — the flash-decode pattern.
    """
    b = x.shape[0]
    # normalize to a per-row length vector; scalar == every row equal
    cache_len = jnp.broadcast_to(
        jnp.asarray(cache_len, jnp.int32).reshape(-1), (b,))
    paged = page_table is not None
    if paged and window is not None:
        raise NotImplementedError(
            "attention_decode: sliding-window attention over a paged KV "
            f"cache is not implemented (window={window} with page_table) — "
            "SWA uses contiguous ring caches (DESIGN.md §9); drop the "
            "window or use a contiguous cache")
    if paged and not update_cache:
        raise ValueError("paged KV caches do not support cross-attention "
                         "reads")
    if paged_impl not in ("fused", "gather"):
        raise ValueError(f"unknown paged_impl {paged_impl!r}")
    page_size = cache["k"].shape[1]
    max_len = page_table.shape[1] * page_size if paged else cache["k"].shape[1]
    ring = (not paged) and window is not None and max_len <= window
    q = _split_heads(dense(p["wq"], x), num_heads)          # (B,1,H,dh)
    pos = cache_len[:, None]                                # (B, 1)
    if update_cache:
        write_pos = cache_len % max_len if ring else cache_len
        knew = _split_heads(dense(p["wk"], x), kv_heads)
        vnew = _split_heads(dense(p["wv"], x), kv_heads)
        if use_rope and mrope_sections is not None:
            pos3 = jnp.tile(pos[..., None], (1, 1, 3))
            q = apply_mrope(q, pos3, mrope_sections, theta=rope_theta)
            knew = apply_mrope(knew, pos3, mrope_sections, theta=rope_theta)
        elif use_rope:
            q = apply_rope(q, pos, theta=rope_theta)
            knew = apply_rope(knew, pos, theta=rope_theta)
        if paged:
            # physical slot of this row's next token: its own page table
            # entry at logical page cache_len // page_size
            pid = jnp.take_along_axis(
                page_table, (cache_len // page_size)[:, None], axis=1)[:, 0]
            off = cache_len % page_size
            ck = cache["k"].at[pid, off].set(
                knew[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[pid, off].set(
                vnew[:, 0].astype(cache["v"].dtype))
        else:
            rows = jnp.arange(b)
            ck = cache["k"].at[rows, write_pos].set(
                knew[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[rows, write_pos].set(
                vnew[:, 0].astype(cache["v"].dtype))
        cache = {"k": ck, "v": cv}
    else:  # cross-attention: cache holds encoder K/V, no rope on q
        pass
    if paged and paged_impl == "fused":
        # walk the page table with an online softmax — no logical view,
        # O(cache_len) traffic; the rotated new-token K/V seeds the
        # accumulator in-register instead of round-tripping via the pool
        o32 = _paged_decode_op(
            q[:, 0], knew[:, 0], vnew[:, 0], cache["k"], cache["v"],
            page_table, cache_len)
        o = dense(p["wo"], o32.astype(x.dtype).reshape(
            b, 1, num_heads * head_dim))
        return o, cache
    if paged:
        # pages gather: (B, max_pages, page, K, dh) -> (B, S_logical, K, dh)
        ck = cache["k"][page_table].reshape(b, max_len, kv_heads, head_dim)
        cv = cache["v"][page_table].reshape(b, max_len, kv_heads, head_dim)
    else:
        ck = logical_constraint(cache["k"], "batch", "kv_seq", "kv", None)
        cv = logical_constraint(cache["v"], "batch", "kv_seq", "kv", None)

    g = num_heads // kv_heads
    qg = q.reshape(b, 1, kv_heads, g, head_dim)
    scores = _gqa_scores(qg, ck) / math.sqrt(head_dim)      # (B,K,G,1,S)
    kpos = jnp.arange(ck.shape[1])[None, :]                 # (1, S)
    clen = cache_len[:, None]                               # (B, 1)
    if not update_cache:
        valid = kpos < clen                         # cross-attn: encoder len
    elif ring:
        # ring slots hold the last min(cache_len+1, max_len) tokens — all
        # valid once full; before that, only slots [0, cache_len]
        valid = kpos <= clen
    else:
        valid = kpos <= clen
        if window is not None:
            valid &= kpos > clen - window
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    if paged:
        # unallocated pages may hold anything (the null page is
        # NaN-poisoned in tests): a NEG_INF score zeroes the softmax
        # weight, but 0 * NaN = NaN in the value contraction — zero the
        # gathered V at dead positions too (a no-op for finite data)
        cv = jnp.where(valid[:, :, None, None], cv, 0)
    w = jax.nn.softmax(scores, axis=-1)
    o = _gqa_values(w, cv).astype(x.dtype)                  # (B,1,K,G,dh)
    o = dense(p["wo"], o.reshape(b, 1, num_heads * head_dim))
    return o, cache
