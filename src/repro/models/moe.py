"""Mixture-of-Experts: top-k token-choice routing with capacity, sort-based
dispatch (no (T, E, C) one-hot blow-up), expert-parallel shardable.

Design (see DESIGN.md §4):
* tokens are split into ``groups`` (sharded on the data axis) and routed
  within each group — GShard-style grouping keeps the dispatch buffers
  O(T·k·cf) and evenly sharded;
* position-within-expert comes from a stable sort by expert id + a
  searchsorted for each expert's start — O(T log T), no E-wide cumsum;
* expert FFNs are a batched (E, C, D) x (E, D, F) matmul with the expert
  dim on the TP axis (EP) when E divides it, else intra-expert TP
  (mixtral's E=8 on a 16-way axis);
* aux load-balancing loss (Switch-style) is returned for the trainer.

Expert weights are 3-D (E, D, F): the pruning structures treat E as a
plane dim, so the knapsack can drop single MXU tiles *or* (at high
sparsity) whole experts — the paper's coarse/fine structure mix.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import _concrete_mesh, logical_constraint
from repro.kernels.ops import Epilogue
from .layers import expert_matmul, matmul, truncated_normal_init


def _cap_axis_ok(num_experts: int) -> bool:
    """Capacity-dim sharding pairs with FSDP'd expert weights (E divides
    the TP axis); under the intra-expert-TP fallback (mixtral E=8 < 16)
    it would fight the weights' own model-axis sharding — measured +88%
    collective on mixtral/train_4k (§Perf)."""
    mesh = _concrete_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return False
    return num_experts % mesh.shape["model"] == 0

__all__ = ["moe_init", "moe_apply"]


def moe_init(
    key,
    d_model: int,
    d_ff: int,
    num_experts: int,
    *,
    gated: bool = True,
    dtype=jnp.float32,
) -> Dict:
    ks = jax.random.split(key, 4)
    std_in = 1.0 / math.sqrt(d_model)
    std_out = 1.0 / math.sqrt(d_ff)
    p = {
        "router": {"kernel": truncated_normal_init(ks[0], (d_model, num_experts), std_in, jnp.float32)},
        "experts_up": truncated_normal_init(ks[1], (num_experts, d_model, d_ff), std_in, dtype),
        "experts_down": truncated_normal_init(ks[2], (num_experts, d_ff, d_model), std_out, dtype),
    }
    if gated:
        p["experts_gate"] = truncated_normal_init(ks[3], (num_experts, d_model, d_ff), std_in, dtype)
    return p


def moe_apply(
    p: Dict,
    x: jnp.ndarray,               # (B, S, D)
    *,
    num_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    groups: Optional[int] = None,
    activation: str = "silu",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (B,S,D), aux_loss scalar fp32)."""
    b, s, d = x.shape
    t = b * s
    g = groups or b
    g = math.gcd(g, t)
    n = t // g                                    # tokens per group
    cap = int(math.ceil(n * top_k * capacity_factor / num_experts))
    cap = max(cap, top_k)

    xt = x.reshape(g, n, d)
    xt = logical_constraint(xt, "batch", None, "embed")

    # --- routing (fp32) ----------------------------------------------------
    # routed through the sparse dispatch for uniformity; the default prune
    # include list keeps the router dense (it decides *where* tokens go)
    logits = matmul(xt, p["router"]["kernel"], accum=jnp.float32)
    # pin the expert dim replicated: propagation otherwise shards E over
    # the model axis and the router backward turns into a (g,n,d) f32 AR
    # per layer (+ top_k all-gathers) — §Perf granite G3
    logits = logical_constraint(logits, "batch", None, None)
    probs = jax.nn.softmax(logits, axis=-1)       # (g, n, E)
    gate, expert = jax.lax.top_k(probs, top_k)    # (g, n, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: E * mean_e(frac_tokens_e * mean_prob_e)
    me = jnp.mean(probs, axis=(0, 1))                               # (E,)
    assign1 = jax.nn.one_hot(expert[..., 0], num_experts)           # top-1 frac
    ce = jnp.mean(assign1, axis=(0, 1))
    aux = num_experts * jnp.sum(me * ce)

    # --- sort-based dispatch -------------------------------------------------
    eflat = expert.reshape(g, n * top_k)          # (g, nk)
    # gates cast to the activation dtype BEFORE entering the dispatch
    # arithmetic: keeps every (g, nk, d) dispatch tensor (and its
    # cotangents) in bf16 — halves dispatch collective bytes (§Perf)
    gflat = gate.reshape(g, n * top_k).astype(x.dtype)
    order = jnp.argsort(eflat, axis=-1, stable=True)               # (g, nk)
    se = jnp.take_along_axis(eflat, order, axis=-1)
    sg = jnp.take_along_axis(gflat, order, axis=-1)
    stok = order // top_k                          # source token per slot

    starts = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(num_experts)))(se)
    pos = jnp.arange(n * top_k)[None, :] - jnp.take_along_axis(starts, se, axis=-1)
    keep = pos < cap                               # capacity drop
    pos_c = jnp.where(keep, pos, 0)

    gathered = jnp.take_along_axis(xt, stok[..., None], axis=1)     # (g, nk, d)

    def scatter_group(buf_tokens, e_idx, p_idx, k_mask):
        buf = jnp.zeros((num_experts, cap, d), buf_tokens.dtype)
        vals = jnp.where(k_mask[:, None], buf_tokens, 0)
        return buf.at[e_idx, p_idx].add(vals, mode="drop")

    # scatter is local per data shard; the buffer's CAPACITY dim is then
    # sharded over the model axis ("expert_cap") — expert compute uses
    # data x model in full, expert weights stay replicated/FSDP (no token
    # travel, no weight travel; §Perf granite iteration G2)
    buffer = jax.vmap(scatter_group)(gathered, se, pos_c, keep)     # (g, E, C, d)
    cap_ax = "expert_cap" if _cap_axis_ok(num_experts) else None
    buffer = logical_constraint(buffer, "batch", None, cap_ax, None)

    # --- expert compute (EP batched matmul; BSRPlanes skip pruned tiles;
    # activation + SwiGLU gate fused into the matmul epilogue) --------------
    if "experts_gate" in p:
        up = expert_matmul(buffer, p["experts_up"], accum=jnp.float32)
        h = expert_matmul(buffer, p["experts_gate"], accum=jnp.float32,
                          epilogue=Epilogue(activation=activation,
                                            multiplier=up))
    else:
        h = expert_matmul(buffer, p["experts_up"], accum=jnp.float32,
                          epilogue=Epilogue(activation=activation))
    h = h.astype(x.dtype)
    h = logical_constraint(h, "batch", None, cap_ax, None)
    out_e = expert_matmul(h, p["experts_down"],
                          accum=jnp.float32).astype(x.dtype)
    out_e = logical_constraint(out_e, "batch", None, cap_ax, None)

    # --- combine --------------------------------------------------------------
    if cap_ax is not None:
        # 2-D gather straight from the (E, C-sharded) buffer: reshaping to
        # (E*C) would merge an unsharded dim with a sharded one and force a
        # full all-gather (70 GB/step measured); the direct gather lowers
        # to a local partial gather + one bf16 all-reduce of (g, nk, d)
        per_slot = jax.vmap(lambda oe, e_i, p_i: oe[e_i, p_i])(out_e, se, pos_c)
    else:
        # TP-fallback (unsharded E and C): flat take_along_axis stays
        # local (reshape of fully-unsharded dims is free)
        back = out_e.reshape(g, num_experts * cap, d)
        flat_idx = se * cap + pos_c
        per_slot = jnp.take_along_axis(back, flat_idx[..., None], axis=1)
    per_slot = per_slot * jnp.where(keep, sg, jnp.zeros((), x.dtype))[..., None]

    def combine_group(slot_vals, tok_idx):
        return jnp.zeros((n, d), slot_vals.dtype).at[tok_idx].add(slot_vals)

    out = jax.vmap(combine_group)(per_slot, stok)                   # (g, n, d)
    out = out.reshape(b, s, d)
    return logical_constraint(out, "batch", "seq", "embed"), aux


def moe_decode(p: Dict, x: jnp.ndarray, *, num_experts: int, top_k: int,
               capacity_factor: float = 2.0,
               activation: str = "silu") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Decode path: same sort-based dispatch, one group (T = B tokens).

    Per-token weight gathers would materialize (B·k·D·F) expert weights —
    30 GB for mixtral at batch 128 — so decode reuses the capacity path
    with a generous factor (token counts are tiny at decode)."""
    return moe_apply(
        p, x, num_experts=num_experts, top_k=top_k,
        capacity_factor=capacity_factor, groups=1, activation=activation,
    )
