"""Model zoo: composable transformer stack + the paper's own benchmarks."""
from .transformer import (
    LayerSpec,
    cross_entropy_loss,
    encode_kv_caches,
    encoder_forward,
    init_caches,
    init_params,
    layer_specs,
    lm_decode,
    lm_forward,
    lm_generate,
    lm_prefill,
)
from .cnn import PAPER_MODELS, paper_model

__all__ = [
    "LayerSpec", "cross_entropy_loss", "encode_kv_caches", "encoder_forward",
    "init_caches", "init_params", "layer_specs", "lm_decode", "lm_forward",
    "lm_generate", "lm_prefill",
    "PAPER_MODELS", "paper_model",
]
