"""Feed-forward blocks: gated (SwiGLU) and plain (GELU) MLPs.

Kernels are (in, out) matmuls — the natural targets of resource-aware
structured pruning.  The "mlp" logical axis puts the hidden dim on the TP
mesh axis (Megatron column/row parallel pair).  All matmuls go through
``layers.dense``, so a BSR-packed kernel (``repro.sparse.pack_params``)
runs here unchanged with pruned tiles skipped (DESIGN.md §6).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_constraint
from .layers import dense, dense_init

__all__ = ["mlp_init", "mlp_apply"]


def mlp_init(
    key,
    d_model: int,
    d_ff: int,
    *,
    gated: bool = True,
    use_bias: bool = False,
    dtype=jnp.float32,
) -> Dict:
    ks = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ks[0], d_model, d_ff, use_bias=use_bias, dtype=dtype),
        "w_down": dense_init(ks[1], d_ff, d_model, use_bias=use_bias, dtype=dtype),
    }
    if gated:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, use_bias=use_bias, dtype=dtype)
    return p


def mlp_apply(p: Dict, x: jnp.ndarray, *, activation: str = "silu",
              accum=None, out_seq: str = "seq",
              residual=None) -> jnp.ndarray:
    """Gated/plain MLP with the whole tail fused into the matmul epilogues
    (DESIGN.md §8): the gate matmul applies ``act(gate) * up`` on its fp32
    accumulator and the down projection adds ``residual`` the same way, so
    the packed path materializes no standalone (B, T, d_ff) activation or
    (B, T, d_model) pre-residual tensor.  With ``residual`` given the
    return value IS the updated residual stream."""
    accum = accum or jnp.float32
    if "w_gate" in p:
        up = dense(p["w_up"], x)
        up = logical_constraint(up, "batch", "seq", "mlp")
        h = dense(p["w_gate"], x, activation=activation, multiplier=up)
    else:
        h = dense(p["w_up"], x, activation=activation)
    h = logical_constraint(h, "batch", "seq", "mlp")
    out = dense(p["w_down"], h, accum=accum, residual=residual)
    # out_seq="res_seq" under Megatron-SP: the row-parallel partial sums
    # reduce-scatter straight into the seq-sharded residual (no AR+slice)
    return logical_constraint(out, "batch", out_seq, "embed")
