"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory, sequential), after Beck et al. 2024 (arXiv:2405.04517).

TPU adaptation:
* mLSTM trains in *chunked parallel* form — intra-chunk quadratic
  (MXU matmuls with stabilized exponential-gating decay matrix), inter-chunk
  recurrent state (C, n, m) carried across chunks.  Chunks are python-
  unrolled below ``CHUNK_UNROLL_LIMIT`` (accurate XLA cost analysis),
  ``lax.scan`` + roofline supplement above.
* sLSTM is inherently sequential (gates depend on h_{t-1} through the
  block-diagonal recurrent matrix R).  The input projections Wx are hoisted
  out of the scan (one big MXU matmul); the scan body only does the
  per-head (dh,4dh) recurrent matmul + elementwise gating.  Its trip count
  is reported to the roofline supplement machinery.

Both use exponential gating with the max-stabilizer m (paper eq. group 15
/ 24): numerically exact in fp32.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_constraint
from .layers import dense, dense_init, layernorm, layernorm_init, truncated_normal_init

__all__ = [
    "mlstm_init", "mlstm_apply", "mlstm_prefill", "mlstm_decode",
    "init_mlstm_cache",
    "slstm_init", "slstm_apply", "slstm_prefill", "slstm_decode",
    "init_slstm_cache",
    "CHUNK_UNROLL_LIMIT",
]

CHUNK_UNROLL_LIMIT = 4


# ===========================================================================
# mLSTM
# ===========================================================================

def mlstm_init(key, d_model: int, num_heads: int, *, proj_factor: float = 2.0,
               dtype=jnp.float32) -> Dict:
    d_in = int(proj_factor * d_model)
    d_in -= d_in % num_heads
    ks = jax.random.split(key, 7)
    return {
        "up_proj": dense_init(ks[0], d_model, d_in, dtype=dtype),
        "gate_proj": dense_init(ks[1], d_model, d_in, dtype=dtype),
        "wq": dense_init(ks[2], d_in, d_in, dtype=dtype),
        "wk": dense_init(ks[3], d_in, d_in, dtype=dtype),
        "wv": dense_init(ks[4], d_in, d_in, dtype=dtype),
        "wif": dense_init(ks[5], d_in, 2 * num_heads, use_bias=True, dtype=dtype),
        "down_proj": dense_init(ks[6], d_in, d_model, dtype=dtype),
    }


def _mlstm_chunk(carry, q, k, v, ig, fg):
    """One chunk of chunked mLSTM.

    carry: (C (B,H,dk,dv), n (B,H,dk), m (B,H))
    q,k,v (B,H,L,dh) fp32; ig,fg (B,H,L) raw gate pre-activations.
    Returns (new_carry, h (B,H,L,dh))."""
    C_p, n_p, m_p = carry
    b, h, l, dh = q.shape
    logf = jax.nn.log_sigmoid(fg)                       # (B,H,L)
    F = jnp.cumsum(logf, axis=-1)                       # decay chunk-start->t
    F_total = F[..., -1]

    # stabilizers
    d_intra = F[..., :, None] - F[..., None, :] + ig[..., None, :]  # (B,H,L,L)
    tri = jnp.tril(jnp.ones((l, l), bool))
    d_intra = jnp.where(tri[None, None], d_intra, -jnp.inf)
    m_intra = jnp.max(d_intra, axis=-1)                 # (B,H,L)
    m_inter = m_p[..., None] + F                        # (B,H,L)
    m_t = jnp.maximum(m_inter, m_intra)
    m_t = jnp.maximum(m_t, -1e30)

    scale = 1.0 / math.sqrt(dh)
    s_intra = jnp.einsum("bhld,bhtd->bhlt", q, k) * scale
    w_intra = s_intra * jnp.exp(d_intra - m_t[..., None])           # (B,H,L,L)
    inter_coeff = jnp.exp(m_inter - m_t)                            # (B,H,L)

    numer = (
        jnp.einsum("bhlt,bhtd->bhld", w_intra, v)
        + inter_coeff[..., None] * jnp.einsum("bhld,bhdv->bhlv", q * scale, C_p)
    )
    denom = (
        jnp.sum(w_intra, axis=-1)
        + inter_coeff * jnp.einsum("bhld,bhd->bhl", q * scale, n_p)
    )
    hidden = numer / jnp.maximum(jnp.abs(denom), jnp.exp(-m_t))[..., None]

    # state update to chunk end
    decay_to_end = F_total[..., None] - F + ig                      # (B,H,L)
    m_new = jnp.maximum(m_p + F_total, jnp.max(decay_to_end, axis=-1))
    kv = jnp.einsum(
        "bhtd,bhtv->bhdv", k * jnp.exp(decay_to_end - m_new[..., None])[..., None], v
    )
    C_new = jnp.exp(m_p + F_total - m_new)[..., None, None] * C_p + kv
    n_new = (
        jnp.exp(m_p + F_total - m_new)[..., None] * n_p
        + jnp.sum(k * jnp.exp(decay_to_end - m_new[..., None])[..., None], axis=2)
    )
    return (C_new, n_new, m_new), hidden


def _heads(x, h):
    b, s, d = x.shape
    return x.reshape(b, s, h, d // h).transpose(0, 2, 1, 3)  # (B,H,S,dh)


def mlstm_apply(p: Dict, x: jnp.ndarray, *, num_heads: int,
                chunk: int = 256) -> jnp.ndarray:
    return _mlstm_forward(p, x, None, num_heads=num_heads, chunk=chunk)[0]


def mlstm_prefill(p: Dict, x: jnp.ndarray, cache: Dict, *, num_heads: int,
                  chunk: int = 256) -> Tuple[jnp.ndarray, Dict]:
    """Batched prefill: chunked-parallel forward + final (C, n, m) cache."""
    carry = (cache["C"], cache["n"], cache["m"])
    out, (C, n, m) = _mlstm_forward(p, x, carry, num_heads=num_heads, chunk=chunk)
    return out, {"C": C, "n": n, "m": m}


def _mlstm_forward(p: Dict, x: jnp.ndarray, carry, *, num_heads: int,
                   chunk: int = 256):
    b, s, _ = x.shape
    xin = dense(p["up_proj"], x)
    gate = dense(p["gate_proj"], x)
    d_in = xin.shape[-1]
    dh = d_in // num_heads
    q = _heads(dense(p["wq"], xin), num_heads).astype(jnp.float32)
    k = _heads(dense(p["wk"], xin), num_heads).astype(jnp.float32)
    v = _heads(dense(p["wv"], xin), num_heads).astype(jnp.float32)
    q = logical_constraint(q, "batch", "heads", "seq", None)
    k = logical_constraint(k, "batch", "heads", "seq", None)
    v = logical_constraint(v, "batch", "heads", "seq", None)
    gif = dense(p["wif"], xin).astype(jnp.float32)                  # (B,S,2H)
    ig, fg = jnp.split(gif, 2, axis=-1)
    ig = ig.transpose(0, 2, 1)                                      # (B,H,S)
    fg = fg.transpose(0, 2, 1)

    chunk = min(chunk, s)
    n_chunks = -(-s // chunk)
    if carry is None:
        carry = (
            jnp.zeros((b, num_heads, dh, dh), jnp.float32),
            jnp.zeros((b, num_heads, dh), jnp.float32),
            jnp.full((b, num_heads), -1e30, jnp.float32),
        )
    if n_chunks <= CHUNK_UNROLL_LIMIT or s % chunk != 0:
        hs = []
        for c0 in range(0, s, chunk):
            c1 = min(c0 + chunk, s)
            carry, hid = _mlstm_chunk(
                carry, q[:, :, c0:c1], k[:, :, c0:c1], v[:, :, c0:c1],
                ig[:, :, c0:c1], fg[:, :, c0:c1],
            )
            hs.append(hid)
        hid = jnp.concatenate(hs, axis=2)                           # (B,H,S,dh)
    else:
        @jax.checkpoint
        def body(c, args):
            qc, kc, vc, igc, fgc = args
            c, hid = _mlstm_chunk(c, qc, kc, vc, igc, fgc)
            return c, hid

        split = lambda t, ax: jnp.stack(jnp.split(t, n_chunks, axis=ax))
        carry, hr = jax.lax.scan(
            body, carry,
            (split(q, 2), split(k, 2), split(v, 2), split(ig, 2), split(fg, 2)),
        )
        hid = hr.transpose(1, 2, 0, 3, 4).reshape(b, num_heads, s, dh)

    out = hid.transpose(0, 2, 1, 3).reshape(b, s, d_in).astype(x.dtype)
    out = out * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype)
    return dense(p["down_proj"], out), carry


def init_mlstm_cache(batch: int, num_heads: int, head_dim: int) -> Dict:
    return {
        "C": jnp.zeros((batch, num_heads, head_dim, head_dim), jnp.float32),
        "n": jnp.zeros((batch, num_heads, head_dim), jnp.float32),
        "m": jnp.full((batch, num_heads), -1e30, jnp.float32),
    }


def mlstm_decode(p: Dict, x: jnp.ndarray, cache: Dict, *, num_heads: int
                 ) -> Tuple[jnp.ndarray, Dict]:
    """One-token recurrent step (exact)."""
    b = x.shape[0]
    xin = dense(p["up_proj"], x)
    gate = dense(p["gate_proj"], x)
    d_in = xin.shape[-1]
    dh = d_in // num_heads
    q = _heads(dense(p["wq"], xin), num_heads)[:, :, 0].astype(jnp.float32)  # (B,H,dh)
    k = _heads(dense(p["wk"], xin), num_heads)[:, :, 0].astype(jnp.float32)
    v = _heads(dense(p["wv"], xin), num_heads)[:, :, 0].astype(jnp.float32)
    gif = dense(p["wif"], xin).astype(jnp.float32)[:, 0]            # (B,2H)
    ig, fg = jnp.split(gif, 2, axis=-1)
    logf = jax.nn.log_sigmoid(fg)

    m_new = jnp.maximum(cache["m"] + logf, ig)
    cf = jnp.exp(cache["m"] + logf - m_new)
    ci = jnp.exp(ig - m_new)
    scale = 1.0 / math.sqrt(dh)
    C = cf[..., None, None] * cache["C"] + ci[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    n = cf[..., None] * cache["n"] + ci[..., None] * k
    numer = jnp.einsum("bhd,bhdv->bhv", q * scale, C)
    denom = jnp.einsum("bhd,bhd->bh", q * scale, n)
    h = numer / jnp.maximum(jnp.abs(denom), jnp.exp(-m_new))[..., None]
    out = h.reshape(b, d_in)[:, None].astype(x.dtype)
    out = out * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype)
    return dense(p["down_proj"], out), {"C": C, "n": n, "m": m_new}


# ===========================================================================
# sLSTM
# ===========================================================================

def slstm_init(key, d_model: int, num_heads: int, *, ff_factor: float = 4 / 3,
               dtype=jnp.float32) -> Dict:
    dh = d_model // num_heads
    ks = jax.random.split(key, 4)
    d_ff = int(ff_factor * d_model)
    d_ff += (-d_ff) % 128
    return {
        # fused input projections for z, i, f, o
        "w_in": dense_init(ks[0], d_model, 4 * d_model, use_bias=True, dtype=dtype),
        # block-diagonal recurrent weights per head (H, dh, 4*dh)
        "r_rec": truncated_normal_init(ks[1], (num_heads, dh, 4 * dh),
                                       1.0 / math.sqrt(dh), dtype),
        "up": dense_init(ks[2], d_model, d_ff, dtype=dtype),
        "down": dense_init(ks[3], d_ff, d_model, dtype=dtype),
    }


def _slstm_step(state, wx_t, r_rec, num_heads):
    """state = (c, n, h, m) each (B, d) fp32; wx_t (B, 4d) fp32."""
    c, n, h, m = state
    b, d = h.shape
    dh = d // num_heads
    hh = h.reshape(b, num_heads, dh)
    rh = jnp.einsum("bhd,hde->bhe", hh, r_rec.astype(jnp.float32))  # (B,H,4dh)
    rh = rh.reshape(b, num_heads, 4, dh).transpose(0, 2, 1, 3).reshape(b, 4 * d)
    pre = wx_t + rh
    zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(zt)
    o = jax.nn.sigmoid(ot)
    logf = jax.nn.log_sigmoid(ft)                 # sigmoid-forget variant (stable)
    m_new = jnp.maximum(logf + m, it)
    cf = jnp.exp(logf + m - m_new)
    ci = jnp.exp(it - m_new)
    c_new = cf * c + ci * z
    n_new = cf * n + ci
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def _slstm_forward(p: Dict, x: jnp.ndarray, state, *, num_heads: int):
    """Sequential sLSTM over seq; scan body is recurrent-matmul only."""
    b, s, d = x.shape
    wx = dense(p["w_in"], x).astype(jnp.float32)                    # (B,S,4d)

    def body(st, wx_t):
        return _slstm_step(st, wx_t, p["r_rec"], num_heads)

    state, hs = jax.lax.scan(body, state, wx.transpose(1, 0, 2))    # (S,B,d)
    out = hs.transpose(1, 0, 2).astype(x.dtype)
    h2 = dense(p["up"], out)
    h2 = jax.nn.gelu(h2.astype(jnp.float32)).astype(x.dtype)
    return dense(p["down"], h2), state


def slstm_apply(p: Dict, x: jnp.ndarray, *, num_heads: int) -> jnp.ndarray:
    state = init_slstm_cache(x.shape[0], x.shape[2])
    state = tuple(state[k] for k in ("c", "n", "h", "m"))
    return _slstm_forward(p, x, state, num_heads=num_heads)[0]


def slstm_prefill(p: Dict, x: jnp.ndarray, cache: Dict, *, num_heads: int
                  ) -> Tuple[jnp.ndarray, Dict]:
    """Batched prefill: sequence scan that also returns the final state."""
    state = tuple(cache[k] for k in ("c", "n", "h", "m"))
    out, (c, n, h, m) = _slstm_forward(p, x, state, num_heads=num_heads)
    return out, {"c": c, "n": n, "h": h, "m": m}


def init_slstm_cache(batch: int, d_model: int) -> Dict:
    z = jnp.zeros((batch, d_model), jnp.float32)
    return {"c": z, "n": z + 1e-6, "h": z, "m": jnp.full((batch, d_model), -1e30, jnp.float32)}


def slstm_decode(p: Dict, x: jnp.ndarray, cache: Dict, *, num_heads: int
                 ) -> Tuple[jnp.ndarray, Dict]:
    b, s, d = x.shape
    wx = dense(p["w_in"], x).astype(jnp.float32)[:, 0]              # (B,4d)
    state = tuple(cache[k] for k in ("c", "n", "h", "m"))
    state, h = _slstm_step(state, wx, p["r_rec"], num_heads)
    out = h[:, None].astype(x.dtype)
    h2 = dense(p["up"], out)
    h2 = jax.nn.gelu(h2.astype(jnp.float32)).astype(x.dtype)
    out = dense(p["down"], h2)
    c, n, hh, m = state
    return out, {"c": c, "n": n, "h": hh, "m": m}
