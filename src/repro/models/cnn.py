"""The paper's own benchmark models (Table I):

* Jets  — 4-layer FC (16 -> 64 -> 32 -> 32 -> 5), ReLU     [Duarte et al.]
* SVHN  — low-latency CNN (3 conv + 3 FC)                  [Aarrestad et al.]
* LeNet — LeNet-like with 3x3 kernels for 28x28 F-MNIST    [paper §IV-D]

Pure JAX; kernels are (in, out) dense / (kh, kw, cin, cout) conv so the
resource-aware structures map exactly as in the paper: per-layer RF and
strategy are carried in ``FpgaLayerCfg`` to reproduce Tables II/III/V
resource vectors.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense, dense_init, truncated_normal_init

__all__ = [
    "FpgaLayerCfg", "PAPER_MODELS", "init_jets_mlp", "jets_mlp_forward",
    "init_svhn_cnn", "svhn_cnn_forward", "init_lenet", "lenet_forward",
    "paper_model", "LENET_LAYER_CFG",
]


@dataclasses.dataclass(frozen=True)
class FpgaLayerCfg:
    """Per-layer hls4ml hardware configuration (paper Table IV)."""

    name: str
    rf: int
    strategy: str            # "latency" | "resource"
    precision_bits: int = 16


# ---------------------------------------------------------------------------
# Jets MLP (paper: 4,389 params, 76.6% acc)
# ---------------------------------------------------------------------------

JETS_DIMS = (16, 64, 32, 32, 5)


def init_jets_mlp(key, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, len(JETS_DIMS) - 1)
    return {
        f"fc_{i+1}": dense_init(ks[i], JETS_DIMS[i], JETS_DIMS[i + 1],
                                use_bias=True, dtype=dtype)
        for i in range(len(JETS_DIMS) - 1)
    }


def jets_mlp_forward(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    n = len(JETS_DIMS) - 1
    for i in range(n):
        x = dense(params[f"fc_{i+1}"], x)
        if i < n - 1:
            x = jax.nn.relu(x)
    return x  # logits (B, 5)


# ---------------------------------------------------------------------------
# Conv helper
# ---------------------------------------------------------------------------

def conv_init(key, kh, kw, cin, cout, dtype=jnp.float32) -> Dict:
    std = 1.0 / (kh * kw * cin) ** 0.5
    return {
        "kernel": truncated_normal_init(key, (kh, kw, cin, cout), std, dtype),
        "bias": jnp.zeros((cout,), dtype),
    }


def conv2d(p: Dict, x: jnp.ndarray, *, stride: int = 1, padding="VALID") -> jnp.ndarray:
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), p["kernel"].astype(jnp.float32),
        window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return (y + p["bias"].astype(jnp.float32)).astype(x.dtype)


def maxpool(x, size=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, size, size, 1), (1, size, size, 1), "VALID"
    )


# ---------------------------------------------------------------------------
# SVHN CNN (Aarrestad et al.: conv 16,16,24 + dense 42,64,10; ~14k params)
# ---------------------------------------------------------------------------

def init_svhn_cnn(key, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 6)
    return {
        "conv2d_1": conv_init(ks[0], 3, 3, 3, 16, dtype),
        "conv2d_2": conv_init(ks[1], 3, 3, 16, 16, dtype),
        "conv2d_3": conv_init(ks[2], 3, 3, 16, 24, dtype),
        "fc_1": dense_init(ks[3], 24 * 2 * 2, 42, use_bias=True, dtype=dtype),
        "fc_2": dense_init(ks[4], 42, 64, use_bias=True, dtype=dtype),
        "fc_3": dense_init(ks[5], 64, 10, use_bias=True, dtype=dtype),
    }


def svhn_cnn_forward(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    """x (B, 32, 32, 3) -> logits (B, 10)."""
    x = maxpool(jax.nn.relu(conv2d(params["conv2d_1"], x)))   # 30->15
    x = maxpool(jax.nn.relu(conv2d(params["conv2d_2"], x)))   # 13->6
    x = maxpool(jax.nn.relu(conv2d(params["conv2d_3"], x)))   # 4->2
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(dense(params["fc_1"], x))
    x = jax.nn.relu(dense(params["fc_2"], x))
    return dense(params["fc_3"], x)


# ---------------------------------------------------------------------------
# LeNet-like for Fashion-MNIST (paper §IV-D: 60,074 params; 3x3 kernels)
# ---------------------------------------------------------------------------

def init_lenet(key, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 5)
    return {
        "conv2d_1": conv_init(ks[0], 3, 3, 1, 6, dtype),        # 60 params
        "conv2d_2": conv_init(ks[1], 3, 3, 6, 16, dtype),       # 880 params
        "fc_1": dense_init(ks[2], 16 * 5 * 5, 120, use_bias=True, dtype=dtype),
        "fc_2": dense_init(ks[3], 120, 84, use_bias=True, dtype=dtype),
        "fc_3": dense_init(ks[4], 84, 10, use_bias=True, dtype=dtype),
    }


def lenet_forward(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    """x (B, 28, 28, 1) -> logits (B, 10)."""
    x = maxpool(jax.nn.relu(conv2d(params["conv2d_1"], x)))    # 26 -> 13
    x = maxpool(jax.nn.relu(conv2d(params["conv2d_2"], x)))    # 11 -> 5
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(dense(params["fc_1"], x))
    x = jax.nn.relu(dense(params["fc_2"], x))
    return dense(params["fc_3"], x)


# Paper Table IV: heterogeneous per-layer hardware configuration for LeNet.
LENET_LAYER_CFG: List[FpgaLayerCfg] = [
    FpgaLayerCfg("conv2d_1", rf=1, strategy="latency", precision_bits=18),
    FpgaLayerCfg("conv2d_2", rf=1, strategy="latency", precision_bits=18),
    FpgaLayerCfg("fc_1", rf=25, strategy="resource", precision_bits=18),
    FpgaLayerCfg("fc_2", rf=12, strategy="resource", precision_bits=18),
    FpgaLayerCfg("fc_3", rf=1, strategy="latency", precision_bits=18),
]


PAPER_MODELS = {
    "jets-mlp": (init_jets_mlp, jets_mlp_forward, (16,)),
    "svhn-cnn": (init_svhn_cnn, svhn_cnn_forward, (32, 32, 3)),
    "lenet-fmnist": (init_lenet, lenet_forward, (28, 28, 1)),
}


def paper_model(name: str):
    if name not in PAPER_MODELS:
        raise KeyError(f"unknown paper model {name!r}: {sorted(PAPER_MODELS)}")
    return PAPER_MODELS[name]
