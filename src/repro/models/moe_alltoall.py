"""Expert-parallel MoE with explicit all-to-all dispatch (shard_map).

The GSPMD-sharded sort/scatter dispatch (moe.py) lets the partitioner
lower data-dependent gathers over the expert-sharded buffer to
replicate+mask+all-reduce — measured at ~300 GB wire/step on
granite/train_4k (EXPERIMENTS.md §Perf).  The production pattern is
explicit: tokens travel to their experts' shards via all_to_all and come
back the same way; wire per layer ≈ 2·tokens·d·bf16·cf — a ~50×
reduction.

Topology: tokens sharded over the DP axes, experts over "model"
(E_local = E / model_size).  Two-stage routing per shard:
  1. sort token-choices by destination shard; fixed per-dest send buffers
     (capacity_factor-bounded, drops beyond),
  2. all_to_all payload + expert-ids to the owning shard,
  3. local per-expert capacity sort + batched FFN,
  4. inverse gather + all_to_all back + gate-weighted combine at source.

Everything inside is shard-local jnp (differentiable; all_to_all's
transpose is all_to_all).  Requires E % model_size == 0 (mixtral's E=8 on
a 16-way axis keeps the GSPMD fallback).

Sparse execution (DESIGN.md §8): packed ``BSRPlanes`` expert weights run
the shard-local FFN through the fused zero-skipping plane kernel with the
activation/SwiGLU gate in the matmul epilogue; ``transform.planes_pspec``
supplies the matching shard_map specs so the packed tree needs no
densify and no special casing at the call site.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.packing import BSRPlanes
from repro.distributed.sharding import _concrete_mesh, current_rules, shard_map
from repro.kernels.ops import Epilogue, apply_epilogue, bsr_planes_matmul
from repro.sparse.transform import planes_pspec

__all__ = ["moe_alltoall_apply", "alltoall_available"]


def _expert_mm(h: jnp.ndarray, w, *, epilogue=None) -> jnp.ndarray:
    """Shard-local expert matmul (E_loc, C, d) @ (E_loc, d, f) -> fp32.

    ``BSRPlanes`` leaves (the shard's E_loc planes of the packed expert
    stack) run the fused zero-skipping kernel with the epilogue applied
    in-kernel; dense 3-D weights take the batched einsum with the same
    fp32 epilogue math."""
    if isinstance(w, BSRPlanes):
        return bsr_planes_matmul(h, w, epilogue=epilogue).astype(jnp.float32)
    y = jnp.einsum("ecd,edf->ecf", h, w, preferred_element_type=jnp.float32)
    return apply_epilogue(y, epilogue)


def alltoall_available(num_experts: int) -> bool:
    mesh = _concrete_mesh()
    rules = current_rules()
    if mesh is None or rules is None or "model" not in mesh.axis_names:
        return False
    return num_experts % mesh.shape["model"] == 0


def _local_moe(x_loc, p, *, num_experts, top_k, capacity_factor, activation,
               model_axis, model_size, dp_axes):
    """Per-shard body. x_loc (T, d) local tokens."""
    t, d = x_loc.shape
    # static axis size threaded from the caller's mesh (jax.lax.axis_size
    # is post-0.4.x, and the value feeds python-level shape math anyway)
    m = model_size
    e_loc = num_experts // m
    c_send = max(int(math.ceil(t * top_k * capacity_factor / m)), top_k)
    c_exp = max(int(math.ceil(m * c_send / e_loc)), 1)

    # --- routing ------------------------------------------------------------
    logits = jnp.einsum("td,de->te", x_loc, p["router"]["kernel"],
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eid = jax.lax.top_k(probs, top_k)                  # (T, k)
    gate = (gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)).astype(x_loc.dtype)

    # Switch aux loss, globally averaged over the token shards
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(eid[..., 0], num_experts), axis=0)
    for ax in dp_axes:
        me = jax.lax.pmean(me, ax)
        ce = jax.lax.pmean(ce, ax)
    aux = num_experts * jnp.sum(me * ce)

    # --- stage 1: sort by destination shard ----------------------------------
    ef = eid.reshape(-1)                                     # (T*k,)
    gf = gate.reshape(-1)
    tokf = jnp.arange(t * top_k) // top_k
    dest = ef // e_loc
    order = jnp.argsort(dest, stable=True)
    sd, se_, sg, stok = dest[order], ef[order], gf[order], tokf[order]
    starts = jnp.searchsorted(sd, jnp.arange(m))
    pos = jnp.arange(t * top_k) - starts[sd]
    keep = pos < c_send
    pos_c = jnp.where(keep, pos, 0)

    send_x = jnp.zeros((m, c_send, d), x_loc.dtype)
    send_x = send_x.at[sd, pos_c].add(
        jnp.where(keep[:, None], x_loc[stok], 0), mode="drop")
    send_id = jnp.full((m, c_send), -1, jnp.int32)
    send_id = send_id.at[sd, pos_c].max(
        jnp.where(keep, se_, -1).astype(jnp.int32), mode="drop")

    # --- stage 2: to the expert shards ---------------------------------------
    recv_x = jax.lax.all_to_all(send_x, model_axis, 0, 0, tiled=False)
    recv_id = jax.lax.all_to_all(send_id, model_axis, 0, 0, tiled=False)
    rx = recv_x.reshape(m * c_send, d)
    rid = recv_id.reshape(m * c_send)

    # --- stage 3: local per-expert buffers -----------------------------------
    le = rid % e_loc
    valid = rid >= 0
    le_sort = jnp.where(valid, le, e_loc)                    # invalid last
    order2 = jnp.argsort(le_sort, stable=True)
    le2, valid2 = le_sort[order2], valid[order2]
    starts2 = jnp.searchsorted(le2, jnp.arange(e_loc))
    pos2 = jnp.arange(m * c_send) - starts2[jnp.clip(le2, 0, e_loc - 1)]
    keep2 = valid2 & (pos2 < c_exp)
    pos2c = jnp.where(keep2, pos2, 0)
    le2c = jnp.where(keep2, le2, 0)

    ebuf = jnp.zeros((e_loc, c_exp, d), x_loc.dtype)
    ebuf = ebuf.at[le2c, pos2c].add(
        jnp.where(keep2[:, None], rx[order2], 0), mode="drop")

    # packed (BSRPlanes) or dense expert FFN, activation/gate fused into
    # the matmul epilogue either way (DESIGN.md §8)
    if "experts_gate" in p:
        up = _expert_mm(ebuf, p["experts_up"])
        h = _expert_mm(ebuf, p["experts_gate"],
                       epilogue=Epilogue(activation=activation, multiplier=up))
    else:
        h = _expert_mm(ebuf, p["experts_up"],
                       epilogue=Epilogue(activation=activation))
    out_e = _expert_mm(h.astype(x_loc.dtype),
                       p["experts_down"]).astype(x_loc.dtype)

    # --- stage 4: inverse route back ------------------------------------------
    y_sorted = jnp.where(keep2[:, None], out_e[le2c, pos2c], 0)
    inv2 = jnp.zeros_like(order2).at[order2].set(jnp.arange(order2.shape[0]))
    y_recv = y_sorted[inv2].reshape(m, c_send, d)
    y_send = jax.lax.all_to_all(y_recv, model_axis, 0, 0, tiled=False)

    y_slot = jnp.where(keep[:, None], y_send[sd, pos_c], 0) * sg[:, None]
    out = jnp.zeros((t, d), x_loc.dtype).at[stok].add(y_slot)
    return out, aux


def moe_alltoall_apply(
    p: Dict,
    x: jnp.ndarray,               # (B, S, D)
    *,
    num_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    activation: str = "silu",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    mesh = _concrete_mesh()
    rules = current_rules()
    dp = rules.get("batch") or ()
    dp_axes = (dp,) if isinstance(dp, str) else tuple(dp)
    b, s, d = x.shape

    body = partial(
        _local_moe, num_experts=num_experts, top_k=top_k,
        capacity_factor=capacity_factor, activation=activation,
        model_axis="model", model_size=int(mesh.shape["model"]),
        dp_axes=dp_axes,
    )

    def wrapped(xs, params):
        t_loc = xs.shape[0] * xs.shape[1]
        y, aux = body(xs.reshape(t_loc, d), params)
        return y.reshape(xs.shape), aux

    # per-leaf specs: dense expert stacks shard the plane (E) dim on the
    # model axis; packed BSRPlanes leaves shard the plane dim of every
    # component array (transform.planes_pspec), so the packed tree flows
    # through the same shard_map unchanged
    pspec = {
        "router": {"kernel": P()},
        "experts_up": planes_pspec(p["experts_up"], "model"),
        "experts_down": planes_pspec(p["experts_down"], "model"),
    }
    if "experts_gate" in p:
        pspec["experts_gate"] = planes_pspec(p["experts_gate"], "model")
    xspec = P(dp_axes if dp_axes else None, None, None)

    fn = shard_map(
        wrapped, mesh=mesh,
        in_specs=(xspec, pspec),
        out_specs=(xspec, P()),
        check=False,
    )
    return fn(x, p)
