"""Params-pytree sparse execution transform (DESIGN.md §6).

``pack_params`` replaces each prunable matmul ``kernel`` leaf with a
``BSRWeight`` (2-D weights) or ``BSRPlanes`` (stacked per-plane BSR for
3-D expert weights), so the whole model stack — forward *and* decode —
runs on packed params through the single dispatch point in
``models/layers.matmul``: pruned tiles are skipped outright instead of
multiplied by zero.  ``unpack_params`` is the dense reconstruction oracle
used by the equivalence tests.

The transform is host-side (numpy): packing happens once at serving
start, not inside a jitted step.  Packed leaves are registered pytrees,
so the resulting params tree jits, remats and shards like the dense one.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.masks import _get_path, _set_path, build_structures
from repro.core.packing import BSRWeight, bsr_to_dense, pack_bsr
from repro.core.structures import BlockingSpec, LayerStructures, PRUNABLE_MIN_SIZE

__all__ = [
    "BSRPlanes",
    "pack_params",
    "unpack_params",
    "is_packed_leaf",
    "sparsity_summary",
]


@dataclasses.dataclass
class BSRPlanes:
    """Flattened per-plane BSR stack for a >2-D weight (MoE (E, D, F)).

    The per-plane ``(indices, blocks)`` pairs are concatenated into ONE
    BSR: the slot dim is padded to the stack-wide ``max_nnz`` and the
    plane offset into the concatenated ``E * grid_n`` block-columns is
    implicit in the leading axis — so ``expert_matmul`` issues a single
    fused kernel call (``kernels.ops.bsr_planes_matmul``) instead of a
    python loop + stack over planes.  Pruning every tile of a plane
    removes the whole expert — the paper's coarse structure; a dead
    plane contributes only `pl.when`-skipped padding slots.
    """

    indices: jnp.ndarray            # (E, grid_n, max_nnz) int32, -1 padded
    blocks: jnp.ndarray             # (E, grid_n, max_nnz, bk, bn)
    shape: Tuple[int, ...]          # full dense shape, leading dims included
    blocking: BlockingSpec          # effective (clamped) tile shape

    @classmethod
    def from_planes(cls, planes: Tuple[BSRWeight, ...],
                    shape: Tuple[int, ...]) -> "BSRPlanes":
        """Concatenate independent per-plane BSRWeights (same (K, N) and
        blocking) into the fused layout, padding slots to the max."""
        max_nnz = max(p.max_nnz for p in planes)
        idx, blk = [], []
        for p in planes:
            pad = max_nnz - p.max_nnz
            idx.append(jnp.pad(p.indices, ((0, 0), (0, pad)),
                               constant_values=-1))
            blk.append(jnp.pad(p.blocks, ((0, 0), (0, pad), (0, 0), (0, 0))))
        return cls(
            indices=jnp.stack(idx),
            blocks=jnp.stack(blk),
            shape=tuple(int(s) for s in shape),
            blocking=planes[0].blocking,
        )

    @property
    def num_planes(self) -> int:
        return self.indices.shape[0]

    @property
    def grid_k(self) -> int:
        return -(-self.shape[-2] // self.blocking.bk)

    @property
    def grid_n(self) -> int:
        return self.indices.shape[1]

    @property
    def max_nnz(self) -> int:
        return self.indices.shape[2]

    @property
    def planes(self) -> Tuple[BSRWeight, ...]:
        """Per-plane BSRWeight views into the fused arrays (oracles/tests)."""
        kn = (int(self.shape[-2]), int(self.shape[-1]))
        return tuple(
            BSRWeight(indices=self.indices[e], blocks=self.blocks[e],
                      shape=kn, blocking=self.blocking)
            for e in range(self.num_planes)
        )

    def density(self) -> float:
        nnz = int(jnp.sum(self.indices >= 0))
        return nnz / max(self.num_planes * self.grid_k * self.grid_n, 1)

    def tree_flatten(self):
        return (self.indices, self.blocks), (self.shape, self.blocking)

    @classmethod
    def tree_unflatten(cls, aux, children):
        indices, blocks = children
        shape, blocking = aux
        return cls(indices=indices, blocks=blocks, shape=shape,
                   blocking=blocking)


jax.tree_util.register_pytree_node(
    BSRPlanes, BSRPlanes.tree_flatten, BSRPlanes.tree_unflatten
)


def is_packed_leaf(x: Any) -> bool:
    return isinstance(x, (BSRWeight, BSRPlanes))


def _copy_tree(tree):
    if isinstance(tree, dict):
        return {k: _copy_tree(v) for k, v in tree.items()}
    if isinstance(tree, list):
        return [_copy_tree(v) for v in tree]
    if isinstance(tree, tuple):
        return tuple(_copy_tree(v) for v in tree)
    return tree


def _host(x) -> np.ndarray:
    return np.asarray(jax.device_get(x))


def pack_params(
    params: Mapping[str, Any],
    masks: Optional[Mapping[str, Any]] = None,
    structures: Optional[LayerStructures] = None,
    blocking: Optional[BlockingSpec] = None,
    *,
    min_size: int = PRUNABLE_MIN_SIZE,
    **iter_kwargs,
) -> Dict[str, Any]:
    """Replace prunable kernel leaves with BSR weights.

    ``structures`` (from ``build_structures`` / ``knapsack_prune``) names
    the leaves to pack and their blocking; when omitted, structures are
    built here from ``blocking``.  ``masks`` zeroes pruned tiles before
    packing; with ``masks=None`` only exactly-zero tiles are dropped.
    All other leaves are passed through untouched.
    """
    if structures is None:
        if blocking is None:
            raise ValueError("pack_params needs either structures or blocking")
        structures = build_structures(
            params, blocking, min_size=min_size, **iter_kwargs
        )
    packed = _copy_tree(dict(params))
    for info in structures.infos:
        w = _host(_get_path(params, info.path))
        m = None
        if masks is not None:
            mleaf = _get_path(masks, info.path)
            m = None if mleaf is None else _host(mleaf)
        if w.ndim == 2:
            leaf: Any = pack_bsr(w, info.blocking, mask=m)
        else:
            k, n = w.shape[-2], w.shape[-1]
            w3 = w.reshape(info.planes, k, n)
            m3 = None if m is None else m.reshape(info.planes, k, n)
            leaf = BSRPlanes.from_planes(
                tuple(
                    pack_bsr(w3[p], info.blocking,
                             mask=None if m3 is None else m3[p])
                    for p in range(info.planes)
                ),
                shape=tuple(int(s) for s in w.shape),
            )
        _set_path(packed, info.path, leaf)
    return packed


def unpack_params(packed: Mapping[str, Any]) -> Dict[str, Any]:
    """Dense reconstruction of a packed tree — the test oracle.

    Every ``BSRWeight``/``BSRPlanes`` leaf becomes the masked dense weight
    (pruned tiles exactly zero); all other leaves pass through.
    """

    def leaf_fn(x):
        if isinstance(x, BSRWeight):
            return bsr_to_dense(x)
        if isinstance(x, BSRPlanes):
            dense = jnp.stack([bsr_to_dense(p) for p in x.planes])
            return dense.reshape(x.shape)
        return x

    return jax.tree.map(leaf_fn, dict(packed), is_leaf=is_packed_leaf)


def sparsity_summary(packed: Mapping[str, Any]) -> Dict[str, Any]:
    """Per-path and aggregate block density of a packed tree."""
    flat = jax.tree_util.tree_flatten_with_path(
        dict(packed), is_leaf=is_packed_leaf
    )[0]
    per_path: Dict[str, float] = {}
    nnz = total = 0
    for keypath, leaf in flat:
        if not is_packed_leaf(leaf):
            continue
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in keypath)
        per_path[path] = leaf.density()
        if isinstance(leaf, BSRWeight):
            nnz += leaf.nnz_blocks
            total += leaf.grid_k * leaf.grid_n
        else:
            nnz += int(jnp.sum(leaf.indices >= 0))
            total += leaf.num_planes * leaf.grid_k * leaf.grid_n
    return {
        "per_path": per_path,
        "nnz_blocks": int(nnz),
        "total_blocks": int(total),
        "density": nnz / max(total, 1),
    }
