"""Params-pytree sparse execution transform (DESIGN.md §6).

``pack_params`` replaces each prunable matmul ``kernel`` leaf with a
``BSRWeight`` (2-D weights) or ``BSRPlanes`` (stacked per-plane BSR for
3-D expert weights), so the whole model stack — forward *and* decode —
runs on packed params through the single dispatch point in
``models/layers.matmul``: pruned tiles are skipped outright instead of
multiplied by zero.  ``unpack_params`` is the dense reconstruction oracle
used by the equivalence tests.

The transform is host-side (numpy): packing happens once at serving
start, not inside a jitted step.  Packed leaves are registered pytrees,
so the resulting params tree jits, remats and shards like the dense one.
``BSRWeight``/``BSRPlanes`` themselves live in ``core/packing.py`` (next
to ``pack_bsr``); ``BSRPlanes`` is re-exported here for compatibility.
"""
from __future__ import annotations

from typing import Any, Dict, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.masks import _get_path, _set_path, build_structures
from repro.core.packing import BSRPlanes, BSRWeight, bsr_to_dense, pack_bsr
from repro.core.structures import BlockingSpec, LayerStructures, PRUNABLE_MIN_SIZE

__all__ = [
    "BSRPlanes",
    "pack_params",
    "unpack_params",
    "is_packed_leaf",
    "planes_pspec",
    "sparsity_summary",
]


def is_packed_leaf(x: Any) -> bool:
    return isinstance(x, (BSRWeight, BSRPlanes))


def _copy_tree(tree):
    if isinstance(tree, dict):
        return {k: _copy_tree(v) for k, v in tree.items()}
    if isinstance(tree, list):
        return [_copy_tree(v) for v in tree]
    if isinstance(tree, tuple):
        return tuple(_copy_tree(v) for v in tree)
    return tree


def _host(x) -> np.ndarray:
    return np.asarray(jax.device_get(x))


def pack_params(
    params: Mapping[str, Any],
    masks: Optional[Mapping[str, Any]] = None,
    structures: Optional[LayerStructures] = None,
    blocking: Optional[BlockingSpec] = None,
    *,
    min_size: int = PRUNABLE_MIN_SIZE,
    **iter_kwargs,
) -> Dict[str, Any]:
    """Replace prunable kernel leaves with BSR weights.

    ``structures`` (from ``build_structures`` / ``knapsack_prune``) names
    the leaves to pack and their blocking; when omitted, structures are
    built here from ``blocking``.  ``masks`` zeroes pruned tiles before
    packing; with ``masks=None`` only exactly-zero tiles are dropped.
    All other leaves are passed through untouched.
    """
    if structures is None:
        if blocking is None:
            raise ValueError("pack_params needs either structures or blocking")
        structures = build_structures(
            params, blocking, min_size=min_size, **iter_kwargs
        )
    packed = _copy_tree(dict(params))
    for info in structures.infos:
        w = _host(_get_path(params, info.path))
        m = None
        if masks is not None:
            mleaf = _get_path(masks, info.path)
            m = None if mleaf is None else _host(mleaf)
        if w.ndim == 2:
            leaf: Any = pack_bsr(w, info.blocking, mask=m)
        else:
            k, n = w.shape[-2], w.shape[-1]
            w3 = w.reshape(info.planes, k, n)
            m3 = None if m is None else m.reshape(info.planes, k, n)
            leaf = BSRPlanes.from_planes(
                tuple(
                    pack_bsr(w3[p], info.blocking,
                             mask=None if m3 is None else m3[p])
                    for p in range(info.planes)
                ),
                shape=tuple(int(s) for s in w.shape),
            )
        _set_path(packed, info.path, leaf)
    return packed


def unpack_params(packed: Mapping[str, Any]) -> Dict[str, Any]:
    """Dense reconstruction of a packed tree — the test oracle.

    Every ``BSRWeight``/``BSRPlanes`` leaf becomes the masked dense weight
    (pruned tiles exactly zero); all other leaves pass through.
    """

    def leaf_fn(x):
        if isinstance(x, BSRWeight):
            return bsr_to_dense(x)
        if isinstance(x, BSRPlanes):
            dense = jnp.stack([bsr_to_dense(p) for p in x.planes])
            return dense.reshape(x.shape)
        return x

    return jax.tree.map(leaf_fn, dict(packed), is_leaf=is_packed_leaf)


def sparsity_summary(packed: Mapping[str, Any]) -> Dict[str, Any]:
    """Per-path and aggregate block density of a packed tree."""
    flat = jax.tree_util.tree_flatten_with_path(
        dict(packed), is_leaf=is_packed_leaf
    )[0]
    per_path: Dict[str, float] = {}
    nnz = total = 0
    for keypath, leaf in flat:
        if not is_packed_leaf(leaf):
            continue
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in keypath)
        per_path[path] = leaf.density()
        nnz += leaf.nnz_blocks
        planes = leaf.num_planes if isinstance(leaf, BSRPlanes) else 1
        total += planes * leaf.grid_k * leaf.grid_n
    return {
        "per_path": per_path,
        "nnz_blocks": int(nnz),
        "total_blocks": int(total),
        "density": nnz / max(total, 1),
    }


def planes_pspec(leaf: Any, plane_axis: str):
    """``shard_map``/GSPMD PartitionSpec tree for an expert-weight leaf.

    Dense (E, D, F) stacks shard the plane dim on ``plane_axis``; a
    ``BSRPlanes`` leaf gets the matching per-array specs — the plane dim
    of every component array is sharded, per-plane index maps and the
    flat tile store ride along replicated within the shard.  This is what
    lets the packed tree flow through ``moe_alltoall``'s ``shard_map``
    unchanged: E_local planes per shard, no densify, no gather."""
    if isinstance(leaf, BSRPlanes):
        return BSRPlanes(
            indices=P(plane_axis, None, None),
            slots=P(plane_axis, None, None),
            blocks=P(plane_axis, None, None, None),
            flat_rows=P(plane_axis, None),
            flat_cols=P(plane_axis, None),
            shape=leaf.shape, blocking=leaf.blocking,
            plane_nnz=leaf.plane_nnz,
        )
    return P(plane_axis, None, None)
