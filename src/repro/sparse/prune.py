"""One-shot knapsack pruning for serving (paper §III-B, Eq. 5-8).

``knapsack_prune`` is the serving-side condensation of the iterative
pruner: compute layer-normalized structure magnitudes (Eq. 4), tile the
per-structure resource costs, and solve one global MDKP at the requested
sparsity.  The returned selection carries everything ``pack_params``
needs, so ``launch/serve.py --pruned`` and the examples are two calls:

    sel = knapsack_prune(params, sparsity=0.5, blocking=BlockingSpec())
    packed = pack_params(params, sel.masks, sel.structures)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional, Sequence

import numpy as np

from repro.core.knapsack import KnapsackResult, solve_mdkp
from repro.core.masks import _get_path, build_structures, masks_from_knapsack
from repro.core.resource_model import TPUResourceModel
from repro.core.structures import (
    BlockingSpec,
    LayerStructures,
    structure_norms_dense,
)

__all__ = ["PruneSelection", "knapsack_prune", "DEFAULT_INCLUDE", "DEFAULT_EXCLUDE"]

# matmul families the serving path packs by default; embeddings and the MoE
# router stay dense (the router decides *where* tokens go — pruning it
# changes routing, not just per-structure compute)
DEFAULT_INCLUDE = ("mlp", "attn", "moe")
DEFAULT_EXCLUDE = (
    "norm", "scale", "bias_only", "embed", "a_log", "dt", "gate_vec", "router",
)


@dataclasses.dataclass
class PruneSelection:
    """Knapsack output bundled for packing and reporting."""

    masks: Dict[str, Any]
    structures: LayerStructures
    result: KnapsackResult
    sparsity: float

    @property
    def kept(self) -> int:
        return int(self.result.x.sum())

    @property
    def total(self) -> int:
        return int(self.result.x.size)


def knapsack_prune(
    params: Mapping[str, Any],
    *,
    sparsity: float,
    blocking: Optional[BlockingSpec] = None,
    include: Optional[Sequence[str]] = DEFAULT_INCLUDE,
    exclude: Sequence[str] = DEFAULT_EXCLUDE,
    min_size: int = 4096,
    resource_model: Optional[TPUResourceModel] = None,
) -> PruneSelection:
    """Solve one global MDKP at ``sparsity`` and expand the masks.

    The budget is ``(1 - sparsity)`` of the model's baseline resource
    vector (MXU passes, HBM pages) — the paper's capacity constraint
    ``(1 - s) ⊙ R_B``.
    """
    if not 0.0 <= sparsity < 1.0:
        raise ValueError(f"sparsity must be in [0, 1), got {sparsity}")
    blocking = blocking or BlockingSpec()
    rm = resource_model or TPUResourceModel(precision="bf16")
    structures = build_structures(
        params, blocking, include=include, exclude=exclude, min_size=min_size
    )
    if not structures.infos:
        raise ValueError(
            f"no prunable weights matched include={include} min_size={min_size}"
        )
    values, weights = [], []
    for info in structures.infos:
        w = _get_path(params, info.path)
        norms = np.asarray(structure_norms_dense(w, info), dtype=np.float64).ravel()
        values.append(norms / max(float(norms.max()), 1e-12))
        weights.append(
            np.tile(rm.structure_cost(info.blocking)[:, None], (1, info.num_structures))
        )
    v = np.concatenate(values)
    u = np.concatenate(weights, axis=1)
    budget = u.sum(axis=1) * (1.0 - sparsity)
    result = solve_mdkp(v, u, budget)
    masks = masks_from_knapsack(params, structures, result.x.astype(np.float32))
    return PruneSelection(
        masks=masks, structures=structures, result=result, sparsity=float(sparsity)
    )
