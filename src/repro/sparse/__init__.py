"""repro.sparse — the sparse execution layer (DESIGN.md §6).

Turns a pruned model into one that actually *skips* pruned structures at
serving time (the paper's §III-C codegen, TPU edition):

* transform   pack_params / unpack_params pytree transforms (BSR leaves)
* prune       one-shot knapsack pruning for the serving entrypoints

The model stack consumes packed params unchanged: ``models/layers.matmul``
routes ``BSRWeight``/``BSRPlanes`` leaves to ``kernels.ops.bsr_matmul``
(ref on CPU, compiled Pallas on TPU) and dense arrays to the einsum path.
"""
from .prune import DEFAULT_EXCLUDE, DEFAULT_INCLUDE, PruneSelection, knapsack_prune
from .transform import (
    BSRPlanes,
    is_packed_leaf,
    pack_params,
    sparsity_summary,
    unpack_params,
)

__all__ = [
    "BSRPlanes", "is_packed_leaf", "pack_params", "sparsity_summary",
    "unpack_params",
    "DEFAULT_EXCLUDE", "DEFAULT_INCLUDE", "PruneSelection", "knapsack_prune",
]
