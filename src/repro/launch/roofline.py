"""Roofline analysis from compiled dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh), all in seconds:

    compute    = HLO_FLOPs_per_device / peak_FLOP/s          (197e12 bf16)
    memory     = HLO_bytes_per_device / HBM_bw               (819e9 B/s)
    collective = wire_bytes_per_device / ICI_link_bw         (50e9 B/s)

``compiled.cost_analysis()`` is **per-device** for SPMD modules (verified
in-repo); collective bytes are parsed from the HLO text with a ring model:

    all-gather      out_bytes * (g-1)/g     (out = full gathered buffer)
    all-reduce      2 * bytes * (g-1)/g
    reduce-scatter  shard_bytes * (g-1)
    all-to-all      bytes * (g-1)/g
    collective-permute  bytes

XLA counts a while-loop body ONCE — scans would corrupt the terms.  Models
unroll their layer/chunk loops below a threshold; the remaining scans
(sLSTM time loop, long-sequence SSM chunk loops) are corrected via
*supplements*: the scan body is compiled standalone and its costs added
(trips-1) times (x3 for train cells: fwd+bwd ~ 3x fwd — documented
approximation, only affects scan-bound archs).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig, ShapeCell
from repro.core.resource_model import TPU_V5E, HardwareSpec

__all__ = [
    "CollectiveOp", "parse_collectives", "wire_bytes_per_device",
    "roofline_terms", "model_flops", "RooflineRecord", "analyze_compiled",
]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVE_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: int          # per-device result buffer bytes
    group_size: int
    wire_bytes: float          # modeled per-device wire traffic


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))           # [num_groups, group_size]
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def _wire(kind: str, nbytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-gather":
        return nbytes * (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * nbytes * (g - 1) / g
    if kind == "reduce-scatter":
        return float(nbytes) * (g - 1)
    if kind == "all-to-all":
        return nbytes * (g - 1) / g
    if kind == "collective-permute":
        return float(nbytes)
    return 0.0


def parse_collectives(hlo_text: str, default_group: int) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        if "-done" in line:
            continue
        type_str = m.group(1) if m.group(1) is not None else m.group(2)
        kind = m.group(3)
        nbytes = _shape_bytes(type_str)
        g = _group_size(line, default_group)
        ops.append(CollectiveOp(kind, nbytes, g, _wire(kind, nbytes, g)))
    return ops


def wire_bytes_per_device(ops: List[CollectiveOp]) -> float:
    return float(sum(o.wire_bytes for o in ops))


def roofline_terms(
    flops_per_dev: float,
    bytes_per_dev: float,
    wire_per_dev: float,
    hw: HardwareSpec = TPU_V5E,
) -> Dict[str, float]:
    return {
        "compute_s": flops_per_dev / hw.peak_flops_bf16,
        "memory_s": bytes_per_dev / hw.hbm_bw,
        "collective_s": wire_per_dev / hw.ici_bw,
    }


def model_flops(cfg: ModelConfig, cell: ShapeCell) -> float:
    """Useful-model-FLOPs for the cell: 6·N·D train, 2·N·D prefill,
    2·N_active·B + KV-read flops for decode (N = active params for MoE)."""
    n_active = cfg.active_param_count()
    tokens = cell.global_batch * cell.seq_len
    if cell.kind == "train":
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        return 2.0 * n_active * tokens
    # decode: one token per sequence; attention reads the cache
    from repro.models.transformer import layer_specs

    attn_layers = sum(1 for s in layer_specs(cfg) if s.mixer == "attn")
    kv_len = min(cell.seq_len, cfg.window) if cfg.window else cell.seq_len
    attn_flops = (
        4.0 * cell.global_batch * cfg.n_heads * cfg.head_dim_() * kv_len * attn_layers
    )
    return 2.0 * n_active * cell.global_batch + attn_flops


@dataclasses.dataclass
class RooflineRecord:
    arch: str
    cell: str
    mesh: str
    chips: int
    flops_per_dev: float
    bytes_per_dev: float
    wire_per_dev: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_total: float
    useful_ratio: float          # MODEL_FLOPS / (HLO_FLOPs * chips)
    collectives: Dict[str, int]
    memory_stats: Dict[str, float]
    supplements: Dict[str, float]

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def analyze_compiled(
    compiled,
    cfg: ModelConfig,
    cell: ShapeCell,
    *,
    mesh_name: str,
    chips: int,
    default_group: int,
    supplements: Optional[Dict[str, float]] = None,
    hw: HardwareSpec = TPU_V5E,
) -> RooflineRecord:
    from repro.distributed.sharding import cost_analysis

    ca = cost_analysis(compiled)
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    ops = parse_collectives(hlo, default_group)
    wire = wire_bytes_per_device(ops)

    supplements = supplements or {}
    flops += supplements.get("flops", 0.0)
    byts += supplements.get("bytes", 0.0)

    terms = roofline_terms(flops, byts, wire, hw)
    dominant = max(terms, key=terms.get).replace("_s", "")
    mf = model_flops(cfg, cell)
    ma = compiled.memory_analysis()
    mem = {
        "argument_gb": ma.argument_size_in_bytes / 1e9,
        "output_gb": ma.output_size_in_bytes / 1e9,
        "temp_gb": ma.temp_size_in_bytes / 1e9,
        "alias_gb": ma.alias_size_in_bytes / 1e9,
        "peak_gb": (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                     + ma.output_size_in_bytes - ma.alias_size_in_bytes) / 1e9,
    }
    counts: Dict[str, int] = {}
    for o in ops:
        counts[o.kind] = counts.get(o.kind, 0) + 1
    return RooflineRecord(
        arch=cfg.name,
        cell=cell.name,
        mesh=mesh_name,
        chips=chips,
        flops_per_dev=flops,
        bytes_per_dev=byts,
        wire_per_dev=wire,
        compute_s=terms["compute_s"],
        memory_s=terms["memory_s"],
        collective_s=terms["collective_s"],
        dominant=dominant,
        model_flops_total=mf,
        useful_ratio=mf / max(flops * chips, 1e-30),
        collectives=counts,
        memory_stats=mem,
        supplements=dict(supplements),
    )
