import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("DRYRUN_XLA_EXTRA", "")
)
# ^^ MUST precede every other import (jax locks the device count on first
#    init).  Do NOT replicate this globally: tests/benches see 1 device.
# DRYRUN_XLA_EXTRA lets the grid driver trade CPU-backend codegen time for
# nothing we measure (cost analysis runs on optimized HLO, not emitted code).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. eval_shape's the full train/serve state (ShapeDtypeStruct only — no
     allocation),
  3. jits the step with explicit in/out shardings and ``.lower().compile()``s,
  4. records memory_analysis / cost_analysis / parsed collective schedule /
     roofline terms to JSON (incremental: existing results are skipped).

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both \
      --out results/dryrun [--fresh-process] [--force]
"""
import argparse
import dataclasses
import functools
import json
import subprocess
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp


def _cell_id(arch: str, shape: str, multi_pod: bool, tag: str = "") -> str:
    base = f"{arch}__{shape}__{'pod2' if multi_pod else 'pod1'}"
    return f"{base}__{tag}" if tag else base


def _parse_overrides(spec: str) -> Dict[str, Any]:
    """'seq_sharded_acts=true,row_accum_dtype=bfloat16,attn_chunk=256'"""
    out: Dict[str, Any] = {}
    for item in filter(None, (spec or "").split(",")):
        k, v = item.split("=", 1)
        if v.lower() in ("true", "false"):
            out[k] = v.lower() == "true"
        else:
            try:
                out[k] = int(v)
            except ValueError:
                out[k] = v
    return out


def run_cell(arch: str, shape: str, multi_pod: bool,
             overrides: Dict[str, Any] = None) -> Dict[str, Any]:
    """Lower+compile one cell; returns the JSON-able result record."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import SHAPES, get_config, input_specs, cell_applicable
    from repro.distributed.sharding import axis_rules, use_mesh
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import analyze_compiled
    from repro.launch.specs import cell_shardings, rules_for_cell, tree_named
    from repro.launch.supplements import supplements_for
    from repro.models.transformer import init_params
    from repro.optim.adamw import AdamWConfig
    from repro.train.train_step import (
        init_train_state,
        make_decode_step,
        make_prefill_step,
        make_train_step,
    )

    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    cell = SHAPES[shape]
    ok, reason = cell_applicable(cfg, cell)
    if not ok:
        return {"arch": arch, "cell": shape, "multi_pod": multi_pod,
                "status": "skipped", "reason": reason}

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    specs = input_specs(cfg, cell)
    opt_cfg = AdamWConfig(use_master=cfg.param_dtype != "float32")

    if cell.kind == "train":
        state_shapes = jax.eval_shape(
            lambda: init_train_state(init_params(jax.random.PRNGKey(0), cfg), opt_cfg)
        )
    else:
        state_shapes = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), cfg)
        )
        state_shapes = {"params": state_shapes}

    shardings = cell_shardings(cfg, cell, mesh, multi_pod, specs,
                               state_shapes=state_shapes)
    rules = rules_for_cell(cell, mesh, multi_pod)

    from repro.optim.schedule import warmup_cosine
    lr = warmup_cosine(3e-4, 100, 10000)

    with use_mesh(mesh), axis_rules(rules):
        if cell.kind == "train":
            step = make_train_step(cfg, opt_cfg, lr)
            in_sh = (tree_named(shardings["state"], mesh),
                     tree_named(shardings["batch"], mesh))
            out_sh = (in_sh[0], None)
            fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
            lowered = fn.lower(state_shapes, specs["batch"])
        elif cell.kind == "prefill":
            step = make_prefill_step(cfg)
            in_sh = (tree_named(shardings["params"], mesh),
                     tree_named(shardings["batch"], mesh))
            fn = jax.jit(step, in_shardings=in_sh)
            lowered = fn.lower(state_shapes["params"], specs["batch"])
        else:  # decode
            step = make_decode_step(cfg)
            cache_sh = tree_named(shardings["caches"], mesh)
            in_sh = (tree_named(shardings["params"], mesh),
                     cache_sh,
                     tree_named(shardings["batch"], mesh),
                     NamedSharding(mesh, P()))
            out_sh = (None, cache_sh)
            fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
            lowered = fn.lower(state_shapes["params"], specs["caches"],
                               specs["batch"], specs["cache_len"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        supp = supplements_for(
            cfg, cell,
            model_size=mesh.shape["model"],
            dp_size=chips // mesh.shape["model"],
        )
        record = analyze_compiled(
            compiled, cfg, cell,
            mesh_name="2x16x16" if multi_pod else "16x16",
            chips=chips,
            default_group=mesh.shape["model"],
            supplements=supp,
        )

    out = record.to_dict()
    out.update({
        "status": "ok",
        "multi_pod": multi_pod,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    })
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--fresh-process", action="store_true",
                    help="run each cell in a subprocess (crash isolation)")
    ap.add_argument("--overrides", default="",
                    help="config overrides, e.g. seq_sharded_acts=true")
    ap.add_argument("--tag", default="", help="suffix for result files")
    args = ap.parse_args()

    from repro.configs import SHAPES, list_archs

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    pods = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi_pod in pods:
                cid = _cell_id(arch, shape, multi_pod, args.tag)
                path = os.path.join(args.out, cid + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip cached] {cid}")
                    continue
                print(f"[run] {cid}", flush=True)
                if args.fresh_process:
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape,
                           "--mesh", "multi" if multi_pod else "single",
                           "--out", args.out, "--overrides", args.overrides,
                           "--tag", args.tag] + (["--force"] if args.force else [])
                    r = subprocess.run(cmd, capture_output=True, text=True,
                                       timeout=3600)
                    if r.returncode != 0:
                        failures += 1
                        err = {"arch": arch, "cell": shape, "multi_pod": multi_pod,
                               "status": "error",
                               "error": (r.stderr or r.stdout)[-4000:]}
                        with open(path, "w") as f:
                            json.dump(err, f, indent=2)
                        print(f"  FAILED (subprocess)", flush=True)
                    continue
                try:
                    rec = run_cell(arch, shape, multi_pod,
                                   _parse_overrides(args.overrides))
                except Exception as e:  # record, keep going
                    failures += 1
                    rec = {"arch": arch, "cell": shape, "multi_pod": multi_pod,
                           "status": "error", "error": traceback.format_exc()[-4000:]}
                    print(f"  FAILED: {e}", flush=True)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2, default=str)
                if rec.get("status") == "ok":
                    print(f"  ok: compile={rec['compile_s']}s "
                          f"dominant={rec['dominant']} "
                          f"compute={rec['compute_s']:.3e}s "
                          f"memory={rec['memory_s']:.3e}s "
                          f"coll={rec['collective_s']:.3e}s", flush=True)
                elif rec.get("status") == "skipped":
                    print(f"  skipped: {rec['reason']}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
