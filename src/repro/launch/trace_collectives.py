import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Collective-traffic trace for one dry-run cell: aggregates per-device
result bytes of every collective by (op kind, originating op_name) — the
§Perf microscope.

  PYTHONPATH=src python -m repro.launch.trace_collectives --arch X \
      --shape train_4k [--overrides k=v,...] [--top 20]
"""
import argparse
import re
import sys
from collections import Counter


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--overrides", default="")
    ap.add_argument("--top", type=int, default=20)
    args = ap.parse_args()

    # reuse the dryrun cell builder up to `compiled`
    from repro.launch import dryrun as dr

    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import SHAPES, get_config, input_specs
    from repro.distributed.sharding import axis_rules, cost_analysis, use_mesh
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import _shape_bytes, parse_collectives
    from repro.launch.specs import cell_shardings, rules_for_cell, tree_named
    from repro.models.transformer import init_params
    from repro.optim.adamw import AdamWConfig
    from repro.optim.schedule import warmup_cosine
    from repro.train.train_step import (
        init_train_state, make_decode_step, make_prefill_step, make_train_step)

    cfg = get_config(args.arch)
    ov = dr._parse_overrides(args.overrides)
    if ov:
        cfg = cfg.replace(**ov)
    cell = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    specs = input_specs(cfg, cell)
    opt_cfg = AdamWConfig(use_master=cfg.param_dtype != "float32")

    if cell.kind == "train":
        state_shapes = jax.eval_shape(
            lambda: init_train_state(init_params(jax.random.PRNGKey(0), cfg), opt_cfg))
    else:
        state_shapes = {"params": jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), cfg))}
    sh = cell_shardings(cfg, cell, mesh, args.multi_pod, specs,
                        state_shapes=state_shapes)
    rules = rules_for_cell(cell, mesh, args.multi_pod)
    with use_mesh(mesh), axis_rules(rules):
        if cell.kind == "train":
            fn = jax.jit(make_train_step(cfg, opt_cfg, warmup_cosine(3e-4, 100, 10000)),
                         in_shardings=(tree_named(sh["state"], mesh),
                                       tree_named(sh["batch"], mesh)),
                         out_shardings=(tree_named(sh["state"], mesh), None))
            compiled = fn.lower(state_shapes, specs["batch"]).compile()
        elif cell.kind == "prefill":
            fn = jax.jit(make_prefill_step(cfg),
                         in_shardings=(tree_named(sh["params"], mesh),
                                       tree_named(sh["batch"], mesh)))
            compiled = fn.lower(state_shapes["params"], specs["batch"]).compile()
        else:
            cache_sh = tree_named(sh["caches"], mesh)
            fn = jax.jit(make_decode_step(cfg),
                         in_shardings=(tree_named(sh["params"], mesh), cache_sh,
                                       tree_named(sh["batch"], mesh),
                                       NamedSharding(mesh, P())),
                         out_shardings=(None, cache_sh))
            compiled = fn.lower(state_shapes["params"], specs["caches"],
                                specs["batch"], specs["cache_len"]).compile()

    txt = compiled.as_text()
    agg = Counter()
    pat = re.compile(
        r"=\s+(?:\(([^)]*)\)|(\S+))\s+"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
        r"(?:-start)?\(")
    for line in txt.splitlines():
        m = pat.search(line)
        if not m or "-done" in line:
            continue
        ts = m.group(1) or m.group(2)
        op = m.group(3)
        meta = re.search(r'op_name="([^"]*)"', line)
        name = (meta.group(1) if meta else "?")[:100]
        agg[(op, name)] += _shape_bytes(ts)

    ops = parse_collectives(txt, mesh.shape["model"])
    wire = sum(o.wire_bytes for o in ops)
    print(f"total collective result bytes/dev: "
          f"{sum(agg.values())/1e9:.2f} GB; modeled wire: {wire/1e9:.2f} GB")
    for (op, name), nb in agg.most_common(args.top):
        print(f"{nb/1e9:8.3f}GB {op:18s} {name}")
    ca = cost_analysis(compiled)
    print(f"flops/dev={ca['flops']:.3e} bytes/dev={ca.get('bytes accessed',0):.3e}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
