"""Roofline supplements for scanned loop bodies (see roofline.py docstring).

XLA cost analysis counts a while-loop body once.  Our models unroll chunk
loops up to ``CHUNK_UNROLL_LIMIT`` chunks; beyond that (and for the
inherently sequential sLSTM time loop) the loop body is compiled standalone
here and its costs are added (trips-1) times.

Accounting conventions (documented approximations):
* train cells multiply body cost x3 (fwd+bwd ~= 3x fwd);
* body costs are divided by the model-axis size (the body's wide dims are
  TP-sharded in the real program);
* per-device batch = global_batch / dp_size.
Only scan-bound archs (xlstm sLSTM; jamba/xlstm long-sequence chunk scans)
have non-zero supplements.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import mamba as mamba_mod
from repro.models import xlstm as xlstm_mod
from repro.models.transformer import layer_specs

__all__ = ["supplements_for"]


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _body_cost(fn, args) -> Tuple[float, float]:
    from repro.distributed.sharding import cost_analysis

    compiled = jax.jit(fn).lower(*args).compile()
    ca = cost_analysis(compiled)
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


def supplements_for(
    cfg: ModelConfig, cell: ShapeCell, *, model_size: int, dp_size: int
) -> Dict[str, float]:
    if cell.kind == "decode":
        return {}
    s = cell.seq_len
    b = max(cell.global_batch // max(dp_size, 1), 1)
    train_mult = 3.0 if cell.kind == "train" else 1.0

    specs = layer_specs(cfg)
    n_slstm = sum(1 for sp in specs if sp.mixer == "slstm")
    n_mamba = sum(1 for sp in specs if sp.mixer == "mamba")
    n_mlstm = sum(1 for sp in specs if sp.mixer == "mlstm")

    flops = 0.0
    byts = 0.0
    detail: Dict[str, float] = {}

    # --- sLSTM time scan (always sequential) --------------------------------
    if n_slstm:
        d = cfg.d_model
        h = cfg.n_heads
        dh = d // h
        state = tuple(_sds((b, d)) for _ in range(4))

        def slstm_body(state, wx, r):
            return xlstm_mod._slstm_step(state, wx, r, h)

        f, by = _body_cost(
            slstm_body, (state, _sds((b, 4 * d)), _sds((h, dh, 4 * dh), cfg.dtype))
        )
        trips = (s - 1) * n_slstm
        flops += f * trips * train_mult
        byts += by * trips * train_mult
        detail["slstm_body_flops"] = f
        detail["slstm_trips"] = trips

    # --- mamba chunk scan (only past the unroll limit) -----------------------
    chunk = min(cfg.ssm_chunk, s)
    n_chunks = -(-s // chunk)
    scanned_ssm = n_chunks > mamba_mod.CHUNK_UNROLL_LIMIT and s % chunk == 0
    if n_mamba and scanned_ssm:
        di = 2 * cfg.d_model
        n = cfg.d_state
        dtr = max(cfg.d_model // 16, 1)
        p_spec = {
            "x_proj": {"kernel": _sds((di, dtr + 2 * n), cfg.dtype)},
            "dt_proj": {"kernel": _sds((dtr, di), cfg.dtype),
                        "bias": _sds((di,), cfg.dtype)},
        }

        def mamba_body(p, hc, xc, a):
            xcf = xc.astype(jnp.float32)
            dt, bm, cm = mamba_mod._ssm_params(p, xc)
            y, hn = mamba_mod._ssm_chunk(hc, dt, bm, cm, xcf, a)
            return hn, y

        f, by = _body_cost(
            mamba_body,
            (p_spec, _sds((b, di, n)), _sds((b, chunk, di), cfg.dtype), _sds((di, n))),
        )
        trips = (n_chunks - 1) * n_mamba
        flops += f * trips * train_mult
        byts += by * trips * train_mult
        detail["mamba_body_flops"] = f
        detail["mamba_trips"] = trips

    # --- mLSTM chunk scan -----------------------------------------------------
    scanned_mlstm = n_chunks > xlstm_mod.CHUNK_UNROLL_LIMIT and s % chunk == 0
    if n_mlstm and scanned_mlstm:
        d_in = int(cfg.mlstm_proj_factor * cfg.d_model)
        d_in -= d_in % cfg.n_heads
        h = cfg.n_heads
        dh = d_in // h
        carry = (_sds((b, h, dh, dh)), _sds((b, h, dh)), _sds((b, h)))
        qkv = _sds((b, h, chunk, dh))
        gate = _sds((b, h, chunk))
        f, by = _body_cost(
            xlstm_mod._mlstm_chunk, (carry, qkv, qkv, qkv, gate, gate)
        )
        trips = (n_chunks - 1) * n_mlstm
        flops += f * trips * train_mult
        byts += by * trips * train_mult
        detail["mlstm_body_flops"] = f
        detail["mlstm_trips"] = trips

    if flops == 0.0:
        return {}
    out = {"flops": flops / model_size, "bytes": byts / model_size}
    out.update(detail)
    return out
