"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single-pod: 16x16 = 256 chips (data, model).
Multi-pod: 2x16x16 = 512 chips (pod, data, model) — the pod axis is pure
DP with optional int8-compressed gradient all-reduce (optim/compression).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
from jax.sharding import Mesh

from repro.distributed.sharding import make_mesh

__all__ = ["make_production_mesh", "make_mesh_shape", "make_test_mesh"]


def make_mesh_shape(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return shape, axes


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape, axes = make_mesh_shape(multi_pod=multi_pod)
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} are "
            f"visible — the dry-run sets XLA_FLAGS=--xla_force_host_platform_"
            f"device_count=512 before importing jax (launch/dryrun.py)."
        )
    return make_mesh(shape, axes, devices=devices[:n])


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Small mesh for CPU unit tests (8 forced host devices)."""
    n = math.prod(shape)
    return make_mesh(shape, axes, devices=jax.devices()[:n])
