"""Per-cell sharding specs: params/opt/batch/caches PartitionSpec trees.

Centralizes every divisibility-aware placement decision of the dry-run
(DESIGN.md §4).  All helpers return PartitionSpec pytrees; NamedShardings
are built at the jit boundary.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell, input_specs
from repro.distributed.sharding import make_decode_rules, make_train_rules, param_pspecs

__all__ = [
    "dp_axes", "batch_axis_for", "cell_shardings", "state_pspecs",
    "tree_named", "rules_for_cell",
]


def dp_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def batch_axis_for(bsize: int, mesh: Mesh, multi_pod: bool):
    """Largest dp prefix that divides the batch (fallback: replicate)."""
    for cand in (dp_axes(multi_pod), ("data",), None):
        if cand is None:
            return None
        if bsize % _axis_size(mesh, cand) == 0:
            return tuple(cand)
    return None


def seq_axes_for(seq: int, mesh: Mesh, batch_sharded: bool):
    """Cache sequence placement: if batch is unshardable (long_500k B=1),
    spread the cache seq over everything that divides it."""
    cands = (("model",),) if batch_sharded else (("data", "model"), ("model",), ("data",))
    for cand in cands:
        if seq % _axis_size(mesh, cand) == 0:
            return tuple(cand)
    return None


def _dim(mesh: Mesh, size: int, axis):
    """axis if it divides size else None."""
    if axis is None or size % _axis_size(mesh, axis) != 0:
        return None
    return axis


def cache_pspecs(caches, cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
                 multi_pod: bool):
    """PartitionSpec tree matching models.transformer.init_caches output."""
    b = cell.global_batch
    bax = batch_axis_for(b, mesh, multi_pod)
    sax = None  # per-leaf, depends on allocated length

    def spec_for(path: str, leaf) -> P:
        shape = leaf.shape
        if "cross" in path:                # (B, enc_frames, kv, dh)
            return P(_dim(mesh, shape[0], bax), None, None, None)
        if path.endswith("conv"):          # mamba (B, k-1, d_inner)
            return P(_dim(mesh, shape[0], bax), None, _dim(mesh, shape[2], "model"))
        if path.endswith("ssm"):           # mamba (B, d_inner, N)
            return P(_dim(mesh, shape[0], bax), _dim(mesh, shape[1], "model"), None)
        if path.endswith("C"):             # mlstm (B, H, dk, dv)
            return P(_dim(mesh, shape[0], bax), None, None, _dim(mesh, shape[3], "model"))
        if len(shape) == 4:                # attn KV cache (B, S_alloc, kv, dh)
            s_ax = seq_axes_for(shape[1], mesh, bax is not None)
            return P(_dim(mesh, shape[0], bax), s_ax, None, None)
        if len(shape) == 3:                # mlstm n (B, H, dk)
            return P(_dim(mesh, shape[0], bax), None, _dim(mesh, shape[2], "model"))
        if len(shape) == 2:                # slstm c/n/h/m (B, d) / mlstm m (B, H)
            return P(_dim(mesh, shape[0], bax), _dim(mesh, shape[1], "model"))
        return P(*([_dim(mesh, shape[0], bax)] + [None] * (len(shape) - 1)))

    flat = jax.tree_util.tree_flatten_with_path(caches)[0]
    specs = []
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)
        specs.append(spec_for(path, leaf))
    return jax.tree.unflatten(jax.tree.structure(caches), specs)


def batch_pspecs(batch, mesh: Mesh, cell: ShapeCell, multi_pod: bool):
    bax = batch_axis_for(cell.global_batch, mesh, multi_pod)

    def spec(path, leaf):
        lead = _dim(mesh, leaf.shape[0], bax)
        return P(*([lead] + [None] * (leaf.ndim - 1)))

    flat = jax.tree_util.tree_flatten_with_path(batch)[0]
    specs = [spec(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]
    return jax.tree.unflatten(jax.tree.structure(batch), specs)


def state_pspecs(state_shapes, mesh: Mesh):
    """Specs for {"params", "opt", "step"(, "masks")}: opt moments mirror
    their parameters; counters replicated."""
    pspec = param_pspecs(state_shapes["params"], mesh)
    out: Dict[str, Any] = {"params": pspec, "step": P()}
    opt = {"m": pspec, "v": pspec, "count": P()}
    if "master" in state_shapes["opt"]:
        opt["master"] = pspec
    out["opt"] = opt
    if "masks" in state_shapes:
        out["masks"] = jax.tree.map(
            lambda leaf: None, state_shapes["masks"], is_leaf=lambda x: x is None
        )
        # masks mirror their params' sharding where present
        out["masks"] = _mask_specs(state_shapes["masks"], pspec)
    return out


def _mask_specs(masks, pspec):
    def walk(m, s):
        if isinstance(m, dict):
            return {k: walk(m[k], s.get(k) if isinstance(s, dict) else None) for k in m}
        if isinstance(m, list):
            return [walk(mm, s[i] if isinstance(s, list) else None) for i, mm in enumerate(m)]
        if m is None:
            return None
        return s if s is not None else P()

    return walk(masks, pspec)


def tree_named(pspecs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if s is not None else NamedSharding(mesh, P()),
        pspecs,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )


def rules_for_cell(cell: ShapeCell, mesh: Mesh, multi_pod: bool):
    if cell.kind == "decode":
        bax = batch_axis_for(cell.global_batch, mesh, multi_pod)
        return make_decode_rules(multi_pod, shard_cache_seq=bax is None)
    return make_train_rules(multi_pod)


def cell_shardings(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh, multi_pod: bool,
                   specs: Dict[str, Any], state_shapes=None):
    """Full sharding bundle for one dry-run cell.

    specs: output of configs.input_specs.  state_shapes: eval_shape of the
    train state (train cells only).  Returns dict of PartitionSpec trees."""
    out: Dict[str, Any] = {"batch": batch_pspecs(specs["batch"], mesh, cell, multi_pod)}
    if cell.kind == "train":
        assert state_shapes is not None
        out["state"] = state_pspecs(state_shapes, mesh)
    else:
        params_shapes = state_shapes["params"] if state_shapes and "params" in state_shapes \
            else state_shapes
        out["params"] = param_pspecs(params_shapes, mesh)
    if cell.kind == "decode":
        out["caches"] = cache_pspecs(specs["caches"], cfg, cell, mesh, multi_pod)
        out["cache_len"] = P()
    return out
