"""Training launcher: ``python -m repro.launch.train --arch qwen1.5-0.5b
--steps 200 --batch 8 --seq 256 [--prune] [--smoke]``.

On this CPU container use ``--smoke`` (reduced config); on a real fleet the
same entrypoint drives the production mesh via ``--mesh single|multi``.
"""
import argparse
import logging
import os
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--mesh", default="none", choices=["none", "single", "multi"])
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--prune", action="store_true",
                    help="run resource-aware pruning after training")
    ap.add_argument("--prune-target", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(name)s: %(message)s")

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, make_smoke
    from repro.data import LMPipeline, TokenTask
    from repro.models import init_params
    from repro.optim import AdamWConfig, warmup_cosine
    from repro.train import Trainer, TrainerConfig, init_train_state, make_train_step

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = make_smoke(cfg)
    mesh = None
    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh

        mesh = make_production_mesh(multi_pod=args.mesh == "multi")

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    opt_cfg = AdamWConfig(use_master=cfg.param_dtype != "float32")
    state = init_train_state(params, opt_cfg)
    step = jax.jit(make_train_step(
        cfg, opt_cfg, warmup_cosine(args.lr, args.steps // 10 + 1, args.steps)))

    task = TokenTask(vocab=cfg.vocab, seed=args.seed)
    pipe = LMPipeline(task, args.batch, args.seq, mesh=mesh)

    trainer = Trainer(
        step, state, pipe.batch_at,
        TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir, log_every=max(args.steps // 20, 1)),
    )
    result = trainer.run()
    print(f"done: step={result['final_step']} preempted={result['preempted']} "
          f"stragglers={len(result['stragglers'])}")
    if result["metrics"]:
        first, last = result["metrics"][0], result["metrics"][-1]
        print(f"loss {first['total_loss']:.4f} -> {last['total_loss']:.4f}")

    if args.prune:
        from repro.core import (
            BlockingSpec, IterativePruner, PruneConfig, TPUResourceModel,
            apply_masks, build_structures, constant_step,
        )
        from repro.models import cross_entropy_loss, lm_forward

        params = trainer.state["params"]
        structures = build_structures(params, BlockingSpec(bk=128, bn=128),
                                      min_size=4096)
        pruner = IterativePruner(
            structures,
            TPUResourceModel(precision=("bf16" if cfg.param_dtype == "bfloat16"
                                         else "fp32")),
            PruneConfig(schedule=constant_step([args.prune_target, args.prune_target], 0.1),
                        tolerance=0.05, higher_is_better=False),
        )
        eval_batch = pipe.batch_at(10_000)

        def eval_fn(p, masks):
            logits, _ = lm_forward(apply_masks(p, masks), eval_batch, cfg)
            return float(cross_entropy_loss(logits, eval_batch["labels"]))

        def finetune_fn(p, masks):
            st = init_train_state(p, opt_cfg, masks=masks)
            fstep = jax.jit(make_train_step(
                cfg, opt_cfg, warmup_cosine(args.lr / 3, 2, 20)))
            for s in range(10):
                st, _ = fstep(st, pipe.batch_at(20_000 + s))
            return st["params"]

        params, masks, logs = pruner.run(params, finetune_fn, eval_fn)
        for log in logs:
            red = log.reduction()
            print(f"prune it={log.iteration} metric={log.metric:.4f} "
                  f"structs={log.structure_sparsity:.1%} "
                  f"mxu_red={red[0]:.2f}x hbm_red={red[1]:.2f}x")
    return 0


if __name__ == "__main__":
    sys.exit(main())
