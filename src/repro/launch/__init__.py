"""Launchers: production mesh, dry-run, roofline, train/serve CLIs.

NOTE: importing this package does NOT touch jax device state; dryrun.py
sets XLA_FLAGS only when executed as a script.
"""
