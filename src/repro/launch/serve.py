"""Serving launcher: batched greedy decoding with KV/SSM caches.

``python -m repro.launch.serve --arch qwen1.5-0.5b --smoke --batch 4
--prompt-len 16 --gen 32``

Runs prefill (forward over the prompt, filling caches) then the decode
loop.  On a real fleet, add ``--mesh single|multi`` for the production
placement; serving with pruned weights uses the BSR path benchmarked in
benchmarks/bench_kernels.py.
"""
import argparse
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, make_smoke
    from repro.models import init_caches, init_params, lm_decode, lm_forward
    from repro.models.transformer import encode_kv_caches, encoder_forward

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = make_smoke(cfg)

    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    b, plen = args.batch, args.prompt_len
    max_len = plen + args.gen
    caches = init_caches(cfg, b, max_len, jnp.float32)

    prompt = jax.random.randint(key, (b, plen), 0, cfg.vocab)
    batch = {"tokens": prompt}
    if cfg.enc_layers:
        frames = jax.random.normal(key, (b, cfg.enc_frames, cfg.d_model))
        enc = encoder_forward(params, frames, cfg)
        caches = encode_kv_caches(params, enc, cfg, caches)

    # prefill: feed prompt tokens one by one through the decode path
    # (prefill-by-decode keeps the example simple; launch/dryrun.py lowers
    # the batched prefill step for the assigned prefill cells)
    decode = jax.jit(lambda p, c, t, l: lm_decode(p, c, {"tokens": t}, l, cfg))
    t0 = time.time()
    tok = prompt[:, :1]
    for i in range(plen):
        logits, caches = decode(params, caches, prompt[:, i:i + 1],
                                jnp.asarray(i, jnp.int32))
    out_tokens = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for i in range(args.gen):
        out_tokens.append(np.asarray(tok)[:, 0])
        logits, caches = decode(params, caches, tok,
                                jnp.asarray(plen + i, jnp.int32))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    dt = time.time() - t0
    gen = np.stack(out_tokens, axis=1)
    print(f"generated {gen.shape} tokens in {dt:.2f}s "
          f"({args.gen * b / dt:.1f} tok/s aggregate)")
    print("sample:", gen[0][:16])
    return 0


if __name__ == "__main__":
    sys.exit(main())
