"""Serving launcher: batched greedy decoding with KV/SSM caches.

``python -m repro.launch.serve --arch qwen1.5-0.5b --smoke --batch 4
--prompt-len 16 --gen 32``

Runs prefill (forward over the prompt, filling caches) then the decode
loop.  ``--pruned <sparsity>`` turns on the sparse execution layer
(DESIGN.md §6): the model is knapsack-pruned at ``--block bk,bn`` tile
granularity, packed to BSR, and every decode matmul skips pruned tiles
via the ``models/layers.matmul`` dispatch (ref path on CPU, compiled
Pallas on TPU).  On a real fleet, add ``--mesh single|multi`` for the
production placement.
"""
import argparse
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pruned", type=float, default=None, metavar="SPARSITY",
                    help="knapsack-prune to this structure sparsity and "
                         "serve through the zero-skipping BSR path")
    ap.add_argument("--block", type=str, default="128,128", metavar="BK,BN",
                    help="pruning tile shape (MXU-aligned on TPU)")
    ap.add_argument("--min-size", type=int, default=4096,
                    help="smallest weight (elements) eligible for pruning")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, make_smoke
    from repro.models import init_caches, init_params, lm_decode, lm_forward
    from repro.models.transformer import encode_kv_caches, encoder_forward

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = make_smoke(cfg)

    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)

    if args.pruned is not None:
        from repro.core import BlockingSpec
        from repro.kernels.ops import on_tpu
        from repro.sparse import knapsack_prune, pack_params, sparsity_summary

        bk, bn = (int(t) for t in args.block.split(","))
        sel = knapsack_prune(
            params, sparsity=args.pruned,
            blocking=BlockingSpec(bk=bk, bn=bn), min_size=args.min_size,
        )
        params = pack_params(params, sel.masks, sel.structures)
        summ = sparsity_summary(params)
        path = "pallas" if on_tpu() else "ref (CPU)"
        print(f"pruned: kept {sel.kept}/{sel.total} structures "
              f"({sel.result.method}, feasible={sel.result.feasible}); "
              f"BSR density {summ['density']:.2f} "
              f"({summ['nnz_blocks']}/{summ['total_blocks']} blocks), "
              f"dispatch={path}")
        for p, d in sorted(summ["per_path"].items())[:4]:
            print(f"  {p}: density {d:.2f}")

    b, plen = args.batch, args.prompt_len
    max_len = max(plen + args.gen, 1)
    caches = init_caches(cfg, b, max_len, jnp.float32)

    prompt = jax.random.randint(key, (b, max(plen, 1)), 0, cfg.vocab)
    if cfg.enc_layers:
        frames = jax.random.normal(key, (b, cfg.enc_frames, cfg.d_model))
        enc = encoder_forward(params, frames, cfg)
        caches = encode_kv_caches(params, enc, cfg, caches)

    # prefill: feed prompt tokens one by one through the decode path
    # (prefill-by-decode keeps the example simple; launch/dryrun.py lowers
    # the batched prefill step for the assigned prefill cells)
    decode = jax.jit(lambda p, c, t, l: lm_decode(p, c, {"tokens": t}, l, cfg))
    t0 = time.time()
    if plen > 0:
        for i in range(plen):
            logits, caches = decode(params, caches, prompt[:, i:i + 1],
                                    jnp.asarray(i, jnp.int32))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    else:
        # empty prompt: start generation from token 0 (a stand-in BOS)
        tok = jnp.zeros((b, 1), jnp.int32)
    out_tokens = []
    for i in range(args.gen):
        out_tokens.append(np.asarray(tok)[:, 0])
        logits, caches = decode(params, caches, tok,
                                jnp.asarray(plen + i, jnp.int32))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    dt = max(time.time() - t0, 1e-9)
    gen = (np.stack(out_tokens, axis=1) if out_tokens
           else np.zeros((b, 0), np.int32))
    print(f"generated {gen.shape} tokens in {dt:.2f}s "
          f"({args.gen * b / dt:.1f} tok/s aggregate)")
    if out_tokens:
        print("sample:", gen[0][:16])
    return 0


if __name__ == "__main__":
    sys.exit(main())
