"""Serving launcher: batched prefill + on-device greedy decode loop.

``python -m repro.launch.serve --arch qwen1.5-0.5b --smoke --batch 4
--prompt-len 16 --gen 32``

The hot path is two jitted calls (DESIGN.md §7):

1. **prefill** — one ``lm_prefill`` pass over the whole prompt fills every
   KV/SSM cache and yields the first generated token (argmax on device);
2. **decode** — one ``lm_generate`` call runs the entire greedy loop as a
   ``jax.lax.scan`` with the caches in the carry: N tokens, zero host
   round-trips, one device->host transfer at the end.

``--pruned <sparsity>`` turns on the sparse execution layer (DESIGN.md
§6/§7): the model is knapsack-pruned at ``--block bk,bn`` tile
granularity, packed to BSR, and every matmul on both calls skips pruned
tiles via the ``models/layers.matmul`` dispatch (zero-skipping ref path
on CPU, compiled Pallas on TPU; MoE experts go through the fused
flattened-planes kernel).  On a real fleet, add ``--mesh single|multi``
for the production placement.

``--stream`` switches to request-level serving (DESIGN.md §9/§10):
ragged prompts arrive every ``--arrive-every`` ticks and flow through
the continuous-batching engine — paged KV pool (prompt K/V written
straight into the request's pages at prefill), ``--ticks-per-sync``
decode steps scanned on device between scheduler events, EOS'd slots
re-admitted from the queue.  ``--request-temperatures`` cycles
per-request sampling temperatures through the stream (co-batched
requests sample independently).  Each finished stream is verified
token-identical against its solo decode — including sampled streams,
which are replicated with the engine's per-slot key derivation.
"""
import argparse
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature; 0 = greedy argmax")
    ap.add_argument("--top-k", type=int, default=None,
                    help="sample from the k highest-probability tokens")
    ap.add_argument("--top-p", type=float, default=None,
                    help="nucleus sampling probability mass")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop token: finished rows emit it and the scan "
                         "body early-exits once all rows are done")
    ap.add_argument("--pruned", type=float, default=None, metavar="SPARSITY",
                    help="knapsack-prune to this structure sparsity and "
                         "serve through the zero-skipping BSR path")
    ap.add_argument("--block", type=str, default="128,128", metavar="BK,BN",
                    help="pruning tile shape (MXU-aligned on TPU)")
    ap.add_argument("--min-size", type=int, default=4096,
                    help="smallest weight (elements) eligible for pruning")
    ap.add_argument("--stream", action="store_true",
                    help="continuous batching over a streamed request "
                         "arrival pattern (paged KV pool, prefill-on-join)")
    ap.add_argument("--requests", type=int, default=6,
                    help="[--stream] number of requests in the stream")
    ap.add_argument("--arrive-every", type=int, default=2,
                    help="[--stream] ticks between request arrivals")
    ap.add_argument("--page-size", type=int, default=8,
                    help="[--stream] tokens per physical KV page")
    ap.add_argument("--ticks-per-sync", type=int, default=4,
                    help="[--stream] decode steps batched into one "
                         "on-device chunk between scheduler events "
                         "(1 = host sync per token)")
    ap.add_argument("--adaptive", action="store_true",
                    help="[--stream] SLO-aware adaptive chunking "
                         "(DESIGN.md §15): the chunk length becomes a "
                         "policy pick from a geometric level ladder "
                         "topped at --ticks-per-sync — shrinking toward "
                         "slot-free events and SLO edges when the queue "
                         "is hot, growing back when calm.  Requests get "
                         "alternating priority classes with soft TTFT "
                         "targets on the interactive class; the run "
                         "fails unless at least one chunk-shrink event "
                         "fired and every stream still verifies "
                         "bit-identical to its solo decode")
    ap.add_argument("--request-temperatures", type=str, default=None,
                    metavar="T0,T1,...",
                    help="[--stream] per-request sampling temperatures, "
                         "cycled over the stream (overrides --temperature "
                         "per request; 0 = greedy)")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="[--stream] all requests share a long common "
                         "prompt prefix (and the first two share the FULL "
                         "prompt) to exercise the prefix cache: hit "
                         "requests map the cached pages and prefill only "
                         "their tail (DESIGN.md §12); streams still "
                         "verify token-identical vs solo decode")
    ap.add_argument("--chaos", action="store_true",
                    help="seeded fault-injection smoke (DESIGN.md §13): "
                         "serve a stream under a deterministic plan of "
                         "NaN poisoning, allocator failure, index "
                         "corruption, a chunk crash, a cancel, a deadline "
                         "and queue-overflow rejects; verify every "
                         "request reaches a terminal status, non-faulted "
                         "streams stay bit-identical to solo decode, and "
                         "the page pool drains exactly")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, make_smoke
    from repro.models import init_caches, init_params, lm_generate, lm_prefill
    from repro.models.transformer import encode_kv_caches, encoder_forward

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = make_smoke(cfg)

    # independent streams for weights / benchmark inputs — reusing one key
    # would correlate the random prompt (and encoder frames) with the
    # weight draw and skew every benchmark number derived from them
    key_params, key_prompt, key_frames, key_sample = jax.random.split(
        jax.random.PRNGKey(args.seed), 4)
    params = init_params(key_params, cfg)

    if args.pruned is not None:
        from repro.core import BlockingSpec
        from repro.kernels.ops import on_tpu
        from repro.sparse import knapsack_prune, pack_params, sparsity_summary

        bk, bn = (int(t) for t in args.block.split(","))
        sel = knapsack_prune(
            params, sparsity=args.pruned,
            blocking=BlockingSpec(bk=bk, bn=bn), min_size=args.min_size,
        )
        params = pack_params(params, sel.masks, sel.structures)
        summ = sparsity_summary(params)
        path = "pallas" if on_tpu() else "ref (CPU)"
        print(f"pruned: kept {sel.kept}/{sel.total} structures "
              f"({sel.result.method}, feasible={sel.result.feasible}); "
              f"BSR density {summ['density']:.2f} "
              f"({summ['nnz_blocks']}/{summ['total_blocks']} blocks), "
              f"dispatch={path}")
        for p, d in sorted(summ["per_path"].items())[:4]:
            print(f"  {p}: density {d:.2f}")

    if args.chaos:
        return _run_chaos(args, cfg, params)
    if args.stream:
        return _run_stream(args, cfg, params)

    b, plen = args.batch, args.prompt_len
    max_len = max(plen + args.gen, 1)
    caches = init_caches(cfg, b, max_len, jnp.float32)

    prompt = jax.random.randint(key_prompt, (b, max(plen, 1)), 0, cfg.vocab)
    if cfg.enc_layers:
        frames = jax.random.normal(key_frames, (b, cfg.enc_frames, cfg.d_model))
        enc = encoder_forward(params, frames, cfg)
        caches = encode_kv_caches(params, enc, cfg, caches)

    # prefill: ONE lm_prefill call over the whole prompt fills the caches
    # and produces the first token — not prompt_len decode steps
    @jax.jit
    def prefill(p, c, toks):
        logits, c = lm_prefill(p, c, {"tokens": toks}, cfg)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        return tok, c

    # decode: ONE lm_generate call (lax.scan) emits every token on device;
    # sampling (temperature/top-k/top-p) and EOS early-exit run inside the
    # scan — still zero host round-trips per token
    sample_key = key_sample
    generate = jax.jit(
        lambda p, c, t, l: lm_generate(
            p, c, t, l, args.gen, cfg,
            temperature=args.temperature, top_k=args.top_k,
            top_p=args.top_p, eos_id=args.eos_id, key=sample_key))

    # warm both calls once (trace + XLA compile) so the printed numbers
    # measure steady-state serving, not compilation
    if plen > 0:
        wtok, wcaches = prefill(params, caches, prompt)
    else:
        wtok, wcaches = jnp.zeros((b, 1), jnp.int32), caches
    jax.block_until_ready(
        generate(params, wcaches, wtok, jnp.asarray(plen, jnp.int32)))

    t0 = time.time()
    if plen > 0:
        tok, caches = prefill(params, caches, prompt)
    else:
        # empty prompt: start generation from token 0 (a stand-in BOS)
        tok = jnp.zeros((b, 1), jnp.int32)
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0

    t1 = time.time()
    tokens, caches = generate(params, caches, tok, jnp.asarray(plen, jnp.int32))
    gen = np.asarray(tokens)          # the single host transfer
    dt_dec = max(time.time() - t1, 1e-9)
    dt = max(time.time() - t0, 1e-9)

    print(f"generated {gen.shape} tokens in {dt:.2f}s "
          f"(prefill {t_prefill * 1e3:.1f}ms, decode "
          f"{args.gen * b / dt_dec:.1f} tok/s aggregate)")
    if gen.shape[1]:
        print("sample:", gen[0][:16])
    return 0


def _run_stream(args, cfg, params) -> int:
    """Continuous-batching demo: ragged prompts arrive over time, flow
    through the paged-KV engine in ``--ticks-per-sync`` on-device decode
    chunks, and every finished stream — greedy OR sampled — is checked
    token-identical against its solo decode (sampled streams are
    replicated with the engine's per-slot fold_in(base, rid) keys)."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models import init_caches, lm_generate, lm_prefill
    from repro.serving import AdaptiveChunkPolicy, ServingEngine

    plen, gen = max(args.prompt_len, 1), args.gen
    rng = np.random.default_rng(args.seed)
    if args.shared_prefix:
        # long common prefix + short unique tails; requests 0 and 1 get
        # the IDENTICAL full prompt — duplicate prompts must still get
        # unique rids and per-request fold_in keys (verified below)
        tail = max(plen // 4, 1)
        pre = max(plen - tail, 0)
        prefix = rng.integers(0, cfg.vocab, size=pre).astype(np.int32)
        prompts = [np.concatenate([
            prefix, rng.integers(0, cfg.vocab, size=tail).astype(np.int32)])
            for _ in range(args.requests)]
        if args.requests >= 2:
            prompts[1] = prompts[0].copy()
        lens = np.asarray([len(p) for p in prompts])
    else:
        lens = rng.integers(max(1, plen // 2), plen + 1, size=args.requests)
        prompts = [rng.integers(0, cfg.vocab, size=int(l)).astype(np.int32)
                   for l in lens]
    req_temps = None
    if args.request_temperatures:
        req_temps = [float(t) for t in args.request_temperatures.split(",")]

    # adaptive mode: geometric chunk-level ladder topped at the fixed
    # setting, alternating priority classes, soft TTFT targets on the
    # interactive (priority 0) class — the smoke must see a shrink
    policy = None
    if args.adaptive:
        levels = sorted({1, args.ticks_per_sync}
                        | {2 ** k for k in range(10)
                           if 2 ** k < args.ticks_per_sync})
        policy = AdaptiveChunkPolicy(levels=tuple(levels))

    def build():
        eng = ServingEngine(
            params, cfg, num_slots=args.batch, page_size=args.page_size,
            max_seq_len=plen + gen, ticks_per_sync=args.ticks_per_sync,
            chunk_policy=policy, temperature=args.temperature,
            top_k=args.top_k, top_p=args.top_p, eos_id=args.eos_id,
            seed=args.seed)
        for i, p in enumerate(prompts):
            kw = {}
            if req_temps is not None:
                kw["temperature"] = req_temps[i % len(req_temps)]
            if args.adaptive:
                kw["priority"] = i % 2
                if i % 2 == 0:
                    kw["ttft_target_ticks"] = 2 * args.ticks_per_sync
            eng.submit(p, gen, arrival=i * args.arrive_every, **kw)
        return eng

    # warm the jitted prefill/chunk shapes so the printed numbers are
    # steady-state (same discipline as the static path above)
    build().run()
    engine = build()

    t0 = time.time()
    done = engine.run()
    dt = max(time.time() - t0, 1e-9)
    emitted = sum(len(r.tokens) for r in done.values())
    print(f"streamed {len(done)} requests (ragged prompts "
          f"{int(lens.min())}..{int(lens.max())}, arrivals every "
          f"{args.arrive_every} ticks, {args.ticks_per_sync} ticks/sync) "
          f"in {dt:.2f}s: {emitted} tokens, "
          f"{emitted / dt:.1f} tok/s aggregate, slot utilization "
          f"{engine.slot_utilization:.2f}, "
          f"{engine.pool.num_pages}x{args.page_size}-token pages/layer")
    joins = [r.admitted_at for r in done.values()]
    print(f"  joins at ticks {sorted(joins)}; "
          f"pool free pages after drain: {engine.pool.free_pages}")
    st = engine.prefix_stats
    if st["enabled"]:
        print(f"  prefix cache: {st['hit_requests']}/{st['lookups']} "
              f"admissions hit, {st['pages_shared']} pages mapped instead "
              f"of prefilled, {st['blocks_indexed']} blocks resident, "
              f"{st['cow_copies']} COW copies, refcount high-water "
              f"{st['ref_high_water']}")
    if args.adaptive:
        slo = engine.slo_stats()
        print(f"  slo: chunks_by_ticks={slo['chunks_by_ticks']} "
              f"shrinks={slo['chunk_shrinks']} grows={slo['chunk_grows']} "
              f"ttft_misses={slo['ttft_target_misses']} "
              f"by_priority={slo['by_priority']}")
        if slo["chunk_shrinks"] < 1:
            print("stream verify FAILED: adaptive run never shrank a "
                  "chunk (policy inert)")
            return 1
        extra = set(slo["chunks_by_ticks"]) - set(slo["chunk_levels"])
        if extra:
            print(f"stream verify FAILED: undeclared chunk lengths "
                  f"{sorted(extra)} ran (compile set violated)")
            return 1
    if args.shared_prefix:
        # dedupe safety: N identical full prompts must still be distinct
        # requests — unique rids, and (for sampled runs) independent
        # fold_in(base, rid) keys; the per-rid solo replication below is
        # what proves each stream used its own key
        rids = sorted(done)
        assert len(set(rids)) == len(done), f"duplicate rids: {rids}"
        if st["enabled"] and st["hit_requests"] == 0:
            print("stream verify FAILED: shared-prefix run produced no "
                  "prefix-cache hits")
            return 1

    # token-identity vs solo decode through the static hot path.  Each
    # request replays with ITS effective sampling params and the engine's
    # per-slot key (fold_in(base, rid)) — so mixed greedy/sampled streams
    # verify too.  Retraces per distinct (prompt length, sampling combo).
    prefill = jax.jit(lambda p, c, t: lm_prefill(p, c, {"tokens": t}, cfg))
    base_key = jax.random.PRNGKey(args.seed)
    # sampling params are static (python-level branches in lm_generate):
    # jit's own cache keys one compilation per distinct combo
    generate = jax.jit(
        lambda pp, c, tok, l, key, t, k, p: lm_generate(
            pp, c, tok, l, gen, cfg, temperature=t, top_k=k, top_p=p,
            eos_id=args.eos_id, key=key),
        static_argnums=(5, 6, 7))

    bad = 0
    for rid, req in sorted(done.items()):
        toks = jnp.asarray(req.prompt[None])
        caches = init_caches(cfg, 1, req.prompt_len + gen, jnp.float32)
        logits, caches = prefill(params, caches, toks)
        first = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        t, k, p = engine.sampling_for(req)
        want, _ = generate(
            params, caches, first, jnp.asarray(req.prompt_len, jnp.int32),
            jax.random.fold_in(base_key, rid), t, k, p)
        # a stream may only be short of --gen if it legitimately hit EOS
        # — otherwise a prefix match would mask dropped trailing tokens
        short_ok = (args.eos_id is not None and len(req.tokens) >= 1
                    and req.tokens[-1] == args.eos_id)
        want = np.asarray(want)[0][:len(req.tokens)]
        if not np.array_equal(req.tokens, want) or (
                len(req.tokens) != gen and not short_ok):
            bad += 1
            print(f"  request {rid}: MISMATCH vs solo decode "
                  f"(got {len(req.tokens)} toks {req.tokens[:8]}.. "
                  f"want {gen} toks {want[:8]}..)")
    if bad:
        print(f"stream verify FAILED: {bad}/{len(done)} requests diverged")
        return 1
    n_sampled = sum(1 for r in done.values()
                    if engine.sampling_for(r)[0] > 0)
    print(f"  verify OK: all {len(done)} streams token-identical to "
          f"solo decode ({n_sampled} sampled, {len(done) - n_sampled} "
          "greedy)")
    return 0


def _run_chaos(args, cfg, params) -> int:
    """Seeded fault-injection smoke (DESIGN.md §13): a streamed workload
    plus a deterministic plan of every fault kind, a cancel, a deadline
    and queue-overflow rejects.  Verifies the engine's fault contract
    end-to-end: every request terminal, the faulted/cancelled/expired
    streams carrying correct solo-prefix partials, every NON-faulted
    stream bit-identical to its solo decode, all fault counters
    registering, and the page pool draining exactly."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models import init_caches, lm_generate, lm_prefill
    from repro.serving import (FaultInjector, RequestStatus, ServingEngine,
                               alloc_failure, chunk_exception,
                               index_corruption, nan_logit)

    plen, gen = max(args.prompt_len, 2), max(args.gen, 12)
    rng = np.random.default_rng(args.seed)
    lens = rng.integers(max(2, plen // 2), plen + 1, size=args.requests)
    prompts = [rng.integers(0, cfg.vocab, size=int(l)).astype(np.int32)
               for l in lens]
    victim = 1 % args.requests          # rid the NaN fault targets

    def build(injector=None, max_queue=None):
        eng = ServingEngine(
            params, cfg, num_slots=args.batch, page_size=args.page_size,
            max_seq_len=plen + gen, ticks_per_sync=args.ticks_per_sync,
            eos_id=args.eos_id, seed=args.seed, max_queue=max_queue,
            fault_injector=injector)
        for i, p in enumerate(prompts):
            eng.submit(p, gen, arrival=i * args.arrive_every)
        return eng

    # warm the jitted shapes faults will replay through — including the
    # degraded ticks_per_sync=1 chunk the crash recovery falls back to
    build().run()
    if args.ticks_per_sync != 1:
        w = build()
        w.ticks_per_sync = 1
        w.run()

    plan = [
        alloc_failure(0),                 # admission unwound + retried
        index_corruption(3),              # caught by verify() -> cache drop
        nan_logit(6, rid=victim),         # quarantined, others untouched
        chunk_exception(9),               # snapshot restore + degraded mode
    ]
    inj = FaultInjector(plan, seed=args.seed)
    engine = build(injector=inj, max_queue=args.requests + 2)
    # lifecycle extras: one request cancelled while queued, one that
    # cannot finish inside its deadline, and two rejects past the bound
    rid_cancel = engine.submit(prompts[0], gen, arrival=10_000)
    rid_expire = engine.submit(
        prompts[-1], gen, arrival=0, deadline_ticks=max(3, gen // 2))
    rejected = [engine.submit(prompts[0], gen, arrival=0) for _ in range(3)]
    engine.cancel(rid_cancel)

    t0 = time.time()
    done = engine.run()
    dt = max(time.time() - t0, 1e-9)
    stats = engine.fault_stats
    print(f"chaos: {len(done)} requests terminal in {dt:.2f}s under "
          f"{len(plan)} injected faults + cancel/deadline/overflow")
    print(f"  statuses: "
          f"{sorted((r.rid, r.status.value) for r in done.values())}")
    print(f"  fault counters: {stats}")
    print(f"  injector fired: {[(k, t) for k, t, _ in inj.fired]}")

    failures = []

    def check(cond, msg):
        if not cond:
            failures.append(msg)

    # 1. totality: every submitted request reached a terminal status
    check(len(done) == len(engine.requests),
          f"{len(engine.requests) - len(done)} requests not terminal")
    check(all(r.terminal for r in engine.requests.values()),
          "non-terminal request status")
    # 2. the planned fates landed
    check(done[rid_cancel].status is RequestStatus.CANCELLED,
          f"cancel victim ended {done[rid_cancel].status}")
    check(done[rid_expire].status is RequestStatus.EXPIRED,
          f"deadline victim ended {done[rid_expire].status}")
    for r in rejected:
        check(done[r].status is RequestStatus.REJECTED,
              f"overflow submit {r} ended {done[r].status}")
    check(done[victim].status is RequestStatus.FAILED,
          f"NaN victim ended {done[victim].status}")
    # 3. every fault path actually exercised
    for counter in ("guard_trips", "chunk_failures", "alloc_failures",
                    "index_drops", "rejected", "cancelled", "expired",
                    "degraded"):
        check(stats[counter] >= 1, f"counter {counter} never tripped")
    check(not inj.pending, f"faults never fired: {inj.pending}")

    # 4. token correctness: non-faulted streams bit-identical to solo
    # decode; FAILED/EXPIRED partials are clean solo prefixes
    prefill = jax.jit(lambda p, c, t: lm_prefill(p, c, {"tokens": t}, cfg))
    generate = jax.jit(
        lambda pp, c, tok, l: lm_generate(
            pp, c, tok, l, gen, cfg, eos_id=args.eos_id))
    for rid, req in sorted(done.items()):
        if req.status is RequestStatus.REJECTED or len(req.tokens) == 0:
            continue
        toks = jnp.asarray(req.prompt[None])
        caches = init_caches(cfg, 1, req.prompt_len + gen, jnp.float32)
        logits, caches = prefill(params, caches, toks)
        first = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        want, _ = generate(params, caches, first,
                           jnp.asarray(req.prompt_len, jnp.int32))
        want = np.asarray(want)[0]
        if req.status is RequestStatus.FINISHED:
            check(np.array_equal(req.tokens, want),
                  f"rid {rid}: non-faulted stream diverged from solo")
        else:   # FAILED / EXPIRED / CANCELLED partials
            check(np.array_equal(req.tokens, want[:len(req.tokens)]),
                  f"rid {rid} ({req.status.value}): partial tokens are "
                  f"not a solo-decode prefix")
    # 5. no page leaked through any of it
    engine.release_prefix_cache()
    check(engine.pool.free_pages == engine.pool.num_pages - 1,
          f"pool did not drain: {engine.pool.free_pages}/"
          f"{engine.pool.num_pages - 1}")
    check(engine.pool.live_refs() == 0, "dangling page references")

    if failures:
        for f in failures:
            print(f"  chaos verify FAILED: {f}")
        return 1
    n_ok = sum(1 for r in done.values()
               if r.status is RequestStatus.FINISHED)
    print(f"  verify OK: {n_ok} streams bit-identical to solo decode, "
          f"faulted/cancelled/expired partials are clean prefixes, "
          f"pool drained exactly")
    return 0


if __name__ == "__main__":
    sys.exit(main())
