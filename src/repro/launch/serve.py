"""Serving launcher: batched prefill + on-device greedy decode loop.

``python -m repro.launch.serve --arch qwen1.5-0.5b --smoke --batch 4
--prompt-len 16 --gen 32``

The hot path is two jitted calls (DESIGN.md §7):

1. **prefill** — one ``lm_prefill`` pass over the whole prompt fills every
   KV/SSM cache and yields the first generated token (argmax on device);
2. **decode** — one ``lm_generate`` call runs the entire greedy loop as a
   ``jax.lax.scan`` with the caches in the carry: N tokens, zero host
   round-trips, one device->host transfer at the end.

``--pruned <sparsity>`` turns on the sparse execution layer (DESIGN.md
§6/§7): the model is knapsack-pruned at ``--block bk,bn`` tile
granularity, packed to BSR, and every matmul on both calls skips pruned
tiles via the ``models/layers.matmul`` dispatch (zero-skipping ref path
on CPU, compiled Pallas on TPU; MoE experts go through the fused
flattened-planes kernel).  On a real fleet, add ``--mesh single|multi``
for the production placement.
"""
import argparse
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature; 0 = greedy argmax")
    ap.add_argument("--top-k", type=int, default=None,
                    help="sample from the k highest-probability tokens")
    ap.add_argument("--top-p", type=float, default=None,
                    help="nucleus sampling probability mass")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop token: finished rows emit it and the scan "
                         "body early-exits once all rows are done")
    ap.add_argument("--pruned", type=float, default=None, metavar="SPARSITY",
                    help="knapsack-prune to this structure sparsity and "
                         "serve through the zero-skipping BSR path")
    ap.add_argument("--block", type=str, default="128,128", metavar="BK,BN",
                    help="pruning tile shape (MXU-aligned on TPU)")
    ap.add_argument("--min-size", type=int, default=4096,
                    help="smallest weight (elements) eligible for pruning")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, make_smoke
    from repro.models import init_caches, init_params, lm_generate, lm_prefill
    from repro.models.transformer import encode_kv_caches, encoder_forward

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = make_smoke(cfg)

    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)

    if args.pruned is not None:
        from repro.core import BlockingSpec
        from repro.kernels.ops import on_tpu
        from repro.sparse import knapsack_prune, pack_params, sparsity_summary

        bk, bn = (int(t) for t in args.block.split(","))
        sel = knapsack_prune(
            params, sparsity=args.pruned,
            blocking=BlockingSpec(bk=bk, bn=bn), min_size=args.min_size,
        )
        params = pack_params(params, sel.masks, sel.structures)
        summ = sparsity_summary(params)
        path = "pallas" if on_tpu() else "ref (CPU)"
        print(f"pruned: kept {sel.kept}/{sel.total} structures "
              f"({sel.result.method}, feasible={sel.result.feasible}); "
              f"BSR density {summ['density']:.2f} "
              f"({summ['nnz_blocks']}/{summ['total_blocks']} blocks), "
              f"dispatch={path}")
        for p, d in sorted(summ["per_path"].items())[:4]:
            print(f"  {p}: density {d:.2f}")

    b, plen = args.batch, args.prompt_len
    max_len = max(plen + args.gen, 1)
    caches = init_caches(cfg, b, max_len, jnp.float32)

    prompt = jax.random.randint(key, (b, max(plen, 1)), 0, cfg.vocab)
    if cfg.enc_layers:
        frames = jax.random.normal(key, (b, cfg.enc_frames, cfg.d_model))
        enc = encoder_forward(params, frames, cfg)
        caches = encode_kv_caches(params, enc, cfg, caches)

    # prefill: ONE lm_prefill call over the whole prompt fills the caches
    # and produces the first token — not prompt_len decode steps
    @jax.jit
    def prefill(p, c, toks):
        logits, c = lm_prefill(p, c, {"tokens": toks}, cfg)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        return tok, c

    # decode: ONE lm_generate call (lax.scan) emits every token on device;
    # sampling (temperature/top-k/top-p) and EOS early-exit run inside the
    # scan — still zero host round-trips per token
    sample_key = jax.random.PRNGKey(args.seed + 1)
    generate = jax.jit(
        lambda p, c, t, l: lm_generate(
            p, c, t, l, args.gen, cfg,
            temperature=args.temperature, top_k=args.top_k,
            top_p=args.top_p, eos_id=args.eos_id, key=sample_key))

    # warm both calls once (trace + XLA compile) so the printed numbers
    # measure steady-state serving, not compilation
    if plen > 0:
        wtok, wcaches = prefill(params, caches, prompt)
    else:
        wtok, wcaches = jnp.zeros((b, 1), jnp.int32), caches
    jax.block_until_ready(
        generate(params, wcaches, wtok, jnp.asarray(plen, jnp.int32)))

    t0 = time.time()
    if plen > 0:
        tok, caches = prefill(params, caches, prompt)
    else:
        # empty prompt: start generation from token 0 (a stand-in BOS)
        tok = jnp.zeros((b, 1), jnp.int32)
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0

    t1 = time.time()
    tokens, caches = generate(params, caches, tok, jnp.asarray(plen, jnp.int32))
    gen = np.asarray(tokens)          # the single host transfer
    dt_dec = max(time.time() - t1, 1e-9)
    dt = max(time.time() - t0, 1e-9)

    print(f"generated {gen.shape} tokens in {dt:.2f}s "
          f"(prefill {t_prefill * 1e3:.1f}ms, decode "
          f"{args.gen * b / dt_dec:.1f} tok/s aggregate)")
    if gen.shape[1]:
        print("sample:", gen[0][:16])
    return 0


if __name__ == "__main__":
    sys.exit(main())
