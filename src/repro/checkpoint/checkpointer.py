"""Checkpointing: atomic, async-capable, elastic-restorable.

Layout per step::

    <dir>/step_<n>.tmp/            (write in progress)
    <dir>/step_<n>/
        meta.msgpack               tree structure, shapes, dtypes, step
        leaf_00000.npy ...         one file per pytree leaf (host np arrays)
        COMMITTED                  commit marker (written last)

Fault-tolerance contract:
* writes go to a ``.tmp`` dir, the commit marker is written, then the dir
  is atomically renamed — a crash mid-save never corrupts the latest
  checkpoint and ``latest_step`` only ever returns committed steps;
* ``restore`` can re-device_put onto a *different* mesh/shardings than the
  save used (elastic scaling): arrays are saved as full logical values;
* ``save_async`` snapshots to host then writes on a worker thread so the
  training loop is blocked only for the device->host copy;
* ``keep`` bounds disk usage (oldest committed steps pruned).
"""
from __future__ import annotations

import os
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import msgpack
import numpy as np

__all__ = ["Checkpointer"]


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    paths, leaves = [], []
    for kp, leaf in flat:
        paths.append(jax.tree_util.keystr(kp))
        leaves.append(leaf)
    return paths, leaves


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- inspection ----------------------------------------------------------

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def committed_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.directory):
            full = os.path.join(self.directory, name)
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(full, "COMMITTED")):
                    steps.append(int(name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    # -- save ------------------------------------------------------------------

    def save(self, step: int, state: Any, *, blocking: bool = True) -> None:
        # serialize with any in-flight async save: two writers racing on the
        # same step dir turn rmtree/makedirs into FileExists/FileNotFound
        self.wait()
        paths, leaves = _flatten_with_paths(state)
        # device->host snapshot (the only part that must block the step loop)
        host_leaves = [np.asarray(l) for l in leaves]
        treedef = jax.tree.structure(state)

        def write():
            tmp = self._step_dir(step) + ".tmp"
            final = self._step_dir(step)
            if os.path.exists(tmp):
                shutil.rmtree(tmp)       # stale .tmp from a crashed writer
            os.makedirs(tmp, exist_ok=True)
            meta = {
                "step": step,
                "paths": paths,
                "shapes": [list(h.shape) for h in host_leaves],
                "dtypes": [str(h.dtype) for h in host_leaves],
                "treedef": str(treedef),
            }
            for i, h in enumerate(host_leaves):
                np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), h, allow_pickle=False)
            with open(os.path.join(tmp, "meta.msgpack"), "wb") as f:
                f.write(msgpack.packb(meta))
            with open(os.path.join(tmp, "COMMITTED"), "w") as f:
                f.write("ok")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def save_async(self, step: int, state: Any) -> None:
        self.save(step, state, blocking=False)

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self) -> None:
        steps = self.committed_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def restore(
        self,
        step: Optional[int] = None,
        *,
        target: Any = None,
        shardings: Any = None,
    ) -> Any:
        """Load a committed checkpoint.

        ``target``: pytree prototype whose structure the leaves are
        unflattened into (required — treedefs are not unpickled from disk
        for safety).  ``shardings``: optional matching pytree of
        NamedShardings for elastic placement on the current mesh."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no committed checkpoints in {self.directory}")
        d = self._step_dir(step)
        if not os.path.exists(os.path.join(d, "COMMITTED")):
            raise FileNotFoundError(f"checkpoint step {step} not committed")
        with open(os.path.join(d, "meta.msgpack"), "rb") as f:
            meta = msgpack.unpackb(f.read())
        host = [
            np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
            for i in range(len(meta["paths"]))
        ]
        if target is None:
            return {"step": meta["step"], "leaves": host, "paths": meta["paths"]}
        treedef = jax.tree.structure(target)
        if treedef.num_leaves != len(host):
            raise ValueError(
                f"target has {treedef.num_leaves} leaves, checkpoint {len(host)}"
            )
        if shardings is not None:
            flat_s = treedef.flatten_up_to(shardings)
            host = [
                jax.device_put(h, s) if s is not None else jax.device_put(h)
                for h, s in zip(host, flat_s)
            ]
        else:
            host = [jax.device_put(h) for h in host]
        return jax.tree.unflatten(treedef, host)
