"""Atomic / async / elastic checkpointing."""
from .checkpointer import Checkpointer

__all__ = ["Checkpointer"]
