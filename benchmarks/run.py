"""Benchmark harness: one entry per paper table + solver/kernel benches.

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` (or QUICK=1) trims
sweeps for CI-speed runs; the full run reproduces every table.
"""
import argparse
import os
import sys
import traceback


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    default=os.environ.get("QUICK") == "1")
    ap.add_argument("--only", default=None,
                    help="comma list: table2,table3,table5,kernels,knapsack,"
                         "serving")
    args, _ = ap.parse_known_args()

    from . import (
        bench_kernels,
        bench_knapsack,
        bench_serving,
        table2_jets,
        table3_svhn,
        table5_lenet,
    )

    benches = {
        "knapsack": bench_knapsack.main,
        "kernels": bench_kernels.main,
        "serving": bench_serving.main,
        "table2": table2_jets.main,
        "table3": table3_svhn.main,
        "table5": table5_lenet.main,
    }
    selected = args.only.split(",") if args.only else list(benches)

    print("name,us_per_call,derived")
    failures = 0
    for name in selected:
        try:
            for line in benches[name](quick=args.quick):
                print(line, flush=True)
        except Exception:
            failures += 1
            print(f"{name},0,FAILED: {traceback.format_exc().splitlines()[-1]}",
                  flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
