"""Assemble EXPERIMENTS.md: inject the generated dry-run/roofline tables
at the <!-- DRYRUN_TABLE --> / <!-- ROOFLINE_TABLE --> markers.

PYTHONPATH=src python -m benchmarks.assemble_experiments
"""
import glob
import io
import json
import os
import subprocess
import sys


def render(dir_: str) -> dict:
    rows = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    ok = [r for r in rows if r.get("status") == "ok"]
    skipped = [r for r in rows if r.get("status") == "skipped"]
    errors = [r for r in rows if r.get("status") == "error"]

    dry = io.StringIO()
    n_pod2 = sum(1 for r in ok if r["mesh"] == "2x16x16")
    n_pod2_skip = sum(1 for r in skipped if r.get("multi_pod"))
    print(f"Compiled cells: **{len(ok)}** ok "
          f"({len(ok) - n_pod2} single-pod, {n_pod2} multi-pod), "
          f"{len(skipped)} skipped per assignment rules "
          f"({len(skipped) - n_pod2_skip} single-pod, {n_pod2_skip} multi-pod), "
          f"{len(errors)} errors.\n", file=dry)
    print("| arch | cell | mesh | peak GB/dev | compile s | collective schedule |", file=dry)
    print("|---|---|---|---|---|---|", file=dry)
    for r in sorted(ok, key=lambda r: (r["arch"], r["cell"], r["mesh"])):
        cols = ", ".join(f"{k}×{v}" for k, v in sorted(r.get("collectives", {}).items()))
        mem = r.get("memory_stats", {})
        print(f"| {r['arch']} | {r['cell']} | {r['mesh']} "
              f"| {mem.get('peak_gb', 0):.1f} | {r.get('compile_s', '')} | {cols} |",
              file=dry)

    roof = io.StringIO()
    print("| arch | cell | compute s | memory s | collective s | dominant | "
          "useful ratio | what would move the dominant term |", file=roof)
    print("|---|---|---|---|---|---|---|---|", file=roof)
    hints = {
        ("memory", "train"): "remat policy + SP residual (see P4: −62% on qwen)",
        ("memory", "prefill"): "bf16 intermediate chains; flash-attn kernel on TPU",
        ("collective", "train"): "seq-sharded activations / k-local MoE combine",
        ("collective", "decode"): "batch the decode步 across requests; kv_seq sharding already flash-decode",
        ("collective", "prefill"): "overlap TP collectives with compute (latency-hiding scheduler)",
        ("compute", "train"): "block-sparse kernels after pruning (paper technique)",
    }
    from repro.configs import SHAPES

    for r in sorted(ok, key=lambda r: (r["arch"], r["cell"])):
        if r["mesh"] != "16x16":
            continue
        kind = SHAPES[r["cell"]].kind
        hint = hints.get((r["dominant"], kind), "—")
        print(f"| {r['arch']} | {r['cell']} | {r['compute_s']:.2e} "
              f"| {r['memory_s']:.2e} | {r['collective_s']:.2e} "
              f"| **{r['dominant']}** | {r['useful_ratio']:.2f} | {hint} |",
              file=roof)

    skip = io.StringIO()
    seen = set()
    for r in skipped:
        key = (r["arch"], r["cell"])
        if key in seen:
            continue
        seen.add(key)
        print(f"- {r['arch']} × {r['cell']}: {r['reason']}", file=skip)
    return {"dry": dry.getvalue(), "roof": roof.getvalue() + "\nSkipped:\n" + skip.getvalue()}


def main():
    parts = render("results/dryrun")
    with open("EXPERIMENTS.md") as f:
        text = f.read()
    text = text.replace("<!-- DRYRUN_TABLE -->", parts["dry"], 1)
    text = text.replace("<!-- ROOFLINE_TABLE -->", parts["roof"], 1)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("EXPERIMENTS.md assembled")


if __name__ == "__main__":
    main()
