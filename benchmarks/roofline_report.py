"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from results/dryrun/*.json.

Usage: PYTHONPATH=src python -m benchmarks.roofline_report [--dir results/dryrun]
Writes markdown to stdout (tee into EXPERIMENTS.md sections).
"""
import argparse
import glob
import json
import os
from collections import defaultdict


def fmt_s(x):
    if x == 0:
        return "0"
    return f"{x:.2e}"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2", "both"])
    args = ap.parse_args()

    rows = []
    for path in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))

    ok = [r for r in rows if r.get("status") == "ok"]
    skipped = [r for r in rows if r.get("status") == "skipped"]
    errors = [r for r in rows if r.get("status") == "error"]

    print("## Dry-run summary\n")
    print(f"- compiled cells: {len(ok)}   skipped (per assignment): "
          f"{len(skipped)}   errors: {len(errors)}\n")
    if errors:
        for r in errors:
            print(f"- ERROR {r['arch']} {r['cell']} pod={r.get('multi_pod')}: "
                  f"{str(r.get('error'))[:160]}")
        print()

    print("| arch | cell | mesh | peak GB/dev | args GB/dev | compile s | collectives |")
    print("|---|---|---|---|---|---|---|")
    for r in sorted(ok, key=lambda r: (r["arch"], r["cell"], r["mesh"])):
        cols = ", ".join(f"{k}:{v}" for k, v in sorted(r.get("collectives", {}).items()))
        mem = r.get("memory_stats", {})
        print(f"| {r['arch']} | {r['cell']} | {r['mesh']} "
              f"| {mem.get('peak_gb', 0):.2f} | {mem.get('argument_gb', 0):.2f} "
              f"| {r.get('compile_s', '')} | {cols} |")

    print("\n## Roofline (single-pod 16x16, per-step seconds)\n")
    print("| arch | cell | compute s | memory s | collective s | dominant | "
          "useful FLOP ratio | MODEL_FLOPS |")
    print("|---|---|---|---|---|---|---|---|")
    for r in sorted(ok, key=lambda r: (r["arch"], r["cell"])):
        if r["mesh"] != "16x16":
            continue
        print(f"| {r['arch']} | {r['cell']} | {fmt_s(r['compute_s'])} "
              f"| {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} "
              f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
              f"| {fmt_s(r['model_flops_total'])} |")

    if skipped:
        print("\n### Skipped cells (assignment rules)\n")
        for r in skipped:
            print(f"- {r['arch']} x {r['cell']}: {r['reason']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
