"""Shared machinery for reproducing the paper's FPGA tables.

The paper's DSP group = RF consecutive weights of the transposed-flattened
matrix = a (bk=RF, bn=1) block of our (in, out) kernels.  BRAM-aware
(multi-dimensional) structures = C consecutive DSP groups = (bk=RF*C, bn=1).
Resource vectors use the paper's own units via
``TPUResourceModel.fpga_dsp_bram`` (DSP blocks, BRAM36 blocks), so the
reported reductions are directly comparable with Tables II/III/V.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BlockingSpec,
    IterativePruner,
    PruneConfig,
    TPUResourceModel,
    apply_masks,
    build_structures,
    constant_step,
    init_masks,
)
from repro.core.resource_model import HardwareSpec
from repro.optim import AdamWConfig, adamw_update, init_opt_state


@dataclasses.dataclass(frozen=True)
class FpgaResourceModel(TPUResourceModel):
    """Resource vectors in the paper's FPGA units for one layer."""

    rf: int = 1
    precision_bits: int = 16
    fpga_strategy: str = "resource"
    multi_dim: bool = False

    def structure_cost(self, blocking) -> np.ndarray:
        dsp, bram = TPUResourceModel.fpga_dsp_bram(
            self.precision_bits, self.rf, self.fpga_strategy
        )
        if self.multi_dim:
            # one structure = C consecutive DSP groups = C DSPs, 1 BRAM
            c = max(blocking.bk // self.rf, 1)
            return np.array([dsp * c, 1.0 if self.fpga_strategy == "resource" else 0.0])
        return np.array([dsp, bram])


def bram_c(precision_bits: int) -> int:
    """Paper Eq. 1 with the 36-bit BRAM word."""
    if 36 % precision_bits == 0:
        return 36 // precision_bits
    return int(np.ceil(2 * 36 / precision_bits))


def train_classifier(params, masks, forward, batch_fn, steps, lr=5e-3,
                     reg=None, seed0=0):
    opt_cfg = AdamWConfig(use_master=False, weight_decay=0.0)
    opt = init_opt_state(params, opt_cfg)

    @jax.jit
    def step(params, opt, x, y):
        def loss_fn(p):
            logits = forward(apply_masks(p, masks), x)
            onehot = jax.nn.one_hot(y, logits.shape[-1])
            loss = -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))
            if reg is not None:
                loss = loss + reg(p)
            return loss

        grads = jax.grad(loss_fn)(params)
        return adamw_update(params, grads, opt, opt_cfg, jnp.asarray(lr), masks=masks)

    for s in range(steps):
        x, y = batch_fn(seed0 + s)
        params, opt = step(params, opt, x, y)
    return params


def accuracy(params, masks, forward, batch) -> float:
    x, y = batch
    logits = forward(apply_masks(params, masks), x)
    return float(jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32)))


def run_prune_experiment(
    *,
    init_fn,
    forward,
    batch_fn,
    val_batch,
    blocking_per_layer: Dict[str, BlockingSpec],
    models_per_layer,
    target=(0.75, 0.75),
    step_size=0.25,
    pretrain_steps=150,
    finetune_steps=40,
    tolerance=0.04,
    min_size=64,
    seed=0,
) -> Dict:
    """Full Algorithm-2 run; returns paper-style reductions + accuracies."""
    params = init_fn(jax.random.PRNGKey(seed))
    structures = build_structures(params, blocking_per_layer, min_size=min_size)
    masks0 = init_masks(params, structures)
    params = train_classifier(params, masks0, forward, batch_fn, pretrain_steps)
    base_acc = accuracy(params, masks0, forward, val_batch)

    pruner = IterativePruner(
        structures, models_per_layer,
        PruneConfig(schedule=constant_step(list(target), step_size),
                    tolerance=tolerance),
    )
    t0 = time.time()
    params, masks, logs = pruner.run(
        params,
        lambda p, m: train_classifier(p, m, forward, batch_fn, finetune_steps,
                                      lr=2e-3, seed0=10_000),
        lambda p, m: accuracy(p, m, forward, val_batch),
    )
    dt = time.time() - t0
    final = logs[-1] if logs else None
    red = final.reduction() if final else np.array([1.0, 1.0])
    return {
        "baseline_acc": base_acc,
        "pruned_acc": accuracy(params, masks, forward, val_batch),
        "dsp_reduction": float(red[0]),
        "bram_reduction": float(red[1]) if np.isfinite(red[1]) else float("inf"),
        "structure_sparsity": final.structure_sparsity if final else 0.0,
        "iterations": len(logs),
        "seconds": dt,
        "baseline_resources": (pruner.baseline_resources.tolist()),
    }
