"""Paper Table III: SVHN CNN, DSP-aware pruning at RF in {3, 9, 27}.

Paper: DSP reductions 3.9x / 3.6x / 2.2x with accuracy *maintained* (the
pruned models even improve slightly).  We reproduce on the synthetic
32x32x3 digit-stand-in task with the same architecture.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core import BlockingSpec
from repro.data import ImageTask
from repro.models.cnn import init_svhn_cnn, svhn_cnn_forward

from .fpga_repro import FpgaResourceModel, run_prune_experiment

RFS = [3, 9, 27]


def run(quick: bool = False) -> List[Dict]:
    task = ImageTask(height=32, width=32, channels=3, classes=10, seed=5)
    val = task.batch(99_999, 1024)
    rows = []
    for rf in (RFS if not quick else [3]):
        res = run_prune_experiment(
            init_fn=init_svhn_cnn,
            forward=svhn_cnn_forward,
            batch_fn=lambda s: task.batch(s, 128),
            val_batch=val,
            blocking_per_layer={"default": BlockingSpec(bk=rf, bn=1)},
            models_per_layer=FpgaResourceModel(rf=rf, precision_bits=16),
            target=(0.8, 0.8),
            step_size=0.2,
            pretrain_steps=80 if quick else 150,
            finetune_steps=20 if quick else 40,
            min_size=128,
        )
        res.update({"rf": rf})
        rows.append(res)
    return rows


def main(quick: bool = False) -> List[str]:
    rows = run(quick)
    return [
        f"table3_svhn_rf{r['rf']},"
        f"{r['seconds']*1e6/max(r['iterations'],1):.0f},"
        f"dsp_red={r['dsp_reduction']:.2f}x "
        f"acc={r['baseline_acc']:.3f}->{r['pruned_acc']:.3f} "
        f"sparsity={r['structure_sparsity']:.2f}"
        for r in rows
    ]


if __name__ == "__main__":
    for line in main():
        print(line)
