"""Knapsack solver benchmark: quality (vs exact) and scaling to the
structure counts of the assigned LMs (1e5-1e6 items)."""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import solve_brute, solve_mdkp


def main(quick: bool = False) -> List[str]:
    rng = np.random.default_rng(0)
    out = []

    # quality vs brute force on adversarial small instances
    worst = 1.0
    trials = 100 if quick else 400
    for t in range(trials):
        n = rng.integers(2, 13)
        m = rng.integers(1, 4)
        v = rng.uniform(0, 1, n)
        w = rng.uniform(0.01, 1, (m, n))
        c = w.sum(axis=1) * rng.uniform(0.1, 0.9)
        b = solve_brute(v, w, c)
        a = solve_mdkp(v, w, c)
        if b.value > 1e-12:
            worst = min(worst, a.value / b.value)
    out.append(f"knapsack_quality_small,{trials},worst_ratio_vs_exact={worst:.4f}")

    # scaling
    for n in ([50_000] if quick else [50_000, 200_000]):
        v = rng.uniform(0, 1, n)
        w = rng.uniform(0.5, 2.0, (2, n))
        c = w.sum(axis=1) * 0.5
        t0 = time.time()
        r = solve_mdkp(v, w, c)
        dt = time.time() - t0
        assert np.all(r.used <= c + 1e-6)
        out.append(f"knapsack_scale_n{n},{dt*1e6:.0f},value={r.value:.0f} "
                   f"feasible={r.feasible} method={r.method}")

    # homogeneous fast path (the common per-layer case)
    n = 500_000
    v = rng.uniform(0, 1, n)
    w = np.ones((2, n))
    t0 = time.time()
    r = solve_mdkp(v, w, np.array([n * 0.3, n * 0.3]))
    dt = time.time() - t0
    out.append(f"knapsack_topk_n{n},{dt*1e6:.0f},method={r.method}")
    return out


if __name__ == "__main__":
    for line in main():
        print(line)
