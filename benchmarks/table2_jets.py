"""Paper Table II: jet classification, RF sweep, DSP- and BRAM-aware pruning.

Paper numbers (16-bit, Resource strategy): DSP reductions 12.2x / 11.9x /
7.9x / 5.8x for RF = 2/4/8/16 (BP-DSP), BRAM 3.9x/3.5x/2.7x/2.3x; BP-MD
trades DSP for BRAM.  We reproduce the *trend and magnitude* on the
synthetic jets task: reductions must exceed 2x at <= RF 4 and decrease
with RF (larger structures = coarser pruning = earlier accuracy cliff).
"""
from __future__ import annotations

import json
from typing import Dict, List

from repro.core import BlockingSpec
from repro.data import JetsTask
from repro.models.cnn import init_jets_mlp, jets_mlp_forward

from .fpga_repro import FpgaResourceModel, bram_c, run_prune_experiment

RFS = [2, 4, 8, 16]


def run(quick: bool = False) -> List[Dict]:
    task = JetsTask()
    val = task.batch(99_999, 2048)
    rows = []
    rfs = RFS if not quick else [2, 8]
    for rf in rfs:
        # md (BRAM-aware) mode at RF=2/8 keeps the paper's BP-MD comparison
        # without doubling every row (wall-clock budget on 1 CPU core)
        for mode in ((["dsp", "md"] if rf in (2, 8) else ["dsp"])
                     if not quick else ["dsp"]):
            if mode == "dsp":
                bits = 16
                blocking = BlockingSpec(bk=rf, bn=1)
                rm = FpgaResourceModel(rf=rf, precision_bits=bits)
            else:
                bits = 18  # paper: BP-MD synthesized at 18-bit
                c = bram_c(bits)
                blocking = BlockingSpec(bk=rf * c, bn=1, consecutive=c)
                rm = FpgaResourceModel(rf=rf, precision_bits=bits, multi_dim=True)
            res = run_prune_experiment(
                init_fn=init_jets_mlp,
                forward=jets_mlp_forward,
                batch_fn=lambda s: task.batch(s, 256),
                val_batch=val,
                blocking_per_layer={"default": blocking},
                models_per_layer=rm,
                target=(0.9, 0.9),
                step_size=0.15,
                pretrain_steps=120 if quick else 180,
                finetune_steps=30 if quick else 50,
                min_size=256,
            )
            res.update({"rf": rf, "mode": mode, "bits": bits})
            rows.append(res)
    return rows


def main(quick: bool = False) -> List[str]:
    rows = run(quick)
    out = []
    for r in rows:
        out.append(
            f"table2_jets_rf{r['rf']}_{r['mode']},"
            f"{r['seconds']*1e6/max(r['iterations'],1):.0f},"
            f"dsp_red={r['dsp_reduction']:.2f}x bram_red={r['bram_reduction']:.2f}x "
            f"acc={r['baseline_acc']:.3f}->{r['pruned_acc']:.3f} "
            f"sparsity={r['structure_sparsity']:.2f}"
        )
    return out


if __name__ == "__main__":
    for line in main():
        print(line)
