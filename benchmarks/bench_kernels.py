"""BSR kernel benchmark: wall-time vs density (interpret mode on CPU is a
correctness proxy; the structural claim — compute and DMA bytes scale with
density — is derived from the kernel's grid/BlockSpec and reported as the
modeled roofline deltas)."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BlockingSpec, pack_bsr
from repro.core.resource_model import TPU_V5E
from repro.kernels import ref
from repro.kernels.block_sparse_matmul import bsr_matmul_pallas


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.time() - t0) / reps


def main(quick: bool = False) -> List[str]:
    rng = np.random.default_rng(0)
    m, k, n, bk, bn = (256, 1024, 1024, 128, 128)
    out = []
    for density in ([1.0, 0.5, 0.25] if not quick else [0.5]):
        w = rng.normal(size=(k, n)).astype(np.float32)
        gk, gn = k // bk, n // bn
        alive = rng.uniform(size=(gk, gn)) < density
        mask = np.repeat(np.repeat(alive, bk, 0), bn, 1).astype(np.float32)
        bsr = pack_bsr(w, BlockingSpec(bk=bk, bn=bn), mask=mask)
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))

        pl_fn = jax.jit(lambda xx: bsr_matmul_pallas(
            xx, bsr.indices, bsr.blocks, n=n, bm=128, interpret=True))
        ref_fn = jax.jit(lambda xx: ref.bsr_matmul_ref(xx, bsr))
        t_pl = _time(pl_fn, x)
        t_ref = _time(ref_fn, x)

        # modeled TPU roofline for the kernel at this density
        flops = 2 * m * k * n * bsr.density()
        bytes_w = bsr.nnz_blocks * bk * bn * 4
        compute_us = flops / TPU_V5E.peak_flops_bf16 * 1e6
        hbm_us = bytes_w / TPU_V5E.hbm_bw * 1e6
        out.append(
            f"bsr_matmul_d{density:.2f},{t_pl*1e6:.0f},"
            f"interp_vs_ref={t_pl/t_ref:.1f}x modeled_tpu_us="
            f"{max(compute_us, hbm_us):.2f} (compute {compute_us:.2f} / "
            f"hbm {hbm_us:.2f}) density={bsr.density():.2f}"
        )
    return out


if __name__ == "__main__":
    for line in main():
        print(line)
