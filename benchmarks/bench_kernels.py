"""BSR kernel benchmark: wall-time vs density (interpret mode on CPU is a
correctness proxy; the structural claim — compute and DMA bytes scale with
density — is derived from the kernel's grid/BlockSpec and reported as the
modeled roofline deltas).

``bench_decode`` is the end-to-end counterpart: a smoke LM decodes through
the dense path and through the BSR dispatch on knapsack-pruned packed
params (repro.sparse), reporting per-token wall time plus the modeled TPU
matmul time at the packed density — the serving-speed claim the sparse
execution layer exists for (DESIGN.md §6)."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BlockingSpec, pack_bsr
from repro.core.resource_model import TPU_V5E
from repro.kernels import ref
from repro.kernels.block_sparse_matmul import bsr_matmul_pallas


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.time() - t0) / reps


def main(quick: bool = False) -> List[str]:
    rng = np.random.default_rng(0)
    m, k, n, bk, bn = (256, 1024, 1024, 128, 128)
    out = []
    for density in ([1.0, 0.5, 0.25] if not quick else [0.5]):
        w = rng.normal(size=(k, n)).astype(np.float32)
        gk, gn = k // bk, n // bn
        alive = rng.uniform(size=(gk, gn)) < density
        mask = np.repeat(np.repeat(alive, bk, 0), bn, 1).astype(np.float32)
        bsr = pack_bsr(w, BlockingSpec(bk=bk, bn=bn), mask=mask)
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))

        pl_fn = jax.jit(lambda xx: bsr_matmul_pallas(
            xx, bsr, bm=128, interpret=True))
        ref_fn = jax.jit(lambda xx: ref.bsr_matmul_ref(xx, bsr))
        t_pl = _time(pl_fn, x)
        t_ref = _time(ref_fn, x)

        # modeled TPU roofline for the kernel at this density
        flops = 2 * m * k * n * bsr.density()
        bytes_w = bsr.nnz_blocks * bk * bn * 4
        compute_us = flops / TPU_V5E.peak_flops_bf16 * 1e6
        hbm_us = bytes_w / TPU_V5E.hbm_bw * 1e6
        out.append(
            f"bsr_matmul_d{density:.2f},{t_pl*1e6:.0f},"
            f"interp_vs_ref={t_pl/t_ref:.1f}x modeled_tpu_us="
            f"{max(compute_us, hbm_us):.2f} (compute {compute_us:.2f} / "
            f"hbm {hbm_us:.2f}) density={bsr.density():.2f}"
        )
    out.extend(bench_decode(quick=quick))
    return out


def bench_decode(quick: bool = False, sparsity: float = 0.5) -> List[str]:
    """Dense vs BSR-packed end-to-end greedy decode on a smoke LM."""
    from repro.configs import get_config, make_smoke
    from repro.core.masks import _get_path
    from repro.models import init_caches, init_params, lm_decode
    from repro.sparse import knapsack_prune, pack_params, sparsity_summary

    cfg = make_smoke(get_config("qwen1.5-0.5b")).replace(
        vocab=128, n_layers=2, name="bench-decode")
    params = init_params(jax.random.PRNGKey(0), cfg)
    sel = knapsack_prune(params, sparsity=sparsity,
                         blocking=BlockingSpec(bk=32, bn=32), min_size=1024)
    packed = pack_params(params, sel.masks, sel.structures)
    density = sparsity_summary(packed)["density"]

    b, steps = 2, (4 if quick else 8)
    decode = jax.jit(lambda p, c, t, l: lm_decode(p, c, {"tokens": t}, l, cfg))

    def run(p):
        caches = init_caches(cfg, b, steps + 1, jnp.float32)
        tok = jnp.zeros((b, 1), jnp.int32)
        # one full warm iteration — decode AND the eager argmax token
        # update — so the timed loop measures steady state, not compiles
        logits, caches = decode(p, caches, tok, jnp.asarray(0, jnp.int32))
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        tok.block_until_ready()
        t0 = time.time()
        for i in range(steps):
            logits, caches = decode(p, caches, tok, jnp.asarray(i + 1, jnp.int32))
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        tok.block_until_ready()
        return (time.time() - t0) / steps

    t_dense = run(params)
    t_packed = run(packed)

    # modeled TPU time for the prunable matmuls at this density: both the
    # MXU term and the weight-streaming HBM term scale linearly with the
    # surviving-block fraction (grid iterates live tiles only)
    w_elems = sum(int(np.prod(_get_path(params, i.path).shape))
                  for i in sel.structures.infos)
    flops_dense = 2 * b * w_elems
    bytes_dense = 2 * w_elems                        # bf16 weight bytes
    compute_us = flops_dense / TPU_V5E.peak_flops_bf16 * 1e6
    hbm_us = bytes_dense / TPU_V5E.hbm_bw * 1e6
    modeled_dense = max(compute_us, hbm_us)
    modeled_packed = modeled_dense * density
    return [
        f"decode_dense,{t_dense*1e6:.0f},per_tok_us batch={b}",
        f"decode_packed_d{density:.2f},{t_packed*1e6:.0f},per_tok_us "
        f"batch={b} modeled_tpu_matmul_us {modeled_dense:.3f}->"
        f"{modeled_packed:.3f} ({1/max(density, 1e-9):.1f}x fewer "
        f"MXU passes + HBM pages)",
    ]


if __name__ == "__main__":
    for line in main():
        print(line)
