"""Dense vs BSR-packed serving benchmark through the compiled hot path.

Times the two jitted serving calls (DESIGN.md §7/§8) — batched
``lm_prefill`` and the single-scan ``lm_generate`` greedy loop —
*separately* for dense and knapsack-pruned+packed params, and writes
``BENCH_serving.json``::

    {"config": {...}, "dense_tok_s": ..., "packed_tok_s": ...,
     "dense_prefill_ms": ..., "packed_prefill_ms": ...,
     "prefill_speedup": ..., "decode_speedup": ...,
     "continuous_batching": {...}, "prefix_caching": {...},
     "fault_tolerance": {...}, "slo_scheduling": {...},
     "paged_attention": {...}}

The ``continuous_batching`` section streams ragged requests through the
paged-KV ``ServingEngine`` (DESIGN.md §9) — staggered arrivals,
prefill-on-join, EOS-freed slots re-admitting from the queue — and
records aggregate throughput + slot utilization for dense and packed
params.

so the serving-perf trajectory is tracked from PR 2 on.  The packed
numbers exercise the zero-skipping kernels end-to-end (flat-store ref
path on CPU, compiled Pallas on TPU); at the default 75% structure
sparsity packed must beat dense on BOTH halves — prefill (bm-tiled
GEMMs) and decode (single-row GEMMs) — work scales with density.
``scripts/check.sh`` gates on both speedups.

``python benchmarks/bench_serving.py [--quick] [--out BENCH_serving.json]``
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Any, Dict


def _bench_paged_attention(
    *,
    contexts=(128, 512, 2048),
    page_size: int = 8,
    batch: int = 4,
    num_heads: int = 8,
    kv_heads: int = 4,
    head_dim: int = 64,
    d_model: int = 512,
    reps: int = 20,
) -> Dict[str, Any]:
    """Gather vs fused paged decode attention over a context-length sweep
    (DESIGN.md §11).  One fixed-width page table sized for the longest
    context; ``cache_len`` sweeps below it — so the legacy gather pays
    its O(max_pages · page_size) view at every point while the fused
    page walk pays O(cache_len).  Times the full ``attention_decode``
    call (projections included) through one jit per impl."""
    import time as _time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models.attention import attention_decode, attention_init

    max_len = max(contexts)
    max_pages = -(-max_len // page_size)
    n_pages = batch * max_pages + 1
    key = jax.random.PRNGKey(0)
    # key is only ever a fold_in parent — each consumer gets its own child
    p = attention_init(jax.random.fold_in(key, 0), d_model, num_heads,
                       kv_heads, head_dim)
    x = jax.random.normal(jax.random.fold_in(key, 1), (batch, 1, d_model))
    pool_k = jax.random.normal(
        jax.random.fold_in(key, 2), (n_pages, page_size, kv_heads, head_dim))
    pool_v = jax.random.normal(
        jax.random.fold_in(key, 3), (n_pages, page_size, kv_heads, head_dim))
    tables = jnp.asarray(
        np.random.default_rng(0).permutation(
            np.arange(1, n_pages))[: batch * max_pages].reshape(
                batch, max_pages), jnp.int32)

    def make(impl):
        def f(x, ck, cv, clen):
            return attention_decode(
                p, x, {"k": ck, "v": cv}, clen, num_heads=num_heads,
                kv_heads=kv_heads, head_dim=head_dim, page_table=tables,
                paged_impl=impl)
        return jax.jit(f)

    fns = {impl: make(impl) for impl in ("gather", "fused")}
    by_ctx: Dict[str, Any] = {}
    for ctx in contexts:
        clen = jnp.full((batch,), ctx - 1, jnp.int32)  # +1 in-register token
        row: Dict[str, Any] = {"context": ctx}
        for impl, f in fns.items():
            o, _ = f(x, pool_k, pool_v, clen)
            jax.block_until_ready(o)                   # warm (compile once)
            t0 = _time.perf_counter()
            for _ in range(reps):
                o, _ = f(x, pool_k, pool_v, clen)
            jax.block_until_ready(o)
            dt = max((_time.perf_counter() - t0) / reps, 1e-9)
            row[f"{impl}_ms"] = dt * 1e3
            row[f"{impl}_tok_s"] = batch / dt
        row["speedup"] = row["gather_ms"] / max(row["fused_ms"], 1e-9)
        by_ctx[str(ctx)] = row
    longest = str(max(contexts))
    return {
        "page_size": page_size, "max_len": max_len, "batch": batch,
        "num_heads": num_heads, "kv_heads": kv_heads, "head_dim": head_dim,
        "by_context": by_ctx,
        "speedup_at_longest": by_ctx[longest]["speedup"],
    }


def _gen_arrivals(rng, n: int, kind: str, mean_gap: float = 2.0):
    """Arrival ticks for ``n`` requests: ``burst`` lands everything at
    tick 0; ``poisson`` draws exponential inter-arrival gaps (mean
    ``mean_gap`` ticks) and floors the cumulative sum to integer ticks."""
    if kind == "burst":
        return [0] * n
    import numpy as np

    gaps = rng.exponential(mean_gap, size=n)
    gaps[0] = 0.0
    return [int(t) for t in np.floor(np.cumsum(gaps))]


def _bench_prefix_caching(
    params, cfg, *, requests: int = 8, prompt_len: int = 256, tail: int = 8,
    page_size: int = 8, gen: int = 8, ticks_per_sync: int = 4,
) -> Dict[str, Any]:
    """Shared-prefix TTFT: ``requests`` prompts sharing the first
    ``prompt_len - tail`` tokens stream through the engine with prefix
    caching on vs off (DESIGN.md §12).  All admissions happen in arrival
    order inside one scheduler pass, so request *i*'s time-to-first-token
    includes prefills 0..i — with caching, hit requests prefill only
    their ``tail`` tokens, so late burst positions improve the most.
    ``check.sh`` gates hit-request p50 TTFT at >= 2x vs uncached."""
    import numpy as np

    from repro.serving import ServingEngine

    rng = np.random.default_rng(7)
    prefix = rng.integers(0, cfg.vocab, size=prompt_len - tail)
    prompts = [
        np.concatenate([prefix, rng.integers(0, cfg.vocab, size=tail)])
        .astype(np.int32) for _ in range(requests)]

    def run_once(caching: bool, arrivals):
        eng = ServingEngine(params, cfg, num_slots=requests,
                            page_size=page_size,
                            max_seq_len=prompt_len + gen,
                            ticks_per_sync=ticks_per_sync,
                            prefix_caching=caching)
        for pr, at in zip(prompts, arrivals):
            eng.submit(pr, gen, arrival=at)
        t0 = time.perf_counter()
        done = eng.run()
        reqs = [done[rid] for rid in sorted(done)]
        ttft = [r.first_token_time - t0 for r in reqs]
        hits = [i for i, r in enumerate(reqs) if r.prefix_hit_pages > 0]
        return ttft, hits, eng

    def pct(xs, q) -> float:
        return float(np.percentile(np.asarray(xs), q)) if xs else 0.0

    def section(kind: str, arr_rng) -> Dict[str, Any]:
        arrivals = _gen_arrivals(arr_rng, requests, kind)
        run_once(True, arrivals)       # warm every jit shape once
        ttft_s, hits, eng = run_once(True, arrivals)
        ttft_u, _, _ = run_once(False, arrivals)
        hit_s = [ttft_s[i] for i in hits]     # same burst positions in
        hit_u = [ttft_u[i] for i in hits]     # both runs -> fair ratio
        return {
            "arrival": kind, "arrivals": arrivals,
            "hit_requests": len(hits),
            "shared": {"ttft_p50_ms": pct(ttft_s, 50) * 1e3,
                       "ttft_p99_ms": pct(ttft_s, 99) * 1e3},
            "unshared": {"ttft_p50_ms": pct(ttft_u, 50) * 1e3,
                         "ttft_p99_ms": pct(ttft_u, 99) * 1e3},
            "hit_ttft_p50_ms": pct(hit_s, 50) * 1e3,
            "unshared_hit_ttft_p50_ms": pct(hit_u, 50) * 1e3,
            "ttft_speedup_hit_p50":
                pct(hit_u, 50) / max(pct(hit_s, 50), 1e-9),
            "prefix_stats": eng.prefix_stats,
        }

    return {
        "requests": requests, "prompt_len": prompt_len, "tail": tail,
        "page_size": page_size, "gen": gen,
        "burst": section("burst", np.random.default_rng(11)),
        "poisson": section("poisson", np.random.default_rng(13)),
    }


def _bench_slo_scheduling(
    params, cfg, *, requests: int = 12, slots: int = 4, prompt_len: int = 12,
    gen: int = 32, page_size: int = 8, fixed_tps: int = 16,
    levels=(1, 2, 4, 8, 16), reps: int = 3,
) -> Dict[str, Any]:
    """Adaptive chunking vs fixed ``ticks_per_sync`` on the SAME
    prioritized workload (DESIGN.md §15): ragged generation lengths over
    burst and poisson arrivals, alternating priority classes (0 =
    interactive with a soft TTFT target, 1 = batch).  Both engines run
    the identical submit sequence — priorities, targets, arrivals — so
    the only variable is the chunk-length policy: fixed boundaries land
    on the ``fixed_tps`` grid (a freed slot idles until the next
    multiple), adaptive ones descend the level ladder to land exactly
    on slot-free events and SLO edges, then grow back.

    Reports TTFT p50/p99 both in *ticks* (deterministic — the gate
    check.sh uses) and wall-clock ms, by priority class, plus streamed
    throughput (median of ``reps``).  check.sh gates: adaptive p99 TTFT
    beats fixed on the burst workload AND throughput stays within 10%."""
    import numpy as np

    from repro.serving import AdaptiveChunkPolicy, ServingEngine
    from repro.serving.slo import percentiles

    rng = np.random.default_rng(17)
    lens = rng.integers(max(1, prompt_len // 2), prompt_len + 1,
                        size=requests)
    gens = rng.integers(max(2, gen // 2), gen + 1, size=requests)
    prompts = [rng.integers(0, cfg.vocab, size=int(l)).astype(np.int32)
               for l in lens]
    prios = [i % 2 for i in range(requests)]

    def run_once(arrivals, adaptive: bool):
        eng = ServingEngine(
            params, cfg, num_slots=slots, page_size=page_size,
            max_seq_len=prompt_len + gen, ticks_per_sync=fixed_tps,
            chunk_policy=(AdaptiveChunkPolicy(levels=tuple(levels))
                          if adaptive else None))
        for i, pr in enumerate(prompts):
            eng.submit(pr, int(gens[i]), arrival=arrivals[i],
                       priority=prios[i],
                       ttft_target_ticks=(2 * fixed_tps if prios[i] == 0
                                          else None))
        t0 = time.perf_counter()
        done = eng.run()
        dt = max(time.perf_counter() - t0, 1e-9)
        reqs = [done[rid] for rid in sorted(done)]
        return {
            "tok_s": sum(len(r.tokens) for r in reqs) / dt,
            "ttft_ms": [(r.first_token_time - t0) * 1e3 for r in reqs],
            "ttft_ticks": [float(r.ttft_ticks) for r in reqs],
            "slo": eng.slo_stats(),
        }

    def side(arrivals, adaptive: bool) -> Dict[str, Any]:
        runs = [run_once(arrivals, adaptive) for _ in range(reps)]
        tick_pct = percentiles(runs[0]["ttft_ticks"])   # deterministic
        ms_p99 = float(np.median(
            [percentiles(r["ttft_ms"])["p99"] for r in runs]))
        ms_p50 = float(np.median(
            [percentiles(r["ttft_ms"])["p50"] for r in runs]))
        slo = runs[0]["slo"]
        return {
            "tok_s": float(np.median([r["tok_s"] for r in runs])),
            "ttft_ticks_p50": tick_pct["p50"],
            "ttft_ticks_p99": tick_pct["p99"],
            "ttft_ms_p50": ms_p50,
            "ttft_ms_p99": ms_p99,
            "by_priority": slo["by_priority"],
            "ttft_target_misses": slo["ttft_target_misses"],
            "chunks_by_ticks": slo["chunks_by_ticks"],
            "chunk_shrinks": slo["chunk_shrinks"],
            "chunk_grows": slo["chunk_grows"],
        }

    def section(kind: str, seed: int) -> Dict[str, Any]:
        arrivals = _gen_arrivals(np.random.default_rng(seed), requests, kind)
        run_once(arrivals, False)       # warm every chunk-level jit shape
        run_once(arrivals, True)
        fixed = side(arrivals, False)
        adapt = side(arrivals, True)
        return {
            "arrival": kind, "arrivals": arrivals,
            "fixed": fixed, "adaptive": adapt,
            "ttft_ticks_p99_improvement":
                fixed["ttft_ticks_p99"] / max(adapt["ttft_ticks_p99"], 1e-9),
            "ttft_ms_p99_improvement":
                fixed["ttft_ms_p99"] / max(adapt["ttft_ms_p99"], 1e-9),
            "throughput_ratio": adapt["tok_s"] / max(fixed["tok_s"], 1e-9),
        }

    return {
        "requests": requests, "slots": slots, "prompt_len": prompt_len,
        "gen": gen, "fixed_ticks_per_sync": fixed_tps,
        "levels": list(levels), "reps": reps,
        "priorities": prios,
        "burst": section("burst", 19),
        "poisson": section("poisson", 23),
    }


def _bench_fault_tolerance(
    params, cfg, *, requests: int = 8, prompt_len: int = 16, gen: int = 32,
    batch: int = 4, arrive_every: int = 2, page_size: int = 8,
    ticks_per_sync: int = 4, reps: int = 3,
) -> Dict[str, Any]:
    """Cost of the fault-tolerance layer on CLEAN traffic (DESIGN.md
    §13): the same streamed workload with the non-finite guard compiled
    into prefill + decode chunk (``nan_guard=True``, the default) vs the
    unguarded chunk (``nan_guard=False`` — the PR-7 hot path).  The
    guard is one ``isfinite`` all-reduce over the logits per row per
    tick, so it must be noise-level next to the matmuls; best-of-reps on
    both sides suppresses scheduler jitter and ``check.sh`` gates the
    regression under 5%."""
    import numpy as np

    from repro.serving import ServingEngine

    rng = np.random.default_rng(5)
    lens = rng.integers(max(1, prompt_len // 2), prompt_len + 1,
                        size=requests)
    prompts = [rng.integers(0, cfg.vocab, size=int(l)).astype(np.int32)
               for l in lens]

    def go(guard: bool) -> float:
        eng = ServingEngine(params, cfg, num_slots=batch,
                            page_size=page_size,
                            max_seq_len=prompt_len + gen,
                            ticks_per_sync=ticks_per_sync,
                            nan_guard=guard)
        for i, pr in enumerate(prompts):
            eng.submit(pr, gen, arrival=i * arrive_every)
        t0 = time.perf_counter()
        done = eng.run()
        dt = max(time.perf_counter() - t0, 1e-9)
        assert eng.fault_stats["guard_trips"] == 0   # clean traffic
        return sum(len(r.tokens) for r in done.values()) / dt

    go(True), go(False)                   # warm both compiled variants
    on = max(go(True) for _ in range(reps))
    off = max(go(False) for _ in range(reps))
    return {
        "requests": requests, "gen": gen,
        "ticks_per_sync": ticks_per_sync, "reps": reps,
        "guard_on_tok_s": on,
        "guard_off_tok_s": off,
        "overhead_pct": (off - on) / max(off, 1e-9) * 100.0,
    }


def bench_serving(
    arch: str = "qwen1.5-0.5b",
    *,
    sparsity: float = 0.75,
    block: int = 128,
    d_model: int = 512,
    d_ff: int = 2048,
    n_layers: int = 2,
    batch: int = 4,
    prompt_len: int = 16,
    gen: int = 32,
    reps: int = 3,
) -> Dict[str, Any]:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, make_smoke
    from repro.core import BlockingSpec
    from repro.kernels.ops import on_tpu
    from repro.models import init_caches, init_params, lm_generate, lm_prefill
    from repro.sparse import knapsack_prune, pack_params, sparsity_summary

    cfg = make_smoke(get_config(arch), d_model=d_model, d_ff=d_ff,
                     n_layers=n_layers, vocab=256, name=f"{arch}-bench")
    params = init_params(jax.random.PRNGKey(0), cfg)
    sel = knapsack_prune(params, sparsity=sparsity,
                         blocking=BlockingSpec(bk=block, bn=block),
                         min_size=1024)
    packed = pack_params(params, sel.masks, sel.structures)
    density = sparsity_summary(packed)["density"]

    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab)

    prefill = jax.jit(lambda p, c, t: lm_prefill(p, c, {"tokens": t}, cfg))
    generate = jax.jit(lambda p, c, t, l: lm_generate(p, c, t, l, gen, cfg))

    def run(p) -> Dict[str, float]:
        """Times prefill and decode separately (each over ``reps`` runs)
        — the two halves of the serving hot path scale with sparsity
        differently (bm-tiled GEMMs vs single-row GEMMs), so a combined
        number would hide a regression in either."""
        caches = init_caches(cfg, batch, prompt_len + gen, jnp.float32)
        # warm both calls (compile + first-run constants)
        logits, c = prefill(p, caches, prompt)
        jax.block_until_ready(logits)
        t0 = time.time()
        for _ in range(reps):
            logits, c = prefill(p, caches, prompt)
        jax.block_until_ready(logits)
        t_prefill = max((time.time() - t0) / reps, 1e-9)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        plen = jnp.asarray(prompt_len, jnp.int32)
        toks, _ = generate(p, c, tok, plen)
        jax.block_until_ready(toks)
        t0 = time.time()
        for _ in range(reps):
            toks, _ = generate(p, c, tok, plen)
        jax.block_until_ready(toks)
        t_decode = max((time.time() - t0) / reps, 1e-9)
        return {"prefill_ms": t_prefill * 1e3,
                "tok_s": gen * batch / t_decode}

    def run_stream(p, *, requests=8, arrive_every=2, page_size=8,
                   ticks_per_sync=1):
        """Streamed-arrival serving through the continuous-batching
        engine: ragged prompts join as slots/pages free up, decode runs
        in ``ticks_per_sync`` on-device chunks between scheduler events
        (1 = the PR-4 host-sync-per-token loop)."""
        import numpy as np

        from repro.serving import ServingEngine

        rng = np.random.default_rng(0)
        lens = rng.integers(max(1, prompt_len // 2), prompt_len + 1,
                            size=requests)
        prompts = [rng.integers(0, cfg.vocab, size=int(l)).astype(np.int32)
                   for l in lens]

        def go():
            eng = ServingEngine(p, cfg, num_slots=batch,
                                page_size=page_size,
                                max_seq_len=prompt_len + gen,
                                ticks_per_sync=ticks_per_sync)
            for i, pr in enumerate(prompts):
                eng.submit(pr, gen, arrival=i * arrive_every)
            t0 = time.time()
            done = eng.run()
            dt = max(time.time() - t0, 1e-9)
            toks = sum(len(r.tokens) for r in done.values())
            return toks / dt, eng.slot_utilization
        go()                       # warm the shared jit caches
        tok_s, util = go()
        return tok_s, util, {"requests": requests,
                             "arrive_every": arrive_every,
                             "page_size": page_size, "num_slots": batch}

    dense = run(params)
    sparse = run(packed)
    # paged engine caches don't cover SWA-ring or encoder-decoder archs:
    # keep the static prefill/decode benchmark working for them and mark
    # the streamed section unsupported instead of crashing
    if cfg.window is None and not cfg.enc_layers:
        # streamed tok/s per on-device chunk size: ticks_per_sync=1 is
        # the PR-4 host-sync-per-token baseline, larger chunks amortize
        # the scheduler round-trip (DESIGN.md §10).  check.sh gates that
        # chunked packed throughput beats the single-tick baseline.
        by_tps: Dict[str, Any] = {}
        cb_cfg: Dict[str, Any] = {}
        for tps in (1, 4, 16):
            d_tok, _, _ = run_stream(params, ticks_per_sync=tps)
            p_tok, util, cb_cfg = run_stream(packed, ticks_per_sync=tps)
            by_tps[str(tps)] = {
                "ticks_per_sync": tps,
                "dense_tok_s": d_tok,
                "packed_tok_s": p_tok,
                "slot_utilization": util,
            }
        base = by_tps["1"]
        best = max(by_tps.values(), key=lambda r: r["packed_tok_s"])
        cb = {
            **cb_cfg,
            "dense_tok_s": base["dense_tok_s"],
            "packed_tok_s": base["packed_tok_s"],
            "decode_speedup":
                base["packed_tok_s"] / max(base["dense_tok_s"], 1e-9),
            "slot_utilization": base["slot_utilization"],
            "by_ticks_per_sync": by_tps,
            "chunked_packed_tok_s": best["packed_tok_s"],
            "chunked_ticks_per_sync": best["ticks_per_sync"],
            "chunked_speedup_vs_single_tick":
                best["packed_tok_s"] / max(base["packed_tok_s"], 1e-9),
        }
        # shared-prefix TTFT: prefix caching on vs off over the same
        # burst/poisson arrival trace (DESIGN.md §12).  check.sh gates
        # hit-request p50 TTFT >= 2x in the burst.
        pc = _bench_prefix_caching(packed, cfg, gen=min(gen, 8))
        # guard-on vs guard-off streamed throughput on clean traffic:
        # the price of §13 fault isolation.  check.sh gates < 5%.
        ft = _bench_fault_tolerance(packed, cfg, batch=batch,
                                    prompt_len=prompt_len, gen=gen,
                                    reps=max(reps, 3))
        # adaptive chunking vs fixed tps=16 on a prioritized burst /
        # poisson workload (DESIGN.md §15).  check.sh gates: adaptive
        # p99 TTFT beats fixed on burst, throughput within 10%.
        slo = _bench_slo_scheduling(packed, cfg, slots=batch,
                                    prompt_len=prompt_len, gen=gen,
                                    reps=max(reps, 3))
    else:
        cb = {"unsupported": "SWA window / encoder-decoder arch"}
        pc = {"unsupported": "SWA window / encoder-decoder arch"}
        ft = {"unsupported": "SWA window / encoder-decoder arch"}
        slo = {"unsupported": "SWA window / encoder-decoder arch"}
    # fused page-walk vs legacy gather decode attention over long contexts
    # (independent of the smoke model above — fixed attention shapes, one
    # table sized for the longest context).  check.sh gates fused >= gather
    # at the longest swept context.
    paged = _bench_paged_attention(reps=max(reps * 4, 8))
    return {
        "analysis": _bench_analysis(),
        "config": {
            "arch": cfg.name, "d_model": d_model, "d_ff": d_ff,
            "n_layers": n_layers, "batch": batch, "prompt_len": prompt_len,
            "gen": gen, "sparsity": sparsity, "block": block,
            "density": density, "backend": jax.default_backend(),
            "kernel": "pallas" if on_tpu() else "ref (CPU)",
        },
        "dense_tok_s": dense["tok_s"],
        "packed_tok_s": sparse["tok_s"],
        "prefill_ms": sparse["prefill_ms"],
        "dense_prefill_ms": dense["prefill_ms"],
        "packed_prefill_ms": sparse["prefill_ms"],
        "prefill_speedup": dense["prefill_ms"] / max(sparse["prefill_ms"], 1e-9),
        "decode_speedup": sparse["tok_s"] / max(dense["tok_s"], 1e-9),
        "continuous_batching": cb,
        "prefix_caching": pc,
        "fault_tolerance": ft,
        "slo_scheduling": slo,
        "paged_attention": paged,
    }


def _bench_analysis() -> Dict[str, Any]:
    """Time the static-analysis sweep (DESIGN.md §14) over the tree.

    check.sh runs the same sweep as a gate; the committed numbers keep
    the analyzer honest about staying interactive (~1-2s) as the tree
    grows, and record the finding census the baseline carries.
    """
    from pathlib import Path

    from repro.analysis import lint

    root = Path(__file__).resolve().parents[1]
    t0 = time.perf_counter()
    report = lint.run_project(root)
    runtime_ms = (time.perf_counter() - t0) * 1e3
    return {
        "runtime_ms": runtime_ms,
        "files_scanned": report.files_scanned,
        "findings": len(report.findings),
        "new": len(report.diff.new),
        "baselined": len(report.diff.known),
        "stale": len(report.diff.stale),
        "inline_suppressed": report.inline_suppressed,
        "by_rule": report.by_rule(),
    }


def main(quick: bool = False):
    """benchmarks/run.py harness entry: CSV lines (also writes the JSON)."""
    kw: Dict[str, Any] = {}
    if quick:
        kw.update(d_model=256, d_ff=1024, block=64, gen=16, reps=2)
    r = bench_serving(**kw)
    with open("BENCH_serving.json", "w") as f:
        json.dump(r, f, indent=2)
    c = r["config"]
    lines = [
        f"serving_prefill_dense,{r['dense_prefill_ms'] * 1e3:.0f},"
        f"b{c['batch']}xS{c['prompt_len']} d{c['d_model']}",
        f"serving_prefill_packed,{r['packed_prefill_ms'] * 1e3:.0f},"
        f"density={c['density']:.2f} speedup={r['prefill_speedup']:.2f}x",
        f"serving_decode,{0:.0f},dense={r['dense_tok_s']:.0f}tok/s "
        f"packed={r['packed_tok_s']:.0f}tok/s "
        f"speedup={r['decode_speedup']:.2f}x",
    ]
    cb = r["continuous_batching"]
    if "chunked_packed_tok_s" in cb:
        lines.append(
            f"serving_stream_chunked,{cb['chunked_packed_tok_s']:.0f},"
            f"packed@tps1={cb['packed_tok_s']:.0f}tok/s "
            f"packed@tps{cb['chunked_ticks_per_sync']}="
            f"{cb['chunked_packed_tok_s']:.0f}tok/s "
            f"({cb['chunked_speedup_vs_single_tick']:.2f}x)")
    pc = r["prefix_caching"]
    if "burst" in pc:
        b = pc["burst"]
        lines.append(
            f"serving_prefix_ttft,{b['shared']['ttft_p50_ms'] * 1e3:.0f},"
            f"burst p50 shared={b['shared']['ttft_p50_ms']:.1f}ms "
            f"unshared={b['unshared']['ttft_p50_ms']:.1f}ms "
            f"hit_speedup={b['ttft_speedup_hit_p50']:.2f}x")
    ft = r["fault_tolerance"]
    if "guard_on_tok_s" in ft:
        lines.append(
            f"serving_fault_guard,{ft['guard_on_tok_s']:.0f},"
            f"guard_on={ft['guard_on_tok_s']:.0f}tok/s "
            f"guard_off={ft['guard_off_tok_s']:.0f}tok/s "
            f"overhead={ft['overhead_pct']:.1f}%")
    slo = r["slo_scheduling"]
    if "burst" in slo:
        b = slo["burst"]
        lines.append(
            f"serving_slo_adaptive,{b['adaptive']['tok_s']:.0f},"
            f"burst p99 TTFT adaptive={b['adaptive']['ttft_ticks_p99']:.0f} "
            f"fixed16={b['fixed']['ttft_ticks_p99']:.0f} ticks "
            f"({b['ttft_ticks_p99_improvement']:.2f}x) "
            f"thpt_ratio={b['throughput_ratio']:.2f}")
    pa = r["paged_attention"]
    longest = str(pa["max_len"])
    row = pa["by_context"][longest]
    lines.append(
        f"serving_paged_attention,{row['fused_ms'] * 1e3:.0f},"
        f"ctx{longest} fused={row['fused_tok_s']:.0f}tok/s "
        f"gather={row['gather_tok_s']:.0f}tok/s "
        f"({pa['speedup_at_longest']:.2f}x)")
    an = r["analysis"]
    lines.append(
        f"static_analysis,{an['runtime_ms'] * 1e3:.0f},"
        f"{an['files_scanned']} files {an['findings']} findings "
        f"({an['new']} new, {an['baselined']} baselined)")
    return lines


def cli() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--sparsity", type=float, default=0.75)
    ap.add_argument("--block", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--d-ff", type=int, default=2048)
    ap.add_argument("--n-layers", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--quick", action="store_true",
                    help="smaller model / fewer steps (CI smoke)")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()

    kw: Dict[str, Any] = dict(
        sparsity=args.sparsity, block=args.block, d_model=args.d_model,
        d_ff=args.d_ff, n_layers=args.n_layers, batch=args.batch,
        prompt_len=args.prompt_len, gen=args.gen,
    )
    if args.quick:
        kw.update(d_model=256, d_ff=1024, block=64, gen=16, reps=2)

    result = bench_serving(args.arch, **kw)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    c = result["config"]
    print(f"bench_serving [{c['arch']} {c['backend']}/{c['kernel']} "
          f"density={c['density']:.2f}]")
    print(f"  dense : prefill {result['dense_prefill_ms']:7.1f}ms  "
          f"decode {result['dense_tok_s']:8.1f} tok/s")
    print(f"  packed: prefill {result['packed_prefill_ms']:7.1f}ms "
          f"({result['prefill_speedup']:.2f}x)  "
          f"decode {result['packed_tok_s']:8.1f} tok/s "
          f"({result['decode_speedup']:.2f}x)")
    cb = result["continuous_batching"]
    if "dense_tok_s" in cb:
        for tps, row in sorted(cb["by_ticks_per_sync"].items(),
                               key=lambda kv: int(kv[0])):
            print(f"  stream[tps={tps:>2}]: dense {row['dense_tok_s']:8.1f} "
                  f"tok/s  packed {row['packed_tok_s']:8.1f} tok/s  "
                  f"util {row['slot_utilization']:.2f}")
        print(f"  chunked packed speedup vs single-tick: "
              f"{cb['chunked_speedup_vs_single_tick']:.2f}x "
              f"(best at ticks_per_sync={cb['chunked_ticks_per_sync']})")
    else:
        print(f"  stream: skipped ({cb['unsupported']})")
    pc = result["prefix_caching"]
    if "burst" in pc:
        for kind in ("burst", "poisson"):
            s = pc[kind]
            print(f"  prefix[{kind:>7}]: TTFT p50 shared "
                  f"{s['shared']['ttft_p50_ms']:7.1f}ms  unshared "
                  f"{s['unshared']['ttft_p50_ms']:7.1f}ms  "
                  f"hit p50 {s['ttft_speedup_hit_p50']:.2f}x "
                  f"({s['hit_requests']}/{pc['requests']} hit)")
    ft = result["fault_tolerance"]
    if "guard_on_tok_s" in ft:
        print(f"  fault guard: on {ft['guard_on_tok_s']:8.1f} tok/s  "
              f"off {ft['guard_off_tok_s']:8.1f} tok/s  "
              f"overhead {ft['overhead_pct']:+.1f}%")
    slo = result["slo_scheduling"]
    if "burst" in slo:
        for kind in ("burst", "poisson"):
            s = slo[kind]
            print(f"  slo[{kind:>7}]: TTFT p99 adaptive "
                  f"{s['adaptive']['ttft_ticks_p99']:6.1f} ticks  fixed"
                  f"{slo['fixed_ticks_per_sync']} "
                  f"{s['fixed']['ttft_ticks_p99']:6.1f} ticks "
                  f"({s['ttft_ticks_p99_improvement']:.2f}x)  thpt ratio "
                  f"{s['throughput_ratio']:.2f}  shrinks "
                  f"{s['adaptive']['chunk_shrinks']}")
    pa = result["paged_attention"]
    for ctx, row in sorted(pa["by_context"].items(), key=lambda kv: int(kv[0])):
        print(f"  paged[ctx={ctx:>5}]: gather {row['gather_ms']:7.2f}ms  "
              f"fused {row['fused_ms']:7.2f}ms  ({row['speedup']:.2f}x)")
    print(f"  -> {args.out}")
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(cli())
