"""Paper Table V: heterogeneous multi-dimensional pruning of LeNet.

The showcase of the knapsack formulation (paper §IV-D): CONV layers in
Latency strategy have per-weight resource vector [1 DSP, 0 BRAM];
FC layers in Resource strategy at 18 bits have per-*structure* vectors
[2 DSP, 1 BRAM].  One *global* MDKP trades them off.  Paper: 4.7x DSP,
1.2-2.1x BRAM at unchanged accuracy.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core import BlockingSpec
from repro.data import ImageTask
from repro.models.cnn import LENET_LAYER_CFG, init_lenet, lenet_forward

from .fpga_repro import FpgaResourceModel, bram_c, run_prune_experiment


def run(quick: bool = False) -> List[Dict]:
    task = ImageTask(height=28, width=28, channels=1, classes=10, seed=11)
    val = task.batch(99_999, 1024)

    blocking: Dict[str, BlockingSpec] = {}
    models: Dict[str, FpgaResourceModel] = {}
    for layer in LENET_LAYER_CFG:
        path_k = f"{layer.name}/kernel"
        if layer.strategy == "latency":
            # unstructured-ish: tiny structures, [1, 0] per weight group
            blocking[path_k] = BlockingSpec(bk=1, bn=1)
            models[path_k] = FpgaResourceModel(
                rf=1, precision_bits=layer.precision_bits, fpga_strategy="latency")
        else:
            c = bram_c(layer.precision_bits)           # 18 bits -> C = 2
            blocking[path_k] = BlockingSpec(bk=layer.rf * c, bn=1, consecutive=c)
            models[path_k] = FpgaResourceModel(
                rf=layer.rf, precision_bits=layer.precision_bits, multi_dim=True)
    blocking["default"] = BlockingSpec(bk=1, bn=1)
    models["default"] = FpgaResourceModel(rf=1, precision_bits=18,
                                          fpga_strategy="latency")

    res = run_prune_experiment(
        init_fn=init_lenet,
        forward=lenet_forward,
        batch_fn=lambda s: task.batch(s, 128),
        val_batch=val,
        blocking_per_layer=blocking,
        models_per_layer=models,
        target=(0.85, 0.85),
        step_size=0.2,
        pretrain_steps=80 if quick else 150,
        finetune_steps=20 if quick else 40,
        min_size=50,
    )
    return [res]


def main(quick: bool = False) -> List[str]:
    rows = run(quick)
    return [
        f"table5_lenet_md,"
        f"{r['seconds']*1e6/max(r['iterations'],1):.0f},"
        f"dsp_red={r['dsp_reduction']:.2f}x bram_red={r['bram_reduction']:.2f}x "
        f"acc={r['baseline_acc']:.3f}->{r['pruned_acc']:.3f}"
        for r in rows
    ]


if __name__ == "__main__":
    for line in main():
        print(line)
